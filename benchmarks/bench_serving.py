"""Serving benchmarks: paged KV engine throughput, prefix-sharing effect,
Pallas kernels vs jnp reference wall-time (interpret mode; on-TPU numbers
come from the roofline analysis instead)."""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.models.lm import LMConfig, init_params
from repro.serving.engine import ServingEngine

from .common import emit, timeit


def bench_engine() -> None:
    cfg = LMConfig(name="bench-serve", n_layers=2, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=257,
                   param_dtype=jnp.float32, remat="none",
                   attn_backend="ref")
    params = init_params(cfg, jax.random.key(0))

    def serve(shared_prefix: bool):
        eng = ServingEngine(cfg, params, page_size=8, num_pages=256,
                            max_batch=8)
        base = list(range(1, 17))
        for i in range(8):
            prompt = base + [40 + i] if shared_prefix \
                else [40 + i] + base[:-1] + [60 + i]
            eng.submit(prompt, max_new_tokens=8)
        done = eng.run()
        assert len(done) == 8
        return eng

    t_unique = timeit(lambda: serve(False), warmup=1, iters=2)
    t_shared = timeit(lambda: serve(True), warmup=1, iters=2)
    eng = serve(True)
    tokens = eng.metrics["decoded_tokens"]
    emit("serving/unique_prompts", t_unique,
         f"{tokens / t_unique:.1f} tok/s")
    emit("serving/shared_prefix", t_shared,
         f"{tokens / t_shared:.1f} tok/s; "
         f"hit_rate={eng.stats()['prefix_hit_rate']:.2f}")


def bench_kernels() -> None:
    from repro.kernels import ops, ref
    q = jax.random.normal(jax.random.key(1), (1, 4, 256, 128))
    k = jax.random.normal(jax.random.key(2), (1, 2, 256, 128))
    v = jax.random.normal(jax.random.key(3), (1, 2, 256, 128))

    f_ref = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c,
                                                        causal=True))
    f_ker = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, True,
                                                        None, None))
    t_ref = timeit(lambda: f_ref(q, k, v).block_until_ready(), iters=3)
    t_ker = timeit(lambda: f_ker(q, k, v).block_until_ready(), iters=3)
    emit("kernels/flash_ref_jnp", t_ref, "XLA-fused reference")
    emit("kernels/flash_pallas_interpret", t_ker,
         "interpret mode (CPU emulation; TPU perf via roofline)")


def run(quick: bool = True) -> None:
    bench_engine()
    bench_kernels()


if __name__ == "__main__":
    from .common import header
    header()
    run()
