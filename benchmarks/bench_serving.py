"""Serving benchmarks: scheduler/executor engine vs the pre-refactor
monolith on the acceptance mixed workload, plus kernel wall-times.

    PYTHONPATH=src python benchmarks/bench_serving.py [--quick] [--json P]

Sections:
  serving/unified — the scheduler/executor engine on the acceptance
      workload (8 long prompts interleaved with 24 short ones): decode
      tokens/s, mean TTFT, jit recompiles vs shape-bucket budget,
      chunked-prefill liveliness (zero_decode_steps must stay 0).
  serving/legacy  — the pre-refactor engine (un-jitted per-prompt
      prefill, batch-size-keyed decode jit, per-sequence host KV
      appends) on the SAME workload.  Acceptance: unified decode
      tokens/s >= 1.5x legacy, recompiles <= bucket count.
  serving/kernels — flash attention Pallas (interpret) vs jnp reference.

JSON (``--json``, default benchmarks/out/serving.json) carries the gate
fields consumed by CI.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.models.lm import LMConfig, init_params  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.legacy import LegacyServingEngine  # noqa: E402

if __package__ in (None, ""):
    from common import emit, header, timeit, write_json  # noqa: E402
else:
    from .common import emit, header, timeit, write_json  # noqa: E402

GATE = {}

# PR 3 unified-engine decode throughput on this workload (the committed
# benchmarks/out/serving.json before the paged-attention/delta-upload
# change).  delta_vs_pr3 RECORDS the change for trend tracking; it is
# machine-specific, so CI asserts the same-machine relative gates
# (speedup vs legacy, table_upload_rows) rather than this constant.
PR3_TOKENS_PER_S = 1222.4


def bench_cfg():
    return LMConfig(name="bench-serve", n_layers=2, d_model=128,
                    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=257,
                    param_dtype=jnp.float32, remat="none",
                    attn_backend="ref")


def mixed_workload(round_idx: int = 0):
    """The acceptance workload: 8 long prompts interleaved with 24
    short ones (3 shorts between consecutive longs).  ``round_idx``
    shifts the token content so repeated rounds on one engine measure
    steady-state serving, not prefix-cache hits."""
    prompts = []
    off = 17 * round_idx
    for i in range(8):
        prompts.append([(7 + off + 13 * i + j) % 251 for j in range(48)])
        for s in range(3):
            prompts.append([(91 + off + 5 * (3 * i + s) + j) % 251
                            for j in range(8)])
    return prompts


def _serve(eng, round_idx: int):
    ttfts = []
    for p in mixed_workload(round_idx):
        eng.submit(p, max_new_tokens=8)
    done = eng.run()
    assert len(done) == 32, f"only {len(done)}/32 served"
    for r in done:
        ttfts.append(r.first_token_at - r.submitted_at)
    return ttfts


def bench_engines(quick: bool) -> None:
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.key(0))
    iters = 2 if quick else 4

    # one engine per variant, reused across rounds: compilation is a
    # server's one-time cost, throughput/TTFT are steady-state
    eng = ServingEngine(cfg, params, page_size=8, num_pages=256,
                        max_batch=8, chunk_size=16, token_budget=32,
                        max_pages_per_seq=16)
    leg = LegacyServingEngine(cfg, params, page_size=8, num_pages=256,
                              max_batch=8)

    warmup = 1
    n_requests = len(mixed_workload(0))
    rounds = iter(range(100))
    ttfts = []
    t_new = timeit(lambda: ttfts.extend(_serve(eng, next(rounds))),
                   warmup=warmup, iters=iters)
    t_old = timeit(lambda: _serve(leg, next(rounds)),
                   warmup=warmup, iters=iters)

    m = eng.metrics
    tokens_per_round = m["decoded_tokens"] / (iters + warmup)
    tokens_old_per_round = leg.metrics["decoded_tokens"] / (iters + warmup)
    ttfts = ttfts[n_requests * warmup:]       # drop compile round(s)
    ttft_mean = sum(ttfts) / len(ttfts)

    tps_new = tokens_per_round / t_new
    tps_old = tokens_old_per_round / t_old
    GATE.update({
        "tokens_per_s": round(tps_new, 1),
        "tokens_per_s_legacy": round(tps_old, 1),
        "speedup": round(tps_new / tps_old, 2),
        "tokens_per_s_pr3_baseline": PR3_TOKENS_PER_S,
        "delta_vs_pr3": round(tps_new / PR3_TOKENS_PER_S - 1, 3),
        "ttft_mean_s": round(ttft_mean, 4),
        "recompiles": m["bucket_compiles"],
        "bucket_count": eng.bucket_count,
        "zero_decode_steps": m["zero_decode_steps"],
        "preemptions": m["preemptions"],
        "prefill_chunks": m["prefill_chunks"],
        "page_hwm": m["page_hwm"],
        # delta-mirror gate: host->device block-table rows must stay
        # O(changed rows); whole-table re-uploads would cost about
        # steps * max_batch rows on this workload
        "table_upload_rows": m["table_upload_rows"],
        "table_full_rebuilds": m["table_full_rebuilds"],
        "steps": m["steps"],
        "max_batch": eng.max_batch,
    })
    emit("serving/unified", t_new,
         f"{tps_new:.1f} tok/s; ttft={ttft_mean * 1e3:.1f}ms; "
         f"compiles={m['bucket_compiles']}/{eng.bucket_count} buckets",
         **GATE)
    emit("serving/legacy", t_old,
         f"{tps_old:.1f} tok/s; speedup={tps_new / tps_old:.2f}x",
         tokens_per_s=round(tps_old, 1))


def bench_kernels() -> None:
    from repro.kernels import ops, ref
    q = jax.random.normal(jax.random.key(1), (1, 4, 256, 128))
    k = jax.random.normal(jax.random.key(2), (1, 2, 256, 128))
    v = jax.random.normal(jax.random.key(3), (1, 2, 256, 128))

    f_ref = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c,
                                                        causal=True))
    f_ker = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, True,
                                                        None, None))
    t_ref = timeit(lambda: f_ref(q, k, v).block_until_ready(), iters=3)
    t_ker = timeit(lambda: f_ker(q, k, v).block_until_ready(), iters=3)
    emit("kernels/flash_ref_jnp", t_ref, "XLA-fused reference")
    emit("kernels/flash_pallas_interpret", t_ker,
         "interpret mode (CPU emulation; TPU perf via roofline)")


def run(quick: bool = True, json_path: str = None) -> None:
    bench_engines(quick)
    if not quick:
        bench_kernels()
    if json_path:
        write_json(json_path, meta={"bench": "serving", "quick": quick,
                                    "gate": GATE})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "out", "serving.json"))
    args = ap.parse_args()
    header()
    run(quick=args.quick, json_path=args.json)
