"""Serving benchmarks: scheduler/executor engine vs the pre-refactor
monolith on the acceptance mixed workload, plus kernel wall-times.

    PYTHONPATH=src python benchmarks/bench_serving.py [--quick] [--json P]

Sections:
  serving/unified — the scheduler/executor engine on the acceptance
      workload (8 long prompts interleaved with 24 short ones): decode
      tokens/s, mean TTFT, jit recompiles vs shape-bucket budget,
      chunked-prefill liveliness (zero_decode_steps must stay 0).
  serving/legacy  — the pre-refactor engine (un-jitted per-prompt
      prefill, batch-size-keyed decode jit, per-sequence host KV
      appends) on the SAME workload.  Acceptance: unified decode
      tokens/s >= 1.5x legacy, recompiles <= bucket count.
  serving/spec_decode — n-gram speculative decoding vs plain greedy on
      the repeat-heavy workload: acceptance rate, decode tokens/s,
      delta vs the PR 4 committed baseline.  Acceptance: outputs
      BITWISE-identical to non-speculative greedy, speculative tok/s
      >= 1.3x non-speculative, recompiles <= bucket count.
  serving/kernels — flash attention Pallas (interpret) vs jnp reference.
  serving/sharded — the SAME engine under a (data, model) device mesh,
      swept over (1,1)/(4,1)/(1,4)/(2,4) mesh shapes on 8 forced host
      devices (run in a subprocess when the current process has fewer):
      aggregate + per-device decode tokens/s, TTFT delta vs the
      single-device engine, greedy-output parity bit, recompiles per
      mesh shape.  Acceptance (``sharded_gate``): bitwise parity across
      every mesh shape, recompiles <= bucket count per shape, best
      aggregate decode tokens/s >= SHARDED_SPEEDUP_FLOOR x single, and
      the best data-parallel shape finishing the queue-bound workload
      in >= SHARDED_STEP_CONCURRENCY_FLOOR x fewer engine steps.  NOTE
      the floors are the honest same-machine gains on a single-core CPU
      host (forced host devices share one core, so per-step device
      compute scales with data-parallel degree R and throughput gains
      cancel; the step-concurrency ratio is the noise-free signal that
      R x slot capacity drains the queue R requests at a time).  On a
      real 8-accelerator host per-step cost is flat in R and the same
      sweep shows the near-linear aggregate scaling the ISSUE targets.

JSON (``--json``, default benchmarks/out/serving.json) carries the gate
fields consumed by CI.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.models.lm import LMConfig, init_params  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.legacy import LegacyServingEngine  # noqa: E402

if __package__ in (None, ""):
    from common import emit, header, timeit, write_json  # noqa: E402
else:
    from .common import emit, header, timeit, write_json  # noqa: E402

GATE = {}
SPEC_GATE = {}
SHARDED_GATE = {}
QUANT_GATE = {}

# Quantized-KV capacity gate: under a FIXED KV byte budget, an int8 /
# fp8_e4m3 page pool (1-byte codes + per-token fp32 scales, ~3.2x
# smaller pages) must sustain >= 2x the concurrent sequences of the
# fp32 pool, while greedy outputs stay at or above the tier's
# token-agreement floor vs the fp32 engine (the same floors
# tests/test_quantization.py gates; see docs/kernels.md).
QUANT_CONCURRENCY_FLOOR = 2.0
QUANT_AGREEMENT_FLOOR = {"int8": 0.75, "fp8_e4m3": 0.5}

# Mesh shapes for the sharded sweep: pure DP, pure TP, and mixed.
SHARD_SHAPES = [(1, 1), (4, 1), (1, 4), (2, 4)]
# Same-machine gates, measured honestly on the 1-core CI host where
# forced host devices SERIALIZE compute (a (4,1) step does 4 replicas'
# work on one core).  Two floors:
#   * aggregate throughput: best shape >= 0.85x single — a
#     no-collapse gate (the sharded data plane must not tax the
#     single-core host; measured band 0.92-1.08x across runs, the
#     spread is machine contention, not the code path).  Real
#     multi-accelerator hosts run replica steps in parallel and clear
#     this by ~R x.
#   * step concurrency: the best data-parallel shape must finish the
#     queue-bound workload in <= half the engine steps of the single
#     engine (measured 80 -> 28 on (4,1)) — the deterministic,
#     noise-free signal that 4x slot capacity actually drains the
#     queue 4 requests at a time.
SHARDED_SPEEDUP_FLOOR = 0.85
SHARDED_STEP_CONCURRENCY_FLOOR = 2.0

# PR 3 unified-engine decode throughput on this workload (the committed
# benchmarks/out/serving.json before the paged-attention/delta-upload
# change).  delta_vs_pr3 RECORDS the change for trend tracking; it is
# machine-specific, so CI asserts the same-machine relative gates
# (speedup vs legacy, table_upload_rows) rather than this constant.
PR3_TOKENS_PER_S = 1222.4
# PR 4 committed decode throughput (paged attention + delta uploads,
# pre-speculation) — delta_vs_pr4 records the trend; CI asserts the
# same-machine relative gate (spec >= 1.3x non-spec) instead.
PR4_TOKENS_PER_S = 1577.0


def bench_cfg():
    return LMConfig(name="bench-serve", n_layers=2, d_model=128,
                    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=257,
                    param_dtype=jnp.float32, remat="none",
                    attn_backend="ref")


def mixed_workload(round_idx: int = 0):
    """The acceptance workload: 8 long prompts interleaved with 24
    short ones (3 shorts between consecutive longs).  ``round_idx``
    shifts the token content so repeated rounds on one engine measure
    steady-state serving, not prefix-cache hits."""
    prompts = []
    off = 17 * round_idx
    for i in range(8):
        prompts.append([(7 + off + 13 * i + j) % 251 for j in range(48)])
        for s in range(3):
            prompts.append([(91 + off + 5 * (3 * i + s) + j) % 251
                            for j in range(8)])
    return prompts


def _serve(eng, round_idx: int):
    ttfts = []
    for p in mixed_workload(round_idx):
        eng.submit(p, max_new_tokens=8)
    done = eng.run()
    assert len(done) == 32, f"only {len(done)}/32 served"
    for r in done:
        ttfts.append(r.first_token_at - r.submitted_at)
    return ttfts


def bench_engines(quick: bool) -> None:
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.key(0))
    iters = 2 if quick else 4

    # one engine per variant, reused across rounds: compilation is a
    # server's one-time cost, throughput/TTFT are steady-state
    eng = ServingEngine(cfg, params, page_size=8, num_pages=256,
                        max_batch=8, chunk_size=16, token_budget=32,
                        max_pages_per_seq=16)
    leg = LegacyServingEngine(cfg, params, page_size=8, num_pages=256,
                              max_batch=8)

    warmup = 1
    n_requests = len(mixed_workload(0))
    rounds = iter(range(100))
    ttfts = []
    t_new = timeit(lambda: ttfts.extend(_serve(eng, next(rounds))),
                   warmup=warmup, iters=iters)
    t_old = timeit(lambda: _serve(leg, next(rounds)),
                   warmup=warmup, iters=iters)

    m = eng.metrics
    tokens_per_round = m["decoded_tokens"] / (iters + warmup)
    tokens_old_per_round = leg.metrics["decoded_tokens"] / (iters + warmup)
    ttfts = ttfts[n_requests * warmup:]       # drop compile round(s)
    ttft_mean = sum(ttfts) / len(ttfts)

    tps_new = tokens_per_round / t_new
    tps_old = tokens_old_per_round / t_old
    GATE.update({
        "tokens_per_s": round(tps_new, 1),
        "tokens_per_s_legacy": round(tps_old, 1),
        "speedup": round(tps_new / tps_old, 2),
        "tokens_per_s_pr3_baseline": PR3_TOKENS_PER_S,
        "delta_vs_pr3": round(tps_new / PR3_TOKENS_PER_S - 1, 3),
        "ttft_mean_s": round(ttft_mean, 4),
        "recompiles": m["bucket_compiles"],
        "bucket_count": eng.bucket_count,
        "zero_decode_steps": m["zero_decode_steps"],
        "preemptions": m["preemptions"],
        "prefill_chunks": m["prefill_chunks"],
        "page_hwm": m["page_hwm"],
        # delta-mirror gate: host->device block-table rows must stay
        # O(changed rows); whole-table re-uploads would cost about
        # steps * max_batch rows on this workload
        "table_upload_rows": m["table_upload_rows"],
        "table_full_rebuilds": m["table_full_rebuilds"],
        "steps": m["steps"],
        "max_batch": eng.max_batch,
    })
    emit("serving/unified", t_new,
         f"{tps_new:.1f} tok/s; ttft={ttft_mean * 1e3:.1f}ms; "
         f"compiles={m['bucket_compiles']}/{eng.bucket_count} buckets",
         **GATE)
    emit("serving/legacy", t_old,
         f"{tps_old:.1f} tok/s; speedup={tps_new / tps_old:.2f}x",
         tokens_per_s=round(tps_old, 1))


def repeat_workload(round_idx: int = 0, n_prompts: int = 48):
    """Candidate repeat-heavy prompts (a token cycle repeated 4x).
    ``round_idx`` shifts content so rounds measure steady-state serving;
    ``spec_workloads`` narrows the pool to the candidates whose greedy
    continuation is ACTUALLY repetitive."""
    prompts = []
    off = 29 * round_idx
    for i in range(n_prompts):
        cycle = [(off + 11 * i + j) % 251 for j in range(8)]
        prompts.append(cycle * 4)
    return prompts


def spec_workloads(cfg, params, rounds: int, n_prompts: int = 16):
    """Build the repeat-heavy spec workload: roll each candidate prompt
    forward 64 tokens with a plain (non-speculative) engine, score how
    often prompt-lookup would have predicted the rollout's own second
    half, and keep the ``n_prompts`` most repetitive PRIMED histories
    (prompt + rollout) per round.  This is the workload speculative
    decoding is FOR — text whose continuation echoes its own past
    (code, templated output, the argmax cycles small models fall
    into) — constructed measurably instead of hoped for.  The same
    prompts feed BOTH engines, so the exactness assert still bites."""
    from repro.serving.spec import NgramProposer
    gen = ServingEngine(cfg, params, page_size=8, num_pages=512,
                        max_batch=8, chunk_size=16, token_budget=64,
                        max_pages_per_seq=32)
    prop = NgramProposer()
    workloads = []
    for r in range(rounds):
        cands = repeat_workload(r)
        ids = [gen.submit(p, max_new_tokens=64) for p in cands]
        gen.run()
        scored = []
        for p, i in zip(cands, ids):
            out = gen.result(i).out_tokens
            hits = sum(bool(d) and d[0] == out[t]
                       for t in range(32, 64)
                       for d in [prop.propose(p + out[:t], 1)])
            scored.append((hits, p + out))
        scored.sort(key=lambda s: (-s[0], s[1]))
        workloads.append([h for _, h in scored[:n_prompts]])
    return workloads


def _serve_repeat(eng, prompts, n_new: int = 48):
    ids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
    done = eng.run()
    assert len(done) == len(prompts), f"only {len(done)} served"
    return [eng.result(i).out_tokens for i in ids]


def bench_spec_decode(quick: bool) -> None:
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.key(0))
    iters = 2 if quick else 4
    warmup = 1
    workloads = spec_workloads(cfg, params, rounds=warmup + iters)

    def make(spec_k):
        # low batch is speculation's home regime (latency-bound decode:
        # the per-step cost is mostly fixed, so carrying k drafts per
        # slot is nearly free while accepted drafts skip whole steps)
        return ServingEngine(cfg, params, page_size=8, num_pages=512,
                             max_batch=2, chunk_size=16,
                             token_budget=32, max_pages_per_seq=32,
                             spec_k=spec_k)

    base_eng, spec_eng = make(0), make(3)
    rounds_a, rounds_b = iter(workloads), iter(workloads)
    outs_base, outs_spec = [], []
    t_base = timeit(
        lambda: outs_base.append(_serve_repeat(base_eng, next(rounds_a))),
        warmup=warmup, iters=iters)
    t_spec = timeit(
        lambda: outs_spec.append(_serve_repeat(spec_eng, next(rounds_b))),
        warmup=warmup, iters=iters)
    # THE exactness anchor: greedy speculative output must be
    # token-for-token identical to greedy non-speculative output
    exact = outs_base == outs_spec
    assert exact, "speculative greedy diverged from non-speculative"

    mb, ms = base_eng.metrics, spec_eng.metrics
    tps_base = mb["decoded_tokens"] / (iters + warmup) / t_base
    tps_spec = ms["decoded_tokens"] / (iters + warmup) / t_spec
    SPEC_GATE.update({
        "exact": exact,
        "tokens_per_s": round(tps_spec, 1),
        "tokens_per_s_nonspec": round(tps_base, 1),
        "speedup_vs_nonspec": round(tps_spec / tps_base, 2),
        "tokens_per_s_pr4_baseline": PR4_TOKENS_PER_S,
        "delta_vs_pr4": round(tps_spec / PR4_TOKENS_PER_S - 1, 3),
        "acceptance_rate": round(ms["spec_acceptance_rate"], 4),
        "proposed_tokens": ms["proposed_tokens"],
        "accepted_tokens": ms["accepted_tokens"],
        "spec_steps": ms["spec_steps"],
        "steps": ms["steps"],
        "steps_nonspec": mb["steps"],
        "recompiles": ms["bucket_compiles"],
        "bucket_count": spec_eng.bucket_count,
    })
    emit("serving/spec_decode", t_spec,
         f"{tps_spec:.1f} tok/s ({tps_spec / tps_base:.2f}x non-spec); "
         f"acceptance={ms['spec_acceptance_rate']:.1%}; exact; "
         f"compiles={ms['bucket_compiles']}/{spec_eng.bucket_count}",
         **SPEC_GATE)
    emit("serving/spec_decode_baseline", t_base,
         f"{tps_base:.1f} tok/s non-speculative greedy",
         tokens_per_s=round(tps_base, 1))


def quant_workload(n: int = 32):
    """Distinct 40-token prompts (content-shifted so the prefix cache
    cannot dedup pages — the byte budget must be paid per sequence)."""
    return [[(5 + 17 * i + j) % 251 for j in range(40)] for i in range(n)]


def _serve_concurrent(eng, prompts, max_new: int = 8):
    """Serve everything, tracking the running-sequence high-water mark
    (the concurrency the pool actually sustained)."""
    ids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    hwm, finished = 0, []
    while eng.waiting or eng.running:
        finished.extend(eng.step())
        hwm = max(hwm, len(eng.running))
    assert len(finished) == len(ids), \
        f"only {len(finished)}/{len(ids)} served"
    return hwm, [eng.result(i).out_tokens for i in ids]


def bench_quantized(quick: bool) -> None:
    """The quantized capacity sweep: same model, same workload, same KV
    byte budget — only the pool storage dtype varies.  The fp32 engine
    is page-starved (8 sequences fit); the quantized pools must fit
    >= 2x as many concurrently AND reproduce the fp32 tokens at the
    tier floor."""
    import time

    from repro.serving.kv_cache import PagedKVCache

    cfg = bench_cfg()
    params = init_params(cfg, jax.random.key(0))
    page_size, pages_f32 = 8, 48

    def page_bytes(kv_dtype):
        # dtype mirrors the engine's fp32 pool (the cache ctor default
        # is bf16, which would halve the baseline budget)
        kv = PagedKVCache(n_layers=cfg.n_layers,
                          n_kv_heads=cfg.n_kv_heads,
                          head_dim=cfg.d_model // cfg.n_heads,
                          page_size=page_size, num_pages=1,
                          dtype=jnp.float32, kv_dtype=kv_dtype)
        return kv.memory_stats()["page_bytes"]

    budget = pages_f32 * page_bytes(None)
    prompts = quant_workload(32)
    t0 = time.perf_counter()
    sweep, base_hwm, base_outs = {}, None, None
    for kv_dtype in (None, "int8", "fp8_e4m3"):
        num_pages = budget // page_bytes(kv_dtype)
        eng = ServingEngine(cfg, params, page_size=page_size,
                            num_pages=num_pages, max_batch=32,
                            chunk_size=16, token_budget=64,
                            max_pages_per_seq=6, kv_dtype=kv_dtype)
        hwm, outs = _serve_concurrent(eng, prompts)
        m = eng.metrics
        stats = {
            "num_pages": num_pages,
            "page_bytes": page_bytes(kv_dtype),
            "kv_bytes": m["kv_bytes"],
            "kv_bytes_per_seq": m["kv_bytes_per_seq"],
            "concurrent_seqs": hwm,
            "recompiles": m["bucket_compiles"],
            "bucket_count": eng.bucket_count,
            "preemptions": m["preemptions"],
        }
        if kv_dtype is None:
            base_hwm, base_outs = hwm, outs
        else:
            agree = sum(sum(a == b for a, b in zip(x, y))
                        for x, y in zip(base_outs, outs))
            total = sum(len(x) for x in base_outs)
            stats.update({
                "concurrency_vs_fp32": round(hwm / base_hwm, 2),
                "token_agreement": round(agree / total, 4),
                "agreement_floor": QUANT_AGREEMENT_FLOOR[kv_dtype],
            })
        sweep[kv_dtype or "fp32"] = stats
    QUANT_GATE.update({
        "byte_budget": budget,
        "concurrency_floor": QUANT_CONCURRENCY_FLOOR,
        "sweep": sweep,
        "concurrency_ok": all(
            s["concurrency_vs_fp32"] >= QUANT_CONCURRENCY_FLOOR
            for k, s in sweep.items() if k != "fp32"),
        "agreement_ok": all(
            s["token_agreement"] >= s["agreement_floor"]
            for k, s in sweep.items() if k != "fp32"),
        "recompile_ok": all(s["recompiles"] <= s["bucket_count"]
                            for s in sweep.values()),
    })
    i8 = sweep["int8"]
    emit("serving/quantized", time.perf_counter() - t0,
         f"int8 {i8['concurrent_seqs']} seqs "
         f"({i8['concurrency_vs_fp32']:.1f}x fp32 @ same bytes); "
         f"agreement={i8['token_agreement']:.2f}; "
         f"fp8 {sweep['fp8_e4m3']['concurrency_vs_fp32']:.1f}x",
         **QUANT_GATE)


def bench_kernels() -> None:
    from repro.kernels import ops, ref
    q = jax.random.normal(jax.random.key(1), (1, 4, 256, 128))
    k = jax.random.normal(jax.random.key(2), (1, 2, 256, 128))
    v = jax.random.normal(jax.random.key(3), (1, 2, 256, 128))

    f_ref = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c,
                                                        causal=True))
    f_ker = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, True,
                                                        None, None))
    t_ref = timeit(lambda: f_ref(q, k, v).block_until_ready(), iters=3)
    t_ker = timeit(lambda: f_ker(q, k, v).block_until_ready(), iters=3)
    emit("kernels/flash_ref_jnp", t_ref, "XLA-fused reference")
    emit("kernels/flash_pallas_interpret", t_ker,
         "interpret mode (CPU emulation; TPU perf via roofline)")


def _serve_with_outputs(eng, round_idx: int):
    """One acceptance round; returns (ttfts, greedy out_tokens)."""
    ids = [eng.submit(p, max_new_tokens=8) for p in mixed_workload(round_idx)]
    done = eng.run()
    assert len(done) == len(ids), f"only {len(done)}/{len(ids)} served"
    ttfts = [r.first_token_at - r.submitted_at for r in done]
    return ttfts, [eng.result(i).out_tokens for i in ids]


def sharded_sweep(quick: bool) -> dict:
    """The mesh sweep body — must run in a process with >= 8 devices
    (``bench_sharded`` re-execs this file under forced host devices when
    needed).  Every engine serves the SAME rounds of the acceptance
    workload, so greedy outputs are comparable bit-for-bit."""
    import time

    from repro.launch.mesh import mesh_for_serving

    cfg = bench_cfg()
    params = init_params(cfg, jax.random.key(0))
    iters = 1 if quick else 2
    ndev = len(jax.devices())
    res = {"n_devices": ndev, "shapes": {}}

    def run_one(mesh):
        eng = ServingEngine(cfg, params, page_size=8, num_pages=256,
                            max_batch=8, chunk_size=16, token_budget=32,
                            max_pages_per_seq=16, mesh=mesh)
        _serve_with_outputs(eng, 0)              # compile round
        d0 = eng.metrics["decoded_tokens"]
        t0 = time.perf_counter()
        ttfts, outs = [], None
        for r in range(1, 1 + iters):
            tf, outs = _serve_with_outputs(eng, r)
            ttfts.extend(tf)
        dt = time.perf_counter() - t0
        m = eng.metrics
        return {
            "tokens_per_s": round((m["decoded_tokens"] - d0) / dt, 1),
            "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
            "recompiles": m["bucket_compiles"],
            "bucket_count": eng.bucket_count,
            "n_replicas": m["n_replicas"],
            "steps": m["steps"],
            "kv_bytes": m["kv_bytes"],
            "page_hwm_per_replica": m["page_hwm_per_replica"],
        }, outs

    base, base_outs = run_one(None)
    res["shapes"]["single"] = base
    parity, best = True, 0.0
    for dp, tp in SHARD_SHAPES:
        key = f"{dp}x{tp}"
        if dp * tp > ndev:
            res["shapes"][key] = {"skipped": f"needs {dp * tp} devices"}
            continue
        stats, outs = run_one(mesh_for_serving(dp * tp, tp=tp))
        stats["per_device_tokens_per_s"] = round(
            stats["tokens_per_s"] / (dp * tp), 1)
        stats["ttft_delta_s"] = round(
            stats["ttft_mean_s"] - base["ttft_mean_s"], 4)
        stats["parity"] = outs == base_outs
        parity = parity and stats["parity"]
        best = max(best, stats["tokens_per_s"])
        res["shapes"][key] = stats
    swept = [s for s in res["shapes"].values() if "recompiles" in s]
    dp_steps = [s["steps"] for s in swept if s["n_replicas"] > 1]
    res.update({
        "parity": parity,
        "tokens_per_s_single": base["tokens_per_s"],
        "tokens_per_s_best": best,
        "aggregate_speedup": round(best / base["tokens_per_s"], 2),
        "speedup_floor": SHARDED_SPEEDUP_FLOOR,
        "step_concurrency": round(base["steps"] / min(dp_steps), 2)
        if dp_steps else None,
        "step_concurrency_floor": SHARDED_STEP_CONCURRENCY_FLOOR,
        "recompile_ok": all(s["recompiles"] <= s["bucket_count"]
                            for s in swept),
    })
    return res


def bench_sharded(quick: bool) -> None:
    import json as _json
    import subprocess
    import time

    t0 = time.perf_counter()
    if len(jax.devices()) >= 8:
        res = sharded_sweep(quick)
    else:
        # forced host devices must be set before jax import -> subprocess
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   REPRO_ALLOW_MULTIDEVICE="1")
        cmd = [sys.executable, os.path.abspath(__file__),
               "--sharded-worker"] + (["--quick"] if quick else [])
        out = subprocess.run(cmd, capture_output=True, text=True,
                             env=env, timeout=1800)
        assert out.returncode == 0, \
            f"sharded worker failed:\n{out.stderr[-4000:]}"
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("SHARDED-JSON ")][-1]
        res = _json.loads(line[len("SHARDED-JSON "):])
    SHARDED_GATE.update(res)
    emit("serving/sharded", time.perf_counter() - t0,
         f"best={res['tokens_per_s_best']:.1f} tok/s "
         f"({res['aggregate_speedup']:.2f}x single); "
         f"parity={'ok' if res['parity'] else 'BROKEN'}; "
         f"shapes={[k for k in res['shapes'] if k != 'single']}",
         **SHARDED_GATE)


def run(quick: bool = True, json_path: str = None,
        quant_only: bool = False) -> None:
    if not quant_only:
        bench_engines(quick)
        bench_spec_decode(quick)
        if not quick:
            bench_kernels()
        bench_sharded(quick)
    bench_quantized(quick)
    if json_path:
        write_json(json_path, meta={"bench": "serving", "quick": quick,
                                    "gate": GATE,
                                    "spec_gate": SPEC_GATE,
                                    "sharded_gate": SHARDED_GATE,
                                    "quant_gate": QUANT_GATE})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--quant-only", action="store_true",
                    help="run only the quantized capacity sweep (the "
                         "ci quant-gate job; other gate sections are "
                         "left empty in the JSON)")
    ap.add_argument("--sharded-worker", action="store_true",
                    help="internal: run the mesh sweep in-process and "
                         "print SHARDED-JSON (requires forced devices)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "out", "serving.json"))
    args = ap.parse_args()
    if args.sharded_worker:
        import json as _json
        print("SHARDED-JSON " + _json.dumps(sharded_sweep(args.quick)),
              flush=True)
        sys.exit(0)
    header()
    run(quick=args.quick, json_path=args.json,
        quant_only=args.quant_only)
