"""Paper Table 1: training throughput for the six benchmark models.

The paper compares PyTorch eager against graph frameworks and finds eager
within 17% of the fastest.  Here the two axes are OUR eager tape vs OUR
compiled path (``repro.compile``/jit = the graph-framework analogue): the
derived column reports images|tokens|samples per second for both modes —
reproducing the paper's eager-vs-graph comparison on one stack.

CPU-scale inputs (reduced batch/resolution); the model definitions are the
full published architectures.
"""

import jax

import repro
import repro.nn.functional as F
import repro.optim as optim
from repro.models.paper_models import (GNMT, NCF, AlexNet, MobileNet,
                                       ResNet50, VGG19)
from repro.nn import functional_call, param_dict

from .common import emit, timeit

CASES = {
    "alexnet": (lambda: AlexNet(10),
                lambda: (repro.randn(4, 3, 224, 224),
                         repro.randint(0, 10, (4,))), 4, "images/s"),
    "vgg19": (lambda: VGG19(10),
              lambda: (repro.randn(2, 3, 64, 64),
                       repro.randint(0, 10, (2,))), 2, "images/s"),
    "resnet50": (lambda: ResNet50(10),
                 lambda: (repro.randn(2, 3, 64, 64),
                          repro.randint(0, 10, (2,))), 2, "images/s"),
    "mobilenet": (lambda: MobileNet(10),
                  lambda: (repro.randn(2, 3, 64, 64),
                           repro.randint(0, 10, (2,))), 2, "images/s"),
    "gnmt": (lambda: GNMT(vocab=1000, hidden=128, layers=2),
             lambda: (repro.randint(0, 1000, (4, 20)),
                      repro.randint(0, 1000, (4, 21))), 80, "tokens/s"),
    "ncf": (lambda: NCF(n_users=1000, n_items=500),
            lambda: (repro.randint(0, 1000, (256,)),
                     repro.randint(0, 500, (256,))), 256, "samples/s"),
}


def _loss_for(name):
    if name == "gnmt":
        return lambda m, src, tgt: F.cross_entropy(
            m(src, tgt[:, :-1]), tgt[:, 1:])
    if name == "ncf":
        return lambda m, u, i: F.binary_cross_entropy_with_logits(
            m(u, i), repro.Tensor((i.data % 2).astype("float32")))
    return lambda m, x, y: F.cross_entropy(m(x), y)


def run(quick: bool = True) -> None:
    for name, (ctor, inputs_fn, units, unit_name) in CASES.items():
        repro.manual_seed(0)
        model = ctor()
        model.eval()                      # dropout off for stable timing
        inputs = inputs_fn()
        loss_fn = _loss_for(name)

        # ---- eager: tape autograd + in-place optimizer -----------------
        opt = optim.SGD(model.parameters(), lr=1e-3)

        def eager_step():
            opt.zero_grad()
            loss = loss_fn(model, *inputs)
            loss.backward()
            opt.step()
            repro.synchronize()

        t_eager = timeit(eager_step, warmup=1, iters=3)

        # ---- compiled: one fused jit step (graph-framework analogue) ---
        params = {k: v.data for k, v in param_dict(model).items()}
        raw = [x.data for x in inputs]

        def loss_of(p, *args):
            targs = [repro.Tensor(a) for a in args]

            class _M:                      # functional_call shim
                def __call__(self, *xs):
                    return functional_call(model, p, *xs)

            return loss_fn(_M(), *targs).data

        vg = jax.jit(jax.value_and_grad(loss_of))
        holder = {"p": params}

        def compiled_step():
            loss, grads = vg(holder["p"], *raw)
            holder["p"] = jax.tree_util.tree_map(
                lambda p, g: p - 1e-3 * g, holder["p"], grads)
            loss.block_until_ready()

        t_comp = timeit(compiled_step, warmup=2, iters=3)

        emit(f"table1/{name}/eager", t_eager,
             f"{units / t_eager:.1f} {unit_name}")
        emit(f"table1/{name}/compiled", t_comp,
             f"{units / t_comp:.1f} {unit_name}; "
             f"eager/compiled={t_eager / t_comp:.2f}x")


if __name__ == "__main__":
    from .common import header
    header()
    run()
