"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
  table1      — Table 1 training throughput (eager vs compiled)
  dispatch    — eager fast path: dispatch cache cold/warm, elementwise
                fusion on/off, foreach vs per-leaf optimizer
  runtime     — Fig. 1 async dispatch, Fig. 2 caching allocator,
                §5.5 refcount memory, §5.4 dataloader transport
  serving     — scheduler/executor engine vs the legacy monolith on the
                mixed workload + kernel wall-times (CPU interpret)
  roofline    — summarizes experiments/dryrun/*.json (produced by
                ``python -m repro.launch.dryrun --all``) — the TPU-side
                performance story lives there.

Output: ``name,us_per_call,derived`` CSV on stdout.
"""

import argparse
import glob
import json
import os

from .common import emit, header


def roofline_summary() -> None:
    pattern = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "dryrun", "*.json")
    files = sorted(glob.glob(pattern))
    if not files:
        print("# roofline: no dry-run artifacts found "
              "(run python -m repro.launch.dryrun --all)", flush=True)
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok" or rec.get("mesh") != "single":
            continue   # multi-pod cells skip the unrolled cost pass
        rl = rec["roofline"]
        total = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
             total,
             f"dominant={rl['dominant']} "
             f"compute={rl['compute_s']*1e3:.2f}ms "
             f"memory={rl['memory_s']*1e3:.2f}ms "
             f"collective={rl['collective_s']*1e3:.2f}ms "
             f"useful={rl['useful_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--sections",
                    default="table1,dispatch,runtime,serving,roofline")
    args = ap.parse_args()
    sections = set(args.sections.split(","))

    header()
    if "table1" in sections:
        from . import bench_table1
        bench_table1.run(quick=args.quick)
    if "dispatch" in sections:
        from . import bench_dispatch
        bench_dispatch.run(quick=args.quick)
    if "runtime" in sections:
        from . import bench_runtime
        bench_runtime.run(quick=args.quick)
    if "serving" in sections:
        from . import bench_serving
        bench_serving.run(quick=args.quick)
    if "roofline" in sections:
        roofline_summary()


if __name__ == "__main__":
    main()
