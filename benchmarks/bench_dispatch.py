"""Eager dispatch fast-path microbenchmarks (the Table-1 small-op story).

    PYTHONPATH=src python -m benchmarks.bench_dispatch [--json PATH]

Sections:
  dispatch/cold-vs-warm — per-op latency of a 512x512 elementwise chain
      with the tape on: cold = dispatch cache disabled (every op re-traces
      ``jax.vjp``), warm = signature-keyed cache replaying jitted
      executables.  derived = speedup (acceptance: >= 3x).
  dispatch/fusion       — the same chain with the elementwise fusion
      queue on vs off: N dispatches vs one fused kernel + flush.
  dispatch/foreach      — optimizer step on a 120-leaf param pytree:
      fused multi-tensor (bucketed concat, one jitted kernel) vs the
      per-leaf tree_map reference.  (acceptance: foreach beats per-leaf)
  dispatch/mlp          — an F.*-layer chain: 3-layer MLP forward +
      backward through nn.functional (linear/gelu/relu/mse), cold
      (cache disabled, re-traced vjp per layer op) vs warm (every layer
      op replays its cached entry).  (acceptance: warm >= 2x cold)

Numbers land in the CSV stream and, with ``--json``, in a structured
JSON record set via ``benchmarks.common.write_json``.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro  # noqa: E402
from repro.core import dispatch as dispatch_mod  # noqa: E402
from repro.core import fuse as fuse_mod  # noqa: E402

if __package__ in (None, ""):
    import common  # noqa: E402
    from common import emit, header, timeit, write_json  # noqa: E402
else:
    from . import common  # noqa: F401,E402
    from .common import emit, header, timeit, write_json  # noqa: E402

N = 512
CHAIN_RESULTS = {}
# per-section dispatch-cache snapshots: sections reset the global cache,
# so the run-level stats only ever describe the last section
SECTION_STATS = {}


def _chain(x):
    # 8 elementwise dispatches, tape recording on
    y = x * 2.0
    y = y + 1.0
    y = y.tanh()
    y = y * x
    y = y.sigmoid()
    y = y + x
    y = y.abs()
    y = y * 0.5
    return y


def bench_cold_vs_warm(iters: int) -> None:
    x = repro.randn(N, N, requires_grad=True)
    sink = []

    def dispatch_only():
        # per-op *dispatch* latency: the host enqueues and returns (§5.2
        # async execution, same methodology as fig1/async); the queue is
        # drained untimed between iterations so backpressure from device
        # compute never enters the measurement
        sink.append(_chain(x))

    def drain():
        if sink:
            sink.pop().data.block_until_ready()
            sink.clear()

    def run_sync():
        _chain(x).data.block_until_ready()

    # cold: every dispatch re-traces jax.vjp (the seed behaviour)
    with dispatch_mod.cache_disabled():
        cold = timeit(dispatch_only, warmup=1, iters=iters,
                      between=drain, stat="min")
        drain()
        cold_wall = timeit(run_sync, warmup=1, iters=iters, stat="min")

    # warm: signature-keyed replay (first call traces, then replays)
    dispatch_mod.reset_dispatch_cache()
    run_sync()  # populate
    warm = timeit(dispatch_only, warmup=2, iters=iters,
                  between=drain, stat="min")
    drain()
    warm_wall = timeit(run_sync, warmup=2, iters=iters, stat="min")
    stats = repro.dispatch_cache_stats()
    speedup = cold / warm
    wall_speedup = cold_wall / warm_wall
    CHAIN_RESULTS["cold_us"] = cold * 1e6
    CHAIN_RESULTS["warm_us"] = warm * 1e6
    CHAIN_RESULTS["warm_speedup"] = speedup
    emit("dispatch/chain512/cold", cold,
         "retraced jax.vjp per op, enqueue only", mode="cold")
    emit("dispatch/chain512/warm", warm,
         f"cached replay, speedup={speedup:.1f}x hits={stats['num_hits']}",
         mode="warm", speedup=round(speedup, 2))
    emit("dispatch/chain512/cold-wall", cold_wall,
         "retraced, synchronized", mode="cold-wall")
    emit("dispatch/chain512/warm-wall", warm_wall,
         f"cached, synchronized, speedup={wall_speedup:.1f}x",
         mode="warm-wall", speedup=round(wall_speedup, 2))
    SECTION_STATS["chain512"] = repro.dispatch_cache_stats()


def bench_fusion(iters: int) -> None:
    x = repro.randn(N, N, requires_grad=True)

    sink = []

    def drain():
        if sink:
            sink.pop().data.block_until_ready()
            sink.clear()

    def unfused():
        sink.append(_chain(x))

    def fused():
        with fuse_mod.fusion():
            y = _chain(x)
        y._data  # flush the chain (enqueues the fused kernel)
        sink.append(y)

    # warm both dispatch-cache paths
    unfused(); drain()
    fused(); drain()
    t_off = timeit(unfused, warmup=2, iters=iters, between=drain,
                   stat="min")
    drain()
    t_on = timeit(fused, warmup=2, iters=iters, between=drain,
                  stat="min")
    drain()
    speedup = t_off / t_on
    emit("dispatch/fusion512/off", t_off, "8 dispatches", mode="off")
    emit("dispatch/fusion512/on", t_on,
         f"1 fused kernel, speedup={speedup:.1f}x",
         mode="on", speedup=round(speedup, 2))
    SECTION_STATS["fusion512"] = repro.dispatch_cache_stats()


def bench_foreach(iters: int) -> None:
    import repro.optim as optim

    def make(foreach):
        repro.manual_seed(0)
        ps = [repro.randn(64, 32, requires_grad=True) for _ in range(60)] \
            + [repro.randn(32, requires_grad=True) for _ in range(60)]
        for p in ps:
            p.grad = repro.Tensor(p.data * 0.01)
        return ps, optim.AdamW(ps, lr=1e-3, foreach=foreach)

    ps_f, opt_f = make(True)
    ps_l, opt_l = make(False)

    def step_foreach():
        opt_f.step()
        ps_f[0].data.block_until_ready()

    def step_perleaf():
        opt_l.step()
        ps_l[0].data.block_until_ready()

    t_fe = timeit(step_foreach, warmup=2, iters=iters, stat="min")
    t_pl = timeit(step_perleaf, warmup=2, iters=iters, stat="min")
    speedup = t_pl / t_fe
    CHAIN_RESULTS["foreach_speedup"] = speedup
    emit("dispatch/optim120/per-leaf", t_pl, "tree_map per leaf",
         mode="per-leaf", leaves=120)
    emit("dispatch/optim120/foreach", t_fe,
         f"fused buckets, speedup={speedup:.1f}x",
         mode="foreach", leaves=120, speedup=round(speedup, 2))


def bench_functional_mlp(iters: int) -> None:
    """The nn.functional fast path: warm layer-op replay vs cold
    re-trace for a full MLP forward + backward step."""
    import repro.nn as nn
    import repro.nn.functional as F

    repro.manual_seed(7)
    model = nn.Sequential(
        nn.Linear(256, 256), nn.GELU(),
        nn.Linear(256, 256), nn.ReLU(),
        nn.Linear(256, 64))
    params = list(model.parameters())
    x = repro.randn(64, 256)
    y = repro.randn(64, 64)

    def step():
        for p in params:
            p.grad = None
        loss = F.mse_loss(model(x), y)
        loss.backward()
        params[0].grad.data.block_until_ready()

    with dispatch_mod.cache_disabled():
        cold = timeit(step, warmup=1, iters=iters, stat="min")

    dispatch_mod.reset_dispatch_cache()
    step()  # populate
    warm = timeit(step, warmup=2, iters=iters, stat="min")
    stats = repro.dispatch_cache_stats()
    speedup = cold / warm
    CHAIN_RESULTS["mlp_cold_us"] = cold * 1e6
    CHAIN_RESULTS["mlp_warm_us"] = warm * 1e6
    CHAIN_RESULTS["mlp_warm_speedup"] = speedup
    hygiene = (stats["num_uncached"] == 0
               and stats["num_fallback_unhashable"] == 0)
    emit("dispatch/mlp256/cold", cold,
         "F.* fwd+bwd, retraced per layer op", mode="cold")
    emit("dispatch/mlp256/warm", warm,
         f"cached layer-op replay, speedup={speedup:.1f}x "
         f"hygiene={'ok' if hygiene else 'VIOLATED'}",
         mode="warm", speedup=round(speedup, 2),
         uncached=stats["num_uncached"],
         fallback_unhashable=stats["num_fallback_unhashable"])
    SECTION_STATS["mlp256"] = stats


def run(quick: bool = True, json_path: str = None) -> None:
    iters = 15 if quick else 40
    bench_cold_vs_warm(iters)
    bench_fusion(iters)
    bench_foreach(iters)
    bench_functional_mlp(iters)
    if json_path:
        write_json(json_path, meta={
            "bench": "dispatch", "backend": jax.default_backend(),
            "n": N, "cache_stats_by_section": SECTION_STATS,
        })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "out", "dispatch.json"))
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.json), exist_ok=True)
    header()
    run(quick=args.quick, json_path=args.json)


if __name__ == "__main__":
    main()
