"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""

import time
from typing import Callable, Optional

ROWS = []


def timeit(fn: Callable, *, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    us = seconds * 1e6
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
