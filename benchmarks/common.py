"""Benchmark utilities: timing + CSV/JSON emission.

Rows accumulate in ``ROWS`` (CSV lines, printed as they land) and in
``RECORDS`` (structured dicts).  ``write_json(path)`` dumps the records —
the machine-readable perf trajectory tracked across PRs.
"""

import json
import time
from typing import Callable, Optional

ROWS = []
RECORDS = []


def timeit(fn: Callable, *, warmup: int = 2, iters: int = 5,
           between: Optional[Callable] = None,
           stat: str = "median") -> float:
    """Seconds per call (``stat``: "median" or "min").

    ``between`` runs untimed before every timed call — e.g. a queue
    drain, so async-dispatch benchmarks measure enqueue latency rather
    than device-compute backpressure.  ``stat="min"`` is the
    noise-robust choice for dispatch microbenchmarks on contended
    machines."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        if between is not None:
            between()
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[0] if stat == "min" else times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "", **extra) -> None:
    us = seconds * 1e6
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us_per_call": round(us, 2),
                    "derived": derived, **extra})
    print(row, flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def write_json(path: str, meta: Optional[dict] = None) -> None:
    """Dump every emitted record (plus optional run metadata) as JSON."""
    payload = {"meta": meta or {}, "records": RECORDS}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[json] {path}", flush=True)
