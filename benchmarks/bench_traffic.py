"""Deterministic heavy-traffic benchmark for the streaming front door.

    PYTHONPATH=src python benchmarks/bench_traffic.py [--quick] [--json P]
        [--seed S] [--rate R] [--requests N]

Everything runs on VIRTUAL time: seeded Poisson arrivals
(``random.expovariate``) and a fixed per-engine-step cost on the
injectable ``FakeClock`` shared with the tier-1 tests
(``tests/clockutil.py``).  The same seed therefore produces the same
arrival trace, the same admission decisions, and bit-identical latency
percentiles on every machine — a tail-latency benchmark CI can gate
with hard ceilings instead of fuzz factors.

Sections:
  traffic/poisson — N requests arriving Poisson at ``--rate`` (virtual
      req/s) with mixed prompt lengths, priorities, tenants and TTFT
      deadlines, streamed through ``AsyncFrontend``: p50/p99 TTFT,
      p50/p99 inter-token latency, decode throughput (tokens per
      virtual second), shed + timed-out counts, recompiles vs the
      shape-bucket budget.
  traffic/churn   — the adversarial run: same arrivals plus seeded
      client churn (server-side cancels + consumer disconnects
      mid-decode).  Gates: KV refcount conservation
      (allocated == freed + held) after the drain, ZERO dropped tokens
      on cancelled streams, zero stuck streams, every stream exactly
      one terminal event.

JSON (``--json``, default benchmarks/out/traffic.json) carries the
``TRAFFIC_GATE`` fields consumed by the CI ``traffic-gate`` job.
"""

import argparse
import asyncio
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from clockutil import FakeClock  # noqa: E402
from repro.models.lm import LMConfig, init_params  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.errors import AdmissionRejected  # noqa: E402
from repro.serving.frontend import AsyncFrontend  # noqa: E402

if __package__ in (None, ""):
    from common import emit, header, write_json  # noqa: E402
else:
    from .common import emit, header, write_json  # noqa: E402

TRAFFIC_GATE = {}

# Virtual cost of one engine step.  The value itself is arbitrary (it
# cancels out of every ratio); what matters is that it is FIXED, so the
# latency distribution is a pure function of (seed, workload, scheduler
# policy) and regressions in admission ordering or prefill liveliness
# move the gated percentiles deterministically.
STEP_COST_S = 0.005
# Hard ceilings for the gated run (seed 0, --quick workload).  The sim
# is bit-deterministic, so these are behavioral regression tripwires
# (2-3x headroom over measured), not noise allowances.
P99_TTFT_CEILING_S = 0.40
P99_ITL_CEILING_S = 0.08


def pctl(xs, q):
    """Nearest-rank percentile of a non-empty list (q in [0, 100])."""
    s = sorted(xs)
    return s[min(len(s) - 1, int(round(q / 100 * (len(s) - 1))))]


def bench_cfg():
    return LMConfig(name="traffic", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab_size=97,
                    param_dtype=jnp.float32, remat="none",
                    attn_backend="ref")


def build(clk, *, num_pages=96, max_batch=4):
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.key(0))
    return ServingEngine(cfg, params, page_size=4, num_pages=num_pages,
                         max_batch=max_batch, chunk_size=16, clock=clk)


def make_workload(rng, n, rate_rps, vocab):
    """Poisson arrival times + mixed request shapes (1 long : 3 short,
    a quarter high-priority with TTFT deadlines, two tenants)."""
    reqs, t = [], 0.0
    for i in range(n):
        t += rng.expovariate(rate_rps)
        long = i % 4 == 0
        plen = 24 if long else rng.choice([4, 6, 8])
        reqs.append({
            "arrival": t,
            "prompt": [rng.randrange(1, vocab - 1) for _ in range(plen)],
            "max_new": 12 if long else rng.choice([4, 6, 8]),
            "priority": 1 if i % 4 == 1 else 0,
            "tenant": "a" if i % 3 else "b",
            "ttft_deadline_ms": 2000.0 if i % 4 == 1 else None,
        })
    return reqs


async def simulate(seed, n, rate_rps, *, churn=False):
    """Drive the Poisson workload through the front door on virtual
    time; returns (per-request records, frontend, engine)."""
    rng = random.Random(seed)
    clk = FakeClock()
    eng = build(clk)
    fe = AsyncFrontend(eng, hwm_frac=0.95, low_priority_hwm_frac=0.85,
                       max_queue_depth=64)
    work = make_workload(rng, n, rate_rps, bench_cfg().vocab_size)
    recs = [{"arrival": w["arrival"], "token_times": [],
             "terminal": None, "end": None} for w in work]
    tasks = []

    async def consume(i, w):
        rec = recs[i]
        try:
            async for ev in fe.stream(
                    w["prompt"], w["max_new"], priority=w["priority"],
                    tenant=w["tenant"],
                    ttft_deadline_ms=w["ttft_deadline_ms"]):
                if ev.kind == "token":
                    rec["token_times"].append(clk.t)
                else:
                    rec["terminal"] = ev.kind
                    rec["end"] = clk.t
        except AdmissionRejected:
            rec["terminal"] = "shed"
            rec["end"] = clk.t

    crng = random.Random(seed + 1)
    nxt = 0
    for _ in range(200_000):                      # hard bound, never hit
        while nxt < n and work[nxt]["arrival"] <= clk.t:
            recs[nxt]["arrival"] = clk.t          # admission-quantized
            tasks.append(asyncio.ensure_future(consume(nxt, work[nxt])))
            nxt += 1
        for _ in range(4):
            await asyncio.sleep(0)                # let consumers run
        if churn:
            r = crng.random()
            if r < 0.10 and eng.scheduler.running:
                eng.cancel(crng.choice(list(eng.scheduler.running)))
            elif r < 0.18 and tasks:
                t = crng.choice(tasks)
                if not t.done():
                    t.cancel()                    # client disconnect
        if nxt >= n and not fe.busy and all(t.done() for t in tasks):
            break
        fe.pump()
        clk.advance(STEP_COST_S)
        for _ in range(4):
            await asyncio.sleep(0)
    for t in tasks:
        if not t.done():
            t.cancel()
        try:
            await t
        except asyncio.CancelledError:
            pass
    return recs, fe, eng


def summarize(recs, fe, eng, section):
    finished = [r for r in recs if r["terminal"] == "finished"]
    ttfts = [r["token_times"][0] - r["arrival"]
             for r in recs if r["token_times"]]
    itls = [b - a for r in recs
            for a, b in zip(r["token_times"], r["token_times"][1:])]
    vtime = max((r["end"] for r in recs if r["end"] is not None),
                default=STEP_COST_S)
    m = eng.metrics
    out = {
        "finished": len(finished),
        "shed": sum(r["terminal"] == "shed" for r in recs),
        "timed_out": sum(r["terminal"] == "timed_out" for r in recs),
        "cancelled": sum(r["terminal"] == "cancelled" for r in recs),
        "no_terminal": sum(r["terminal"] is None for r in recs),
        "p50_ttft_s": round(pctl(ttfts, 50), 4) if ttfts else None,
        "p99_ttft_s": round(pctl(ttfts, 99), 4) if ttfts else None,
        "p50_itl_s": round(pctl(itls, 50), 4) if itls else None,
        "p99_itl_s": round(pctl(itls, 99), 4) if itls else None,
        "decode_tok_per_vs": round(m["decoded_tokens"] / vtime, 1),
        "tokens_streamed": fe.metrics["tokens_streamed"],
        "tokens_dropped": fe.metrics["tokens_dropped"],
        "ttft_deadline_misses": m["ttft_deadline_misses"],
        "aged_admissions": m["aged_admissions"],
        "backpressure_rejections": fe.metrics["backpressure_rejections"],
        "bucket_compiles": m["bucket_compiles"],
        "bucket_budget": eng.bucket_count,
        "open_streams": len(fe._streams),
        # engine-side liveness: anything still queued/running after the
        # drain IS a stuck stream (client-side ``no_terminal`` is not —
        # deliberately disconnected consumers never see a terminal)
        "engine_inflight": len(eng.scheduler.waiting)
        + len(eng.scheduler.running),
    }
    pool = eng.kv.pool
    out["refcount_conserved"] = (
        pool.stats.allocated_pages
        == pool.stats.freed_pages + len(pool.refs))
    out["pages_leaked"] = (pool.num_pages - pool.num_free
                           - len(pool.refs))
    emit(f"{section}/p99_ttft", out["p99_ttft_s"] or 0.0,
         f"p50={out['p50_ttft_s']}s finished={out['finished']}", **out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized workload (the gated configuration)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=30.0,
                    help="Poisson arrival rate, virtual req/s")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "out", "traffic.json"))
    args = ap.parse_args()
    n = args.requests or (24 if args.quick else 64)

    header()
    recs, fe, eng = asyncio.run(simulate(args.seed, n, args.rate))
    poisson = summarize(recs, fe, eng, "traffic/poisson")

    recs, fe, eng = asyncio.run(
        simulate(args.seed, n, args.rate, churn=True))
    churn = summarize(recs, fe, eng, "traffic/churn")

    TRAFFIC_GATE.update({
        "seed": args.seed, "requests": n, "rate_rps": args.rate,
        "step_cost_s": STEP_COST_S,
        "p99_ttft_s": poisson["p99_ttft_s"],
        "p99_ttft_ceiling_s": P99_TTFT_CEILING_S,
        "p99_itl_s": poisson["p99_itl_s"],
        "p99_itl_ceiling_s": P99_ITL_CEILING_S,
        "ttft_deadline_misses": poisson["ttft_deadline_misses"],
        "tokens_dropped": poisson["tokens_dropped"]
        + churn["tokens_dropped"],
        "churn_refcount_conserved": churn["refcount_conserved"],
        "churn_pages_leaked": churn["pages_leaked"],
        "churn_stuck_streams": churn["open_streams"]
        + churn["engine_inflight"],
        "churn_disconnects": churn["no_terminal"],
        "churn_cancelled": churn["cancelled"],
        "recompiles_within_budget":
            poisson["bucket_compiles"] <= poisson["bucket_budget"]
            and churn["bucket_compiles"] <= churn["bucket_budget"],
    })
    print("\n-- traffic gate --")
    for k, v in TRAFFIC_GATE.items():
        print(f"{k:>26}: {v}")

    os.makedirs(os.path.dirname(args.json), exist_ok=True)
    write_json(args.json, meta={
        "bench": "traffic", "quick": args.quick,
        "gate": TRAFFIC_GATE,
        "poisson": poisson, "churn": churn,
    })

    ok = (poisson["p99_ttft_s"] is not None
          and poisson["p99_ttft_s"] <= P99_TTFT_CEILING_S
          and (poisson["p99_itl_s"] or 0.0) <= P99_ITL_CEILING_S
          and TRAFFIC_GATE["tokens_dropped"] == 0
          and churn["refcount_conserved"]
          and churn["pages_leaked"] == 0
          and TRAFFIC_GATE["churn_stuck_streams"] == 0
          and TRAFFIC_GATE["recompiles_within_budget"])
    print(f"[gate] {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
