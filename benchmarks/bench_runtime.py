"""Paper Figs. 1-2 + §5.4/§5.5 subsystem benchmarks.

fig1/async     — §6.1: host dispatch runs ahead of device work.  We time
                 enqueueing a stack of matmuls (host returns immediately)
                 vs the synchronized wall time; derived = overlap ratio.
fig2/allocator — §6.2: caching allocator.  Alloc/free churn with the cache
                 ON vs emptied every round (the cudaMalloc/cudaFree path);
                 derived = speedup + hit rate, plus the first-iteration
                 (cold) vs steady-state (warm) time split, reproducing the
                 shape of Fig. 2.
refcount       — §5.5: peak memory with immediate refcount frees vs
                 deferred (GC-style batch) frees.
dataloader     — §5.4: shared-memory transport vs pickle serialization;
                 threaded DataLoader scaling.
"""

import gc
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core.allocator import CachingAllocator
from repro.data import DataLoader, SyntheticLMDataset
from repro.data.shared_memory import PickleChannel, ShmChannel

from .common import emit, timeit


# ----------------------------------------------------------------------
def bench_fig1_async() -> None:
    x = jnp.ones((512, 512))
    w = [jnp.ones((512, 512)) * 0.001 for _ in range(32)]

    def enqueue_only():
        y = x
        for wi in w:
            y = y @ wi
        return y

    # host time to DISPATCH (async: returns before compute finishes)
    t0 = time.perf_counter()
    y = enqueue_only()
    t_dispatch = time.perf_counter() - t0
    y.block_until_ready()

    def full():
        enqueue_only().block_until_ready()

    t_total = timeit(full, warmup=2, iters=5)
    emit("fig1/dispatch_host", t_dispatch,
         f"host queues 32 matmuls then returns")
    emit("fig1/total_synced", t_total,
         f"device/host ratio={t_total / max(t_dispatch, 1e-9):.1f}x "
         f"(host runs ahead)")


# ----------------------------------------------------------------------
def bench_fig2_allocator() -> None:
    sizes = [4096 * (1 + (i % 7)) for i in range(128)]

    def churn(alloc):
        blocks = [alloc.allocate(s) for s in sizes]
        for b in blocks:
            alloc.free(b)

    # warm cache (steady state, like iterations 2+ in Fig. 2)
    warm = CachingAllocator(backed=True)
    t_cold0 = time.perf_counter()
    churn(warm)                                   # first iteration: cold
    t_cold = time.perf_counter() - t_cold0
    t_warm = timeit(lambda: churn(warm), warmup=1, iters=5)

    # no-cache baseline: release to the system every round (cudaFree path)
    nocache = CachingAllocator(backed=True)

    def churn_nocache():
        churn(nocache)
        nocache.empty_cache()

    t_nocache = timeit(churn_nocache, warmup=1, iters=5)
    stats = warm.memory_stats()
    hit = stats["num_cache_hits"] / max(
        1, stats["num_cache_hits"] + stats["num_cache_misses"])
    emit("fig2/first_iteration_cold", t_cold, "all system allocs")
    emit("fig2/steady_state_cached", t_warm,
         f"hit_rate={hit:.3f}; cold/warm={t_cold / t_warm:.1f}x")
    emit("fig2/no_cache_baseline", t_nocache,
         f"cached speedup={t_nocache / t_warm:.1f}x")


# ----------------------------------------------------------------------
def bench_refcount_memory() -> None:
    alloc = repro.allocator.device_allocator()
    n, shape = 24, (256, 256)

    alloc.reset_peak_stats()
    base = alloc.stats.bytes_active

    def immediate():
        for _ in range(n):
            t = repro.randn(*shape)
            del t                              # refcount frees NOW

    immediate()
    gc.collect()
    peak_immediate = alloc.stats.peak_bytes_active - base

    alloc.reset_peak_stats()

    def deferred():
        held = []
        for _ in range(n):
            held.append(repro.randn(*shape))   # GC-style: free in batch
        held.clear()

    deferred()
    gc.collect()
    peak_deferred = alloc.stats.peak_bytes_active - base

    emit("refcount/peak_immediate_free", peak_immediate / 1e9,
         f"{peak_immediate/1e6:.1f} MB peak")
    emit("refcount/peak_deferred_free", peak_deferred / 1e9,
         f"{peak_deferred/1e6:.1f} MB peak; "
         f"deferred/immediate={peak_deferred / max(peak_immediate, 1):.0f}x")


# ----------------------------------------------------------------------
def bench_dataloader() -> None:
    arr = np.random.randn(512, 64, 64).astype(np.float32)  # ~8MB batch

    shm = ShmChannel(maxsize=64)

    def via_shm():
        for _ in range(16):
            desc = shm.send(arr)
            shm.recv()
            shm.recycle(desc)   # pooled segments: steady-state transport

    t_shm = timeit(via_shm, warmup=1, iters=3)
    shm.close()

    pk = PickleChannel(maxsize=64)

    def via_pickle():
        for _ in range(16):
            pk.send(arr)
            pk.recv()

    t_pk = timeit(via_pickle, warmup=1, iters=3)
    mb = 16 * arr.nbytes / 1e6
    emit("dataloader/shm_transport", t_shm,
         f"{mb / t_shm:.0f} MB/s")
    emit("dataloader/pickle_transport", t_pk,
         f"{mb / t_pk:.0f} MB/s; shm speedup={t_pk / t_shm:.1f}x")

    ds = SyntheticLMDataset(1000, 128, size=64)
    for workers in (0, 2, 4):
        dl = DataLoader(ds, batch_size=8, num_workers=workers,
                        pin_memory=True)
        t = timeit(lambda dl=dl: sum(1 for _ in dl), warmup=1, iters=2)
        emit(f"dataloader/workers_{workers}", t,
             f"{len(ds) / t:.0f} samples/s")


def run(quick: bool = True) -> None:
    bench_fig1_async()
    bench_fig2_allocator()
    bench_refcount_memory()
    bench_dataloader()


if __name__ == "__main__":
    from .common import header
    header()
    run()
