"""Quickstart: the paper's Listing 1 model, trained eagerly.

Demonstrates the imperative workflow end to end: custom layer as a Python
class, model composition, eager tape autograd, in-place optimizer steps,
then the same model compiled (``repro.compile``) — the eager/graph duality
of Table 1.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

import repro
import repro.nn as nn
import repro.nn.functional as F
import repro.optim as optim
from repro.nn import functional_call, param_dict


# ---- Listing 1: a custom layer is just a Python class -------------------
class LinearLayer(nn.Module):
    def __init__(self, in_sz, out_sz):
        super().__init__()
        self.w = nn.Parameter(repro.randn(in_sz, out_sz) * 0.05)
        self.b = nn.Parameter(repro.zeros(out_sz))

    def forward(self, activations):
        t = activations @ self.w
        return t + self.b


class FullBasicModel(nn.Module):
    def __init__(self):
        super().__init__()
        self.conv = nn.Conv2d(1, 16, 3)
        self.fc = LinearLayer(16 * 26 * 26, 10)

    def forward(self, x):
        t1 = self.conv(x)
        t2 = F.relu(t1)
        t3 = self.fc(t2.flatten(1))
        return F.log_softmax(t3, dim=-1)


def make_data(n=256):
    """Synthetic 'digits': class k = blob at column k."""
    repro.manual_seed(0)
    xs = np.random.randn(n, 1, 28, 28).astype(np.float32) * 0.1
    ys = np.random.randint(0, 10, n)
    for i, y in enumerate(ys):
        xs[i, 0, 8:20, 2 + y * 2: 4 + y * 2] += 1.5
    return repro.tensor(xs), repro.tensor(ys)


def main():
    model = FullBasicModel()
    opt = optim.Adam(model.parameters(), lr=1e-3)
    x, y = make_data()

    print("== eager training (define-by-run tape) ==")
    for epoch in range(6):
        perm = np.random.permutation(len(x))
        total, correct = 0.0, 0
        for i in range(0, len(x), 64):
            idx = perm[i:i + 64].tolist()
            xb, yb = x[idx], y[idx]
            opt.zero_grad()
            out = model(xb)
            loss = F.nll_loss(out, yb)
            loss.backward()          # tape-recorded graph, built this step
            opt.step()               # in-place, refcounted updates
            total += float(loss.data)
            correct += int((out.argmax(-1).data == yb.data).sum())
        print(f"epoch {epoch}: loss={total / (len(x)//64):.4f} "
              f"acc={correct/len(x):.2%}")

    print("\n== compiled inference (jit bridge) ==")
    params = {k: v.data for k, v in param_dict(model).items()}
    fwd = jax.jit(lambda p, xd: functional_call(
        model, p, repro.Tensor(xd)).data)
    t0 = time.perf_counter()
    out_eager = model(x[:64])
    t_eager = time.perf_counter() - t0
    fwd(params, x[:64].data)  # compile
    t0 = time.perf_counter()
    out_comp = fwd(params, x[:64].data)
    t_comp = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(out_eager.data),
                               np.asarray(out_comp), rtol=1e-4, atol=1e-5)
    print(f"eager fwd {t_eager*1e3:.1f}ms vs compiled {t_comp*1e3:.1f}ms "
          f"(same numerics)")

    stats = repro.allocator.memory_stats()
    print(f"\ncaching allocator: {stats['num_cache_hits']} hits / "
          f"{stats['num_cache_misses']} misses "
          f"({stats['num_system_allocs']} system allocs)")


if __name__ == "__main__":
    main()
