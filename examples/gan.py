"""Paper Listing 2, faithfully: GAN training with two models, two
optimizers, and interleaved backward passes — the workload the paper uses
to argue that "rigid APIs would struggle" while imperative code adapts.

    PYTHONPATH=src python examples/gan.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import repro
import repro.nn as nn
import repro.nn.functional as F
import repro.optim as optim

LATENT = 16
DATA_DIM = 2   # 2-D Gaussian ring — visualizable toy distribution


def create_generator():
    return nn.Sequential(
        nn.Linear(LATENT, 64), nn.ReLU(),
        nn.Linear(64, 64), nn.ReLU(),
        nn.Linear(64, DATA_DIM),
    )


def create_discriminator():
    return nn.Sequential(
        nn.Linear(DATA_DIM, 64), nn.ReLU(),
        nn.Linear(64, 64), nn.ReLU(),
        nn.Linear(64, 1),
    )


def get_noise(n=128):
    return repro.randn(n, LATENT)


def real_samples(n=128):
    theta = np.random.rand(n) * 2 * np.pi
    pts = np.stack([np.cos(theta), np.sin(theta)], 1) * 2.0
    pts += np.random.randn(n, 2) * 0.05
    return repro.tensor(pts.astype(np.float32))


def loss(scores, is_real: bool):
    target = repro.ones(scores.shape[0]) if is_real \
        else repro.zeros(scores.shape[0])
    return F.binary_cross_entropy_with_logits(scores.squeeze(-1), target)


def main():
    repro.manual_seed(0)
    discriminator = create_discriminator()
    generator = create_generator()
    optimD = optim.Adam(discriminator.parameters(), lr=2e-3)
    optimG = optim.Adam(generator.parameters(), lr=1e-3)

    def step(real_sample):
        # (1) Update Discriminator
        optimD.zero_grad()
        errD_real = loss(discriminator(real_sample), True)
        errD_real.backward()
        fake = generator(get_noise())
        errD_fake = loss(discriminator(fake.detach()), False)
        errD_fake.backward()
        optimD.step()
        # (2) Update Generator
        optimG.zero_grad()
        errG = loss(discriminator(fake), True)
        errG.backward()
        optimG.step()
        return (float(errD_real.data) + float(errD_fake.data),
                float(errG.data))

    for it in range(400):
        d_loss, g_loss = step(real_samples())
        if it % 50 == 0:
            fake = generator(get_noise(512)).numpy()
            radius = np.sqrt((fake ** 2).sum(1))
            print(f"iter {it:4d}  D={d_loss:.3f}  G={g_loss:.3f}  "
                  f"fake radius={radius.mean():.2f}±{radius.std():.2f} "
                  f"(target 2.00)")

    fake = generator(get_noise(512)).numpy()
    radius = np.sqrt((fake ** 2).sum(1))
    print(f"final: generated ring radius {radius.mean():.2f} "
          f"(real ring = 2.00)")
    assert 1.0 < radius.mean() < 3.0, "GAN failed to move toward the ring"


if __name__ == "__main__":
    main()
