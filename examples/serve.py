"""Batched serving demo: continuous batching over the paged KV cache.

Submits a burst of requests with shared system-prompt prefixes, runs the
engine, and reports latency/throughput plus the allocator's prefix-cache
and page-reuse statistics (the §5.3/§5.5 machinery at work).

    PYTHONPATH=src python examples/serve.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import BlockSpec, LMConfig, init_params
from repro.serving.engine import ServingEngine


def main():
    cfg = LMConfig(name="serve-demo", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=2, d_ff=256, vocab_size=1024,
                   pattern=(BlockSpec("attn", "dense"),),
                   param_dtype=jnp.float32, remat="none",
                   attn_backend="ref")
    params = init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, page_size=8, num_pages=512,
                           max_batch=8)

    system_prompt = list(range(100, 124))        # 24-token shared prefix
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(16):
        user = rng.integers(1, 1024, size=rng.integers(4, 12)).tolist()
        engine.submit(system_prompt + user, max_new_tokens=12)

    finished = engine.run()
    wall = time.perf_counter() - t0

    lat_first = [r.first_token_at - r.submitted_at for r in finished]
    lat_total = [r.finished_at - r.submitted_at for r in finished]
    toks = sum(len(r.out_tokens) for r in finished)
    print(f"served {len(finished)} requests, {toks} tokens "
          f"in {wall:.2f}s ({toks / wall:.1f} tok/s)")
    print(f"TTFT p50={np.median(lat_first)*1e3:.0f}ms  "
          f"latency p50={np.median(lat_total)*1e3:.0f}ms")

    s = engine.stats()
    print(f"\npaged KV allocator:")
    print(f"  pages: {s['pages_used']} in use / {s['pages_total']} "
          f"(all released: {s['pages_free'] == s['pages_total']})")
    print(f"  prefix cache hit rate: {s['prefix_hit_rate']:.1%}")
    print(f"  copy-on-write page splits: {s['cow_copies']}")
    print(f"  admission rejections (backpressure): "
          f"{s['rejected_admissions']}")
    sample = finished[0]
    print(f"\nsample continuation: {sample.prompt[-4:]} -> "
          f"{sample.out_tokens}")


if __name__ == "__main__":
    main()
