"""End-to-end distributed LM pretraining driver.

Everything in one command: config selection (--arch picks any of the 10
assigned architectures' smoke configs, or --size builds a GPT-style model
from scratch), synthetic data pipeline with parallel workers, pjit train
step with sharded optimizer state, async checkpointing with automatic
restart, straggler watchdog.

    # ~20M params, 200 steps, checkpoint/resume:
    PYTHONPATH=src python examples/train_lm.py --size 20m --steps 200

    # ~100M params (slower on CPU; the pod-scale path is the dry-run):
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

    # kill it mid-run and rerun: it resumes from the last checkpoint.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config
from repro.launch.train import train_loop
from repro.models.lm import BlockSpec, LMConfig

SIZES = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)  ≈ params
    "2m": (4, 128, 4, 2, 512, 2048),
    "20m": (8, 384, 8, 4, 1536, 8192),
    "100m": (12, 768, 12, 4, 3072, 16384),
}


def build_config(size: str) -> LMConfig:
    l, d, h, kv, ff, v = SIZES[size]
    return LMConfig(
        name=f"gpt-{size}", n_layers=l, d_model=d, n_heads=h,
        n_kv_heads=kv, d_ff=ff, vocab_size=v,
        pattern=(BlockSpec("attn", "dense"),),
        param_dtype=jnp.float32, remat="none", attn_backend="ref",
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=SIZES, default="2m")
    ap.add_argument("--arch", choices=ARCHS, default=None,
                    help="train an assigned arch's reduced config instead")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adam", "sgd", "adafactor"])
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.arch
           else build_config(args.size))
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    result = train_loop(
        cfg, steps=args.steps, batch_size=args.batch_size,
        seq_len=args.seq_len, optimizer=args.optimizer, lr=args.lr,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every, log_every=10)

    print(f"\ndone: {result['steps']} steps in "
          f"{result['wall_time_s']:.1f}s, final loss "
          f"{result['final_loss']:.4f}")


if __name__ == "__main__":
    main()
