"""Documentation gates: runnable snippets + docstring coverage.

    PYTHONPATH=src python tools/check_docs.py          # both gates
    PYTHONPATH=src python tools/check_docs.py --lint   # coverage only

Two checks, both wired into ``make docs`` and CI:

1. **Snippet execution** — every fenced ```python block in README.md
   and docs/*.md is executed (doctest-style, blocks in one file share a
   namespace so later snippets may use earlier definitions).  A snippet
   that is illustrative-only (pseudo-code, TPU-only) is skipped by
   placing ``<!-- docs: skip -->`` on the line above the fence.  Docs
   that drift from the code fail CI instead of lying to the reader.

2. **Docstring coverage** — every public callable re-exported into the
   flat ``repro.*`` namespace, plus the named serving/optim surface,
   must carry a docstring, so ``help(repro.<name>)`` is always
   self-explanatory.
"""

from __future__ import annotations

import argparse
import inspect
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

SKIP_MARK = "<!-- docs: skip -->"
FENCE = re.compile(r"^```(\w*)\s*$")


def iter_snippets(path: pathlib.Path):
    """Yield (first_line_no, code) for runnable ```python blocks."""
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if not m or m.group(1) != "python":
            i += 1
            continue
        skip = i > 0 and SKIP_MARK in lines[i - 1]
        start = i + 1
        j = start
        while j < len(lines) and not lines[j].startswith("```"):
            j += 1
        if not skip:
            yield start + 1, "\n".join(lines[start:j])
        i = j + 1


def run_snippets() -> int:
    failures = 0
    doc_files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for path in doc_files:
        if not path.exists():
            print(f"MISSING {path.relative_to(ROOT)}")
            failures += 1
            continue
        ns: dict = {}
        for line_no, code in iter_snippets(path):
            where = f"{path.relative_to(ROOT)}:{line_no}"
            try:
                exec(compile(code, where, "exec"), ns)   # noqa: S102
                print(f"ok   {where}")
            except Exception as e:                       # noqa: BLE001
                print(f"FAIL {where}: {type(e).__name__}: {e}")
                failures += 1
    return failures


def check_docstrings() -> int:
    import repro
    from repro.serving.engine import ServingEngine

    failures = 0

    def need(obj, name):
        nonlocal failures
        if not (getattr(obj, "__doc__", "") or "").strip():
            print(f"UNDOCUMENTED {name}")
            failures += 1

    # the flat torch-like namespace (repro/__init__.py star exports)
    for name in sorted(vars(repro)):
        obj = getattr(repro, name)
        if name.startswith("_") or inspect.ismodule(obj):
            continue
        if callable(obj):
            need(obj, f"repro.{name}")

    # the named API surface the README/architecture docs point at
    import repro.optim as optim
    need(repro.dispatch_cache_stats, "repro.dispatch_cache_stats")
    need(repro.fuse.fusion, "repro.fuse.fusion")
    need(repro.compile, "repro.compile")
    need(ServingEngine, "ServingEngine")
    for mname, meth in inspect.getmembers(ServingEngine,
                                          predicate=inspect.isfunction):
        if not mname.startswith("_"):
            need(meth, f"ServingEngine.{mname}")
    for cls in ("SGD", "Adam", "AdamW", "Adafactor", "Optimizer",
                "make_optimizer", "cosine_schedule",
                "clip_by_global_norm", "global_norm"):
        need(getattr(optim, cls), f"repro.optim.{cls}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lint", action="store_true",
                    help="docstring coverage only (skip snippet runs)")
    args = ap.parse_args()

    failures = check_docstrings()
    if not args.lint:
        failures += run_snippets()
    if failures:
        print(f"{failures} documentation failure(s)")
        sys.exit(1)
    print("docs clean")


if __name__ == "__main__":
    main()
