# Tier-1 verify + perf + hygiene, one command each.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast gradcheck conformance chaos bench-smoke bench lint docs traffic quant

test:
	$(PY) -m pytest -x -q

# tier-1 gate: everything except the @pytest.mark.slow heavyweights
# (chaos / conformance / gradcheck matrices run in the full CI job)
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# fault-injection matrix: the engine must fail ONE request, never the
# step loop (tests/test_chaos.py gates watchdog_trips == injected,
# refcount conservation after recovery, zero_decode_steps == 0)
chaos:
	$(PY) -m pytest -x -q tests/test_chaos.py

# the dispatch-cache gate: numeric gradients + kwarg-collision cases
gradcheck:
	$(PY) -m pytest -x -q tests/test_gradcheck.py

# forward conformance of the F.* surface (cold/warm bitwise equality)
conformance:
	$(PY) -m pytest -x -q tests/test_functional_conformance.py

bench-smoke:
	mkdir -p benchmarks/out
	$(PY) benchmarks/bench_dispatch.py --quick
	$(PY) benchmarks/bench_serving.py --quick

bench:
	$(PY) -m benchmarks.run

# deterministic heavy-traffic gate: seeded Poisson arrivals + client
# churn through the async front door on virtual time (exits nonzero on
# any TRAFFIC_GATE violation — p99 TTFT ceiling, dropped tokens,
# refcount leaks, stuck streams, recompile budget)
traffic:
	mkdir -p benchmarks/out
	$(PY) benchmarks/bench_traffic.py --quick

# quantized-KV gate: the kernel parity tier (fp32/int8/fp8 vs the jnp
# oracle), the pool-churn scale-alignment properties, the named
# quality-drift gate, and the byte-budget-matched capacity sweep
# (>= 2x concurrent sequences vs fp32 at the tier agreement floor)
quant:
	mkdir -p benchmarks/out
	$(PY) -m pytest -x -q tests/test_quantization.py
	$(PY) -m pytest -x -q tests/test_kernels.py -k "PagedAttention"
	$(PY) -m pytest -x -q tests/test_serving.py -k "QuantizedPoolChurn"
	$(PY) benchmarks/bench_serving.py --quick --quant-only \
		--json benchmarks/out/serving-quant.json

# documentation gates: README/docs snippets must RUN, public API must
# carry docstrings (tools/check_docs.py)
docs:
	$(PY) tools/check_docs.py

lint:
	$(PY) -m compileall -q src benchmarks tests tools
	@$(PY) -c "import pathlib,sys; bad=[f'{p}:{i}: line too long ({len(l)})' for p in pathlib.Path('src').rglob('*.py') for i,l in enumerate(p.read_text().splitlines(),1) if len(l)>100]; print('\n'.join(bad) or 'lint clean'); sys.exit(1 if bad else 0)"
