"""The six benchmark models of the paper's Table 1, as eager Modules.

AlexNet, VGG-19, ResNet-50, MobileNet(v1) — images/sec;
GNMTv2 — tokens/sec;  NCF (NeuMF) — samples/sec.

These exercise the imperative API exactly as the paper's benchmarks do:
plain Python classes, composed layers, run eagerly or through
``repro.compile`` (the graph-framework comparison axis of Table 1).
"""

from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp

from ..core import tensor_mod as T
from ..core.tensor import Tensor
from .. import nn
from ..nn import functional as F


# ----------------------------------------------------------------------
# AlexNet (Krizhevsky 2012, torchvision layout)
# ----------------------------------------------------------------------

class AlexNet(nn.Module):
    def __init__(self, num_classes: int = 1000):
        super().__init__()
        self.features = nn.Sequential(
            nn.Conv2d(3, 64, 11, stride=4, padding=2), nn.ReLU(),
            nn.MaxPool2d(3, 2),
            nn.Conv2d(64, 192, 5, padding=2), nn.ReLU(),
            nn.MaxPool2d(3, 2),
            nn.Conv2d(192, 384, 3, padding=1), nn.ReLU(),
            nn.Conv2d(384, 256, 3, padding=1), nn.ReLU(),
            nn.Conv2d(256, 256, 3, padding=1), nn.ReLU(),
            nn.MaxPool2d(3, 2),
        )
        self.avgpool = nn.AdaptiveAvgPool2d((6, 6))
        self.classifier = nn.Sequential(
            nn.Dropout(0.5), nn.Linear(256 * 6 * 6, 4096), nn.ReLU(),
            nn.Dropout(0.5), nn.Linear(4096, 4096), nn.ReLU(),
            nn.Linear(4096, num_classes),
        )

    def forward(self, x: Tensor) -> Tensor:
        x = self.features(x)
        x = self.avgpool(x)
        return self.classifier(x.flatten(1))


# ----------------------------------------------------------------------
# VGG-19
# ----------------------------------------------------------------------

_VGG19 = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
          512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]


class VGG19(nn.Module):
    def __init__(self, num_classes: int = 1000):
        super().__init__()
        layers: List[nn.Module] = []
        in_ch = 3
        for v in _VGG19:
            if v == "M":
                layers.append(nn.MaxPool2d(2, 2))
            else:
                layers += [nn.Conv2d(in_ch, v, 3, padding=1), nn.ReLU()]
                in_ch = v
        self.features = nn.Sequential(*layers)
        self.avgpool = nn.AdaptiveAvgPool2d((7, 7))
        self.classifier = nn.Sequential(
            nn.Linear(512 * 7 * 7, 4096), nn.ReLU(), nn.Dropout(0.5),
            nn.Linear(4096, 4096), nn.ReLU(), nn.Dropout(0.5),
            nn.Linear(4096, num_classes),
        )

    def forward(self, x: Tensor) -> Tensor:
        x = self.features(x)
        x = self.avgpool(x)
        return self.classifier(x.flatten(1))


# ----------------------------------------------------------------------
# ResNet-50
# ----------------------------------------------------------------------

class Bottleneck(nn.Module):
    expansion = 4

    def __init__(self, inplanes: int, planes: int, stride: int = 1,
                 downsample: Optional[nn.Module] = None):
        super().__init__()
        self.conv1 = nn.Conv2d(inplanes, planes, 1, bias=False)
        self.bn1 = nn.BatchNorm2d(planes)
        self.conv2 = nn.Conv2d(planes, planes, 3, stride=stride, padding=1,
                               bias=False)
        self.bn2 = nn.BatchNorm2d(planes)
        self.conv3 = nn.Conv2d(planes, planes * 4, 1, bias=False)
        self.bn3 = nn.BatchNorm2d(planes * 4)
        self.downsample = downsample or nn.Identity()

    def forward(self, x: Tensor) -> Tensor:
        identity = self.downsample(x)
        out = F.relu(self.bn1(self.conv1(x)))
        out = F.relu(self.bn2(self.conv2(out)))
        out = self.bn3(self.conv3(out))
        return F.relu(out + identity)


class ResNet50(nn.Module):
    def __init__(self, num_classes: int = 1000):
        super().__init__()
        self.inplanes = 64
        self.conv1 = nn.Conv2d(3, 64, 7, stride=2, padding=3, bias=False)
        self.bn1 = nn.BatchNorm2d(64)
        self.maxpool = nn.MaxPool2d(3, 2, padding=1)
        self.layer1 = self._make_layer(64, 3)
        self.layer2 = self._make_layer(128, 4, stride=2)
        self.layer3 = self._make_layer(256, 6, stride=2)
        self.layer4 = self._make_layer(512, 3, stride=2)
        self.avgpool = nn.AdaptiveAvgPool2d((1, 1))
        self.fc = nn.Linear(512 * 4, num_classes)

    def _make_layer(self, planes: int, blocks: int,
                    stride: int = 1) -> nn.Sequential:
        downsample = None
        if stride != 1 or self.inplanes != planes * 4:
            downsample = nn.Sequential(
                nn.Conv2d(self.inplanes, planes * 4, 1, stride=stride,
                          bias=False),
                nn.BatchNorm2d(planes * 4),
            )
        layers = [Bottleneck(self.inplanes, planes, stride, downsample)]
        self.inplanes = planes * 4
        layers += [Bottleneck(self.inplanes, planes)
                   for _ in range(1, blocks)]
        return nn.Sequential(*layers)

    def forward(self, x: Tensor) -> Tensor:
        x = self.maxpool(F.relu(self.bn1(self.conv1(x))))
        x = self.layer4(self.layer3(self.layer2(self.layer1(x))))
        x = self.avgpool(x).flatten(1)
        return self.fc(x)


# ----------------------------------------------------------------------
# MobileNet v1 (depthwise-separable)
# ----------------------------------------------------------------------

def _dw_block(in_ch: int, out_ch: int, stride: int) -> nn.Sequential:
    return nn.Sequential(
        nn.Conv2d(in_ch, in_ch, 3, stride=stride, padding=1, groups=in_ch,
                  bias=False),
        nn.BatchNorm2d(in_ch), nn.ReLU(),
        nn.Conv2d(in_ch, out_ch, 1, bias=False),
        nn.BatchNorm2d(out_ch), nn.ReLU(),
    )


class MobileNet(nn.Module):
    def __init__(self, num_classes: int = 1000):
        super().__init__()
        cfg = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
               (512, 1), (512, 1), (512, 1), (512, 1), (512, 1),
               (1024, 2), (1024, 1)]
        layers: List[nn.Module] = [
            nn.Conv2d(3, 32, 3, stride=2, padding=1, bias=False),
            nn.BatchNorm2d(32), nn.ReLU(),
        ]
        in_ch = 32
        for out_ch, stride in cfg:
            layers.append(_dw_block(in_ch, out_ch, stride))
            in_ch = out_ch
        self.features = nn.Sequential(*layers)
        self.avgpool = nn.AdaptiveAvgPool2d((1, 1))
        self.fc = nn.Linear(1024, num_classes)

    def forward(self, x: Tensor) -> Tensor:
        x = self.features(x)
        return self.fc(self.avgpool(x).flatten(1))


# ----------------------------------------------------------------------
# GNMTv2 (seq2seq LSTM with attention; tokens/sec benchmark)
# ----------------------------------------------------------------------

class BahdanauAttention(nn.Module):
    def __init__(self, dim: int):
        super().__init__()
        self.q = nn.Linear(dim, dim, bias=False)
        self.k = nn.Linear(dim, dim, bias=False)
        self.v = nn.Linear(dim, 1, bias=False)

    def forward(self, query: Tensor, keys: Tensor) -> Tensor:
        # query (B, Sq, D), keys (B, Sk, D)
        scores = self.v(F.tanh(self.q(query).unsqueeze(2)
                               + self.k(keys).unsqueeze(1))).squeeze(-1)
        weights = F.softmax(scores, dim=-1)          # (B, Sq, Sk)
        return weights @ keys


class GNMT(nn.Module):
    """4-layer encoder (1 bidir) / 4-layer decoder with attention —
    GNMTv2 structure at configurable width."""

    def __init__(self, vocab: int = 32000, hidden: int = 1024,
                 layers: int = 4):
        super().__init__()
        self.embed_src = nn.Embedding(vocab, hidden)
        self.embed_tgt = nn.Embedding(vocab, hidden)
        self.enc_bidir = nn.LSTM(hidden, hidden, 1, bidirectional=True)
        self.enc_proj = nn.Linear(2 * hidden, hidden, bias=False)
        self.enc_stack = nn.LSTM(hidden, hidden, layers - 1)
        self.attention = BahdanauAttention(hidden)
        self.dec_stack = nn.LSTM(2 * hidden, hidden, layers)
        self.out = nn.Linear(hidden, vocab)

    def forward(self, src: Tensor, tgt: Tensor) -> Tensor:
        enc = self.embed_src(src)
        enc, _ = self.enc_bidir(enc)
        enc = self.enc_proj(enc)
        enc, _ = self.enc_stack(enc)
        dec_in = self.embed_tgt(tgt)
        ctx = self.attention(dec_in, enc)            # (B, St, D)
        dec, _ = self.dec_stack(T.cat([dec_in, ctx], dim=-1))
        return self.out(dec)


# ----------------------------------------------------------------------
# NCF / NeuMF (samples/sec benchmark)
# ----------------------------------------------------------------------

class NCF(nn.Module):
    def __init__(self, n_users: int = 138_000, n_items: int = 27_000,
                 mf_dim: int = 64, mlp_dims=(256, 256, 128, 64)):
        super().__init__()
        self.user_mf = nn.Embedding(n_users, mf_dim)
        self.item_mf = nn.Embedding(n_items, mf_dim)
        self.user_mlp = nn.Embedding(n_users, mlp_dims[0] // 2)
        self.item_mlp = nn.Embedding(n_items, mlp_dims[0] // 2)
        mlp: List[nn.Module] = []
        for i in range(len(mlp_dims) - 1):
            mlp += [nn.Linear(mlp_dims[i], mlp_dims[i + 1]), nn.ReLU()]
        self.mlp = nn.Sequential(*mlp)
        self.head = nn.Linear(mf_dim + mlp_dims[-1], 1)

    def forward(self, users: Tensor, items: Tensor) -> Tensor:
        mf = self.user_mf(users) * self.item_mf(items)
        mlp = self.mlp(T.cat([self.user_mlp(users), self.item_mlp(items)],
                             dim=-1))
        return self.head(T.cat([mf, mlp], dim=-1)).squeeze(-1)


PAPER_MODELS = {
    "alexnet": AlexNet,
    "vgg19": VGG19,
    "resnet50": ResNet50,
    "mobilenet": MobileNet,
    "gnmt": GNMT,
    "ncf": NCF,
}
