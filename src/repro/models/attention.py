"""Attention computation layer (jnp-level, kernel-selectable).

``sdpa`` is the single entry point used by both the eager ``nn.functional``
path and the functional LM models.  It handles:

  * GQA/MQA: k/v with fewer heads than q are broadcast per group,
  * causal masking, sliding-window (local) masking, explicit masks,
  * backend selection: "ref" (pure jnp, the oracle), "pallas" (flash
    kernel), "auto" (pallas when available for the shape, else ref).

All reference math upcasts softmax statistics to f32, matching the Pallas
kernels bit-for-bit in structure so allclose checks are tight.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_PALLAS_MIN_SEQ = 128  # below this the ref path is cheaper than tiling


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, Hkv, S, D) -> (B, Hkv*n_rep, S, D)."""
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    k = jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d))
    return k.reshape(b, h * n_rep, s, d)


def _build_mask(q_len: int, kv_len: int, is_causal: bool,
                window: Optional[int], dtype) -> Optional[jnp.ndarray]:
    if not is_causal and window is None:
        return None
    # query i attends key j where j <= i + (kv_len - q_len)  (causal)
    # and j > i + (kv_len - q_len) - window                  (sliding)
    q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    k_pos = jnp.arange(kv_len)[None, :]
    ok = jnp.ones((q_len, kv_len), dtype=bool)
    if is_causal:
        ok = ok & (k_pos <= q_pos)
    if window is not None:
        ok = ok & (k_pos > q_pos - window)
    return ok


def sdpa_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             mask: Optional[jnp.ndarray] = None,
             is_causal: bool = False,
             scale: Optional[float] = None,
             window: Optional[int] = None) -> jnp.ndarray:
    """Pure-jnp oracle. q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        k = repeat_kv(k, hq // hkv)
        v = repeat_kv(v, hq // hkv)
    scale = scale if scale is not None else d ** -0.5

    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    structural = _build_mask(sq, k.shape[2], is_causal, window, q.dtype)
    if structural is not None:
        logits = jnp.where(structural[None, None], logits,
                           jnp.finfo(jnp.float32).min)
    if mask is not None:
        if mask.dtype == jnp.bool_:
            logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
        else:
            logits = logits + mask.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def context_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 scale: Optional[float], causal: bool,
                 window: Optional[int]) -> jnp.ndarray:
    """Manual context-parallel attention (used when heads don't divide TP
    and the residual stream is sequence-sharded).

    GSPMD cannot derive ring attention: left alone it all-gathers the
    full f32 (B, H, S, D) q/k/v per layer (§Perf yi iteration log).
    Here each model rank keeps its LOCAL query slice and all-gathers only
    the (much smaller, GQA-reduced, bf16) K/V — the KV-gather variant of
    context parallelism.  Causal masking uses global query offsets.
    """
    from ..distributed import act_sharding as AS
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import functools

    scope = AS._get()
    mesh = scope.mesh
    axis = scope.model
    b, hq, s_full, d = q.shape
    batch_ax = scope.batch if (b > 1 and b % scope.data_size == 0) \
        else None
    qspec = P(batch_ax, None, axis, None)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(qspec, qspec, qspec), out_specs=qspec,
        check_rep=False)
    def _inner(q_l, k_l, v_l):
        idx = jax.lax.axis_index(axis)
        k_g = jax.lax.all_gather(k_l, axis, axis=2, tiled=True)
        v_g = jax.lax.all_gather(v_l, axis, axis=2, tiled=True)
        s_loc = q_l.shape[2]
        q_pos = idx * s_loc + jnp.arange(s_loc)[:, None]
        k_pos = jnp.arange(k_g.shape[2])[None, :]
        ok = jnp.ones((s_loc, k_g.shape[2]), bool)
        if causal:
            ok = ok & (k_pos <= q_pos)
        if window is not None:
            ok = ok & (k_pos > q_pos - window)
        return sdpa_ref(q_l, k_g, v_g, mask=ok[None, None], scale=scale)

    return _inner(q, k, v)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask: Optional[jnp.ndarray] = None,
         is_causal: bool = False,
         scale: Optional[float] = None,
         window: Optional[int] = None,
         backend: str = "auto") -> jnp.ndarray:
    if backend == "ref":
        import os
        from ..distributed import act_sharding as AS
        scope = AS._get()
        if (os.environ.get("REPRO_SEQ_SHARD") == "1" and scope is not None
                and scope.model is not None and mask is None
                and q.shape[1] % scope.model_size != 0
                and q.shape[2] % scope.model_size == 0
                and q.shape[2] == k.shape[2]):
            return context_sdpa(q, k, v, scale, is_causal, window)
        return sdpa_ref(q, k, v, mask, is_causal, scale, window)
    if backend in ("auto", "pallas"):
        if mask is None and q.shape[2] >= _PALLAS_MIN_SEQ:
            try:
                from ..kernels import ops as kops
                return kops.flash_attention(
                    q, k, v, causal=is_causal, scale=scale, window=window)
            except Exception:
                if backend == "pallas":
                    raise
        return sdpa_ref(q, k, v, mask, is_causal, scale, window)
    raise ValueError(f"unknown sdpa backend {backend!r}")


def mixed_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, seg_ids: jnp.ndarray,
                    positions: jnp.ndarray,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    backend: str = "auto") -> jnp.ndarray:
    """Attention for a FLAT token batch mixing prefill chunks and decode
    tokens (the serving executor's unified step).

    q: (T, Hq, D) — one query per scheduled token; k_cache/v_cache:
    (S, Hkv, L, D) — per-slot contiguous KV (gathered from pages, already
    containing this step's scatter); seg_ids: (T,) slot index per token
    (<0 = padding); positions: (T,) absolute position of the token in its
    sequence.  Token t attends slot seg_ids[t]'s cache at key positions
    <= positions[t] (its own K/V included) — causal both against history
    and within its prefill chunk.  Returns (T, Hq, D).
    """
    t, hq, d = q.shape
    s, hkv, l, _ = k_cache.shape
    scale = scale if scale is not None else d ** -0.5

    if backend in ("auto", "pallas"):
        try:
            from ..kernels import ops as kops
            return kops.mixed_attention(q, k_cache, v_cache, seg_ids,
                                        positions, scale=scale,
                                        window=window)
        except Exception:
            if backend == "pallas":
                raise

    seg = jnp.clip(seg_ids, 0, s - 1)
    k = jnp.take(k_cache, seg, axis=0)                  # (T, Hkv, L, D)
    v = jnp.take(v_cache, seg, axis=0)
    if hkv != hq:
        k = repeat_kv(k, hq // hkv)
        v = repeat_kv(v, hq // hkv)
    logits = jnp.einsum("thd,thld->thl", q, k,
                        preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(l)[None, :]
    valid = k_pos <= positions[:, None]
    if window is not None:
        valid = valid & (k_pos > positions[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits,
                       jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("thl,thld->thd", probs, v)


def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, tables: jnp.ndarray,
                    seg_ids: jnp.ndarray, positions: jnp.ndarray,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    k_scale: Optional[jnp.ndarray] = None,
                    v_scale: Optional[jnp.ndarray] = None,
                    pages_per_tile: Optional[int] = None,
                    backend: str = "auto") -> jnp.ndarray:
    """Mixed prefill/decode attention DIRECTLY over the physical KV page
    pool — no per-slot contiguous cache is materialized.

    q: (T, Hq, D) — one query per scheduled token; k_pages/v_pages:
    (N, ps, Hkv, D) — the page arrays exactly as ``PagedKVCache`` stores
    them; tables: (S, P) int32 device block tables (row s = slot s's
    physical page ids, padded with 0); seg_ids: (T,) slot per token
    (<0 = padding); positions: (T,) absolute position in the sequence.
    Token t attends slot seg_ids[t]'s pages at key positions <=
    positions[t].  Returns (T, Hq, D).

    A QUANTIZED pool (int8 / fp8_e4m3 codes) passes ``k_scale``/
    ``v_scale`` — (N, ps, Hkv) fp32 per-(token, head) scales stored
    beside the pages (see ``serving.quant``).  The Pallas path
    dequantizes inside the kernel (scales ride the same table-routed
    BlockSpec path as their pages); the ref path dequantizes the pool
    before its gather — same math, the tolerance oracle.
    ``pages_per_tile`` statically packs several pages per kernel grid
    step (fp32 output bitwise-independent of the tile size).

    Backends: "pallas" runs the block-table-prefetching kernel (the
    production TPU path: the table lookup happens in the BlockSpec index
    map, so only live pages are ever DMA'd); "ref"/fallback gathers
    (S, P*ps) page rows with one ``jnp.take`` and reduces to
    ``mixed_attention`` — the oracle, and the XLA-fused CPU path.
    """
    t, hq, d = q.shape
    n_pages, ps, hkv, _ = k_pages.shape
    s, p = tables.shape
    scale = scale if scale is not None else d ** -0.5

    # auto: take the kernel only when head_dim is lane-aligned — for
    # d % 128 != 0 the wrapper would lane-pad (copy) the ENTIRE page
    # pool per layer per step, costing more than the gather it saves
    if backend == "pallas" or (backend == "auto" and d % 128 == 0):
        try:
            from ..kernels import ops as kops
            return kops.paged_attention(q, k_pages, v_pages, tables,
                                        seg_ids, positions, scale=scale,
                                        window=window, k_scale=k_scale,
                                        v_scale=v_scale,
                                        pages_per_tile=pages_per_tile)
        except Exception:
            if backend == "pallas":
                raise

    if k_scale is not None:
        # ref dequant: codes × scales materialize an fp32 pool view
        # (oracle/CPU path only — the kernel path never does this)
        k_pages = (k_pages.astype(jnp.float32)
                   * k_scale[..., None]).astype(q.dtype)
        v_pages = (v_pages.astype(jnp.float32)
                   * v_scale[..., None]).astype(q.dtype)
    gidx = (tables[:, :, None] * ps
            + jnp.arange(ps)[None, None, :]).reshape(s, p * ps)
    return _paged_attention_ref(q, k_pages, v_pages, gidx, seg_ids,
                                positions, scale=scale, window=window,
                                backend=backend)


def _paged_attention_ref(q, k_pages, v_pages, gidx, seg_ids, positions,
                         *, scale, window, backend):
    t, hq, d = q.shape
    n_pages, ps, hkv, _ = k_pages.shape
    kf = k_pages.reshape(n_pages * ps, hkv, d)
    vf = v_pages.reshape(n_pages * ps, hkv, d)
    k_cache = jnp.take(kf, gidx, axis=0).transpose(0, 2, 1, 3)
    v_cache = jnp.take(vf, gidx, axis=0).transpose(0, 2, 1, 3)
    # keep the caller's backend: under "auto" with a non-lane-aligned
    # head_dim the gather feeds the Pallas mixed_attention kernel —
    # exactly the pre-paged executor path
    return mixed_attention(q, k_cache, v_cache, seg_ids, positions,
                           scale=scale, window=window, backend=backend)


def select_paged_backend(requested: str, *, sharded: bool) -> str:
    """Kernel-vs-ref selection for the paged executor.

    The Pallas paged-attention kernel prefetches block-table SCALARS to
    resolve slot→page inside its BlockSpec index map — a whole-array,
    single-device view.  Under a vmapped replica axis or a GSPMD mesh
    the kernel would see a SHARD of the page pool with global table ids
    (and pallas_call batching over the scalar-prefetch grid is not
    supported), so sharded execution pins the jnp reference path; GSPMD
    partitions its gather + softmax like any other XLA op.  Single
    replica on one device keeps whatever the caller asked for."""
    return requested if not sharded else "ref"


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     backend: str = "auto") -> jnp.ndarray:
    """Single-position decode: q (B, Hq, 1, D) against a (B, Hkv, Smax, D)
    cache filled up to ``cache_len`` (int or (B,) array)."""
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    smax = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5

    if backend in ("auto", "pallas"):
        try:
            from ..kernels import ops as kops
            return kops.decode_attention(q, k_cache, v_cache, cache_len,
                                         scale=scale, window=window)
        except Exception:
            if backend == "pallas":
                raise

    k = repeat_kv(k_cache, hq // hkv)
    v = repeat_kv(v_cache, hq // hkv)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(smax)[None, None, None, :]
    clen = jnp.asarray(cache_len)
    clen = jnp.broadcast_to(clen.reshape(-1), (b,)).reshape(b, 1, 1, 1)
    valid = pos < clen
    lo = (clen - window) if window is not None else None
    if lo is not None:
        valid = valid & (pos >= jnp.maximum(lo, 0))
    logits = jnp.where(valid, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
