"""Configurable LM covering all assigned architecture families.

A model is a *pattern* of heterogeneous blocks (attention / sliding-window
attention / MLA / Mamba / RWKV6 mixers × dense / MoE / none FFNs) repeated
``n_groups`` times (+ an unrolled tail when the pattern doesn't divide
n_layers).  Per-group parameters are stacked on a leading axis and the
forward pass ``lax.scan``s over groups — compact HLO, O(pattern) compile
cost instead of O(n_layers), and remat applies per group.

Two entry points per model:
  * ``forward(params, batch)``      — full-sequence (train / prefill)
  * ``decode_step(params, cache, tokens, pos)`` — single-token serving step
    against a mutable-cache pytree (attention KV, sliding ring-buffers,
    Mamba conv/ssm state, RWKV wkv state).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from ..distributed import act_sharding as AS

Params = Dict[str, Any]


@dataclass(frozen=True)
class BlockSpec:
    mixer: str = "attn"          # attn | sliding | mla | mamba | rwkv
    ffn: str = "dense"           # dense | moe | none


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    # attention
    causal: bool = True
    window: Optional[int] = None          # for "sliding" mixers
    rope_theta: Optional[float] = 10000.0
    rope_theta_local: Optional[float] = None  # sliding layers (gemma3)
    qkv_bias: bool = False
    qk_norm: bool = False
    query_scale: Optional[float] = None   # e.g. gemma uses head_dim**-0.5
    # MoE
    n_experts: int = 0
    n_experts_padded: Optional[int] = None   # pad expert SLOTS (dead,
                                             # -inf router) for EP
                                             # divisibility
    top_k: int = 2
    n_shared_experts: int = 0
    d_ff_shared: Optional[int] = None
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False      # arctic: dense FFN in parallel
    d_ff_dense_residual: Optional[int] = None
    # MLA (MiniCPM3 / DeepSeek-V2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    mla_nope_dim: int = 0
    mla_rope_dim: int = 0
    mla_v_dim: int = 0
    # mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # rwkv
    rwkv_head_dim: int = 64
    # misc
    act: str = "silu"
    gated_mlp: bool = True                # False: plain 2-matrix FFN
    norm: str = "rms"                     # rms | layer
    norm_offset: float = 0.0              # 1.0 for gemma (1+w)
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False             # gemma: x *= sqrt(d_model)
    final_softcap: Optional[float] = None
    input_mode: str = "tokens"            # tokens | embeddings
    lm_head: bool = True                  # False → encoder (hubert)
    n_classes: Optional[int] = None       # encoder classification head
    param_dtype: Any = jnp.bfloat16
    remat: str = "full"                   # none | full
    unroll_groups: bool = False           # True: Python loop (exact
                                          # cost_analysis; scan counts the
                                          # body once) — dry-run cost pass
    attn_backend: str = "auto"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> Tuple[BlockSpec, ...]:
        rem = self.n_layers % len(self.pattern)
        return self.pattern[:rem]

    def active_params_per_token_factor(self) -> float:
        """Fraction of MoE FFN params active per token (for 6·N_active·D)."""
        if self.n_experts == 0:
            return 1.0
        return self.top_k / self.n_experts


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def _norm_init(cfg: LMConfig):
    if cfg.norm_offset:
        return jnp.zeros((cfg.d_model,), jnp.float32)
    return jnp.ones((cfg.d_model,), jnp.float32)


def _block_init(key, cfg: LMConfig, spec: BlockSpec) -> Params:
    kmix, kffn, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": _norm_init(cfg)}
    if cfg.norm == "layer":
        p["norm1_b"] = jnp.zeros((cfg.d_model,), jnp.float32)

    if spec.mixer in ("attn", "sliding"):
        p["attn"] = L.attn_init(kmix, cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.hd, cfg.param_dtype,
                                qkv_bias=cfg.qkv_bias)
        if cfg.qk_norm:
            p["attn"]["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
            p["attn"]["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    elif spec.mixer == "mla":
        p["attn"] = L.mla_init(
            kmix, cfg.d_model, cfg.n_heads, q_lora_rank=cfg.q_lora_rank,
            kv_lora_rank=cfg.kv_lora_rank, nope_dim=cfg.mla_nope_dim,
            rope_dim=cfg.mla_rope_dim, v_dim=cfg.mla_v_dim,
            dtype=cfg.param_dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = L.mamba_init(
            kmix, cfg.d_model, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, expand=cfg.mamba_expand,
            dtype=cfg.param_dtype)
    elif spec.mixer == "rwkv":
        p["rwkv"] = L.rwkv6_init(kmix, cfg.d_model,
                                 head_dim=cfg.rwkv_head_dim,
                                 dtype=cfg.param_dtype)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != "none":
        p["norm2"] = _norm_init(cfg)
        if cfg.norm == "layer":
            p["norm2_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if spec.ffn == "dense":
        p["mlp"] = L.mlp_init(kffn, cfg.d_model, cfg.d_ff, cfg.param_dtype,
                              gated=cfg.gated_mlp)
    elif spec.ffn == "moe":
        p["moe"] = L.moe_init(
            kffn, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.param_dtype,
            gated=True, n_shared=cfg.n_shared_experts,
            d_ff_shared=cfg.d_ff_shared,
            n_padded=cfg.n_experts_padded)
        if cfg.moe_dense_residual:
            p["mlp"] = L.mlp_init(
                k3, cfg.d_model,
                cfg.d_ff_dense_residual or cfg.d_ff, cfg.param_dtype,
                gated=True)

    return p


def init_params(cfg: LMConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 4)
    params: Params = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                              cfg.param_dtype),
        "final_norm": _norm_init(cfg),
    }
    if cfg.norm == "layer":
        params["final_norm_b"] = jnp.zeros((cfg.d_model,), jnp.float32)

    # stacked group params: vmap init over group index
    if cfg.n_groups > 0:
        gkeys = jax.random.split(keys[1], cfg.n_groups)

        def one_group(k):
            pkeys = jax.random.split(k, len(cfg.pattern))
            return [
                _block_init(pk, cfg, spec)
                for pk, spec in zip(pkeys, cfg.pattern)
            ]

        params["groups"] = jax.vmap(one_group)(gkeys)
    if cfg.tail:
        tkeys = jax.random.split(keys[2], len(cfg.tail))
        params["tail"] = [
            _block_init(tk, cfg, spec)
            for tk, spec in zip(tkeys, cfg.tail)
        ]
    if cfg.lm_head and not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[3], cfg.d_model,
                                         cfg.vocab_size, cfg.param_dtype)
    if not cfg.lm_head and cfg.n_classes:
        params["cls_head"] = L.dense_init(keys[3], cfg.d_model,
                                          cfg.n_classes, cfg.param_dtype)
    return params


def abstract_params(cfg: LMConfig) -> Params:
    """Shape-only params (no allocation) for the multi-pod dry-run."""
    return jax.eval_shape(partial(init_params, cfg),
                          jax.random.key(0))


# ----------------------------------------------------------------------
# block application
# ----------------------------------------------------------------------

def _norm(cfg: LMConfig, x, w, b=None):
    if cfg.norm == "layer":
        return L.layer_norm(x, w, b, cfg.norm_eps)
    return L.rms_norm(x, w, cfg.norm_eps, cfg.norm_offset)


def _apply_block(cfg: LMConfig, spec: BlockSpec, p: Params, x, aux,
                 cache: Optional[Params] = None, cache_pos=None):
    """cache_pos: absolute position (scalar) in decode.  Sliding layers
    translate it to a ring-buffer slot internally."""
    h = _norm(cfg, x, p["norm1"], p.get("norm1_b"))
    new_cache = None

    if spec.mixer in ("attn", "sliding"):
        sliding = spec.mixer == "sliding"
        window = cfg.window if sliding else None
        theta = (cfg.rope_theta_local
                 if (sliding and cfg.rope_theta_local) else cfg.rope_theta)
        write_pos, cache_len, dec_window = cache_pos, None, window
        if cache is not None and sliding:
            ring = cache["k"].shape[2]
            write_pos = jnp.mod(cache_pos, ring)
            cache_len = jnp.minimum(cache_pos + h.shape[1], ring)
            dec_window = None  # the ring IS the window
        out, new_cache = L.attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, causal=cfg.causal, window=dec_window,
            rope_theta=theta, query_scale=cfg.query_scale,
            cache=cache, cache_pos=write_pos, cache_len=cache_len,
            abs_pos_arg=cache_pos, q_norm=cfg.qk_norm,
            backend=cfg.attn_backend)
    elif spec.mixer == "mla":
        out, new_cache = L.mla_attention(
            p["attn"], h, n_heads=cfg.n_heads, nope_dim=cfg.mla_nope_dim,
            rope_dim=cfg.mla_rope_dim, v_dim=cfg.mla_v_dim,
            kv_lora_rank=cfg.kv_lora_rank, causal=cfg.causal,
            rope_theta=cfg.rope_theta, cache=cache, cache_pos=cache_pos,
            backend=cfg.attn_backend)
    elif spec.mixer == "mamba":
        out, new_cache = L.mamba(
            p["mamba"], h, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, expand=cfg.mamba_expand, cache=cache,
            backend=cfg.attn_backend)
    elif spec.mixer == "rwkv":
        out, new_cache = L.rwkv6(p["rwkv"], h, head_dim=cfg.rwkv_head_dim,
                                 cache=cache, backend=cfg.attn_backend)
    x = x + out

    if spec.ffn != "none":
        h2 = _norm(cfg, x, p["norm2"], p.get("norm2_b"))
        if spec.ffn == "dense":
            x = x + L.mlp(p["mlp"], h2, cfg.act)
        else:
            # decode is DROPLESS (capacity = full token count): capacity
            # dropping is a training-throughput trade-off, not a serving
            # semantic
            cf = (cfg.capacity_factor if cache is None
                  else float(cfg.n_experts) / cfg.top_k)
            moe_out, moe_aux = L.moe(
                p["moe"], h2, top_k=cfg.top_k, n_experts=cfg.n_experts,
                capacity_factor=cf, activation=cfg.act,
                n_padded=cfg.n_experts_padded)
            if cfg.moe_dense_residual:
                moe_out = moe_out + L.mlp(p["mlp"], h2, cfg.act)
            x = x + moe_out
            aux = aux + moe_aux
    return x, aux, new_cache


# ----------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------

def forward(cfg: LMConfig, params: Params, tokens=None, embeds=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss).  ``tokens``: (B, S) int32 — or pass
    precomputed ``embeds`` (B, S, D) for embedding-mode archs."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds.astype(cfg.param_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = AS.constrain(x, "btd")

    aux0 = jnp.zeros((), jnp.float32)

    def group_body(carry, gp):
        x, aux = carry
        for j, spec in enumerate(cfg.pattern):
            x, aux, _ = _apply_block(cfg, spec, gp[j], x, aux)
            x = AS.constrain(x, "btd")
        return (x, aux), None

    body = group_body
    if cfg.remat == "full":
        body = jax.checkpoint(group_body, prevent_cse=False)

    x_aux = (x, aux0)
    if cfg.n_groups > 0:
        if cfg.unroll_groups:
            for gi in range(cfg.n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[gi],
                                            params["groups"])
                x_aux, _ = body(x_aux, gp)
        else:
            x_aux, _ = jax.lax.scan(body, x_aux, params["groups"])
    x, aux = x_aux
    for j, spec in enumerate(cfg.tail):
        x, aux, _ = _apply_block(cfg, spec, params["tail"][j], x, aux)

    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))

    if not cfg.lm_head:
        if cfg.n_classes:
            return x @ params["cls_head"], aux
        return x, aux

    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    logits = AS.constrain(logits, "logits")
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
    return logits, aux


# ----------------------------------------------------------------------
# KV / state cache
# ----------------------------------------------------------------------

def _block_cache(cfg: LMConfig, spec: BlockSpec, batch: int, max_seq: int,
                 dtype) -> Optional[Params]:
    if spec.mixer == "attn":
        shape = (batch, cfg.n_kv_heads, max_seq, cfg.hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if spec.mixer == "sliding":
        s = min(max_seq, cfg.window or max_seq)
        shape = (batch, cfg.n_kv_heads, s, cfg.hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if spec.mixer == "mla":
        return {
            "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, 1, max_seq, cfg.mla_rope_dim),
                                dtype),
        }
    if spec.mixer == "mamba":
        d_inner = cfg.mamba_expand * cfg.d_model
        return {
            "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_inner), dtype),
            "ssm": jnp.zeros((batch, d_inner, cfg.mamba_d_state),
                             jnp.float32),
        }
    if spec.mixer == "rwkv":
        n_heads = cfg.d_model // cfg.rwkv_head_dim
        return {
            "wkv": jnp.zeros((batch, n_heads, cfg.rwkv_head_dim,
                              cfg.rwkv_head_dim), jnp.float32),
            "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "cm_shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        }
    raise ValueError(spec.mixer)


def init_cache(cfg: LMConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Params:
    cache: Params = {}
    if cfg.n_groups > 0:
        def stack(tree_fn):
            trees = [tree_fn() for _ in range(cfg.n_groups)]
            return jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *trees)

        cache["groups"] = [
            stack(lambda spec=spec: _block_cache(cfg, spec, batch, max_seq,
                                                 dtype))
            for spec in cfg.pattern
        ]
    if cfg.tail:
        cache["tail"] = [
            _block_cache(cfg, spec, batch, max_seq, dtype)
            for spec in cfg.tail
        ]
    return cache


def abstract_cache(cfg: LMConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> Params:
    return jax.eval_shape(partial(init_cache, cfg, batch, max_seq, dtype))


# ----------------------------------------------------------------------
# decode step (serving)
# ----------------------------------------------------------------------

def decode_step(cfg: LMConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos) -> Tuple[jnp.ndarray, Params]:
    """One serving step: ``tokens`` (B, 1) int32, ``pos`` scalar int32 (the
    write position, == number of tokens already in cache).  Returns
    (logits (B, 1, V), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    aux = jnp.zeros((), jnp.float32)

    new_cache: Params = {}
    if cfg.n_groups > 0:
        def group_body(carry, scanned):
            x, aux = carry
            gp, gc = scanned
            new_gc = []
            for j, spec in enumerate(cfg.pattern):
                x, aux, nc = _apply_block(cfg, spec, gp[j], x, aux,
                                          cache=gc[j], cache_pos=pos)
                new_gc.append(nc)
            return (x, aux), new_gc

        if cfg.unroll_groups:
            outs = []
            carry = (x, aux)
            for gi in range(cfg.n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[gi],
                                            params["groups"])
                gc = jax.tree_util.tree_map(lambda a: a[gi],
                                            cache["groups"])
                carry, nc = group_body(carry, (gp, gc))
                outs.append(nc)
            (x, aux) = carry
            new_cache["groups"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            (x, aux), new_groups = jax.lax.scan(
                group_body, (x, aux), (params["groups"], cache["groups"]))
            new_cache["groups"] = new_groups
    if cfg.tail:
        new_cache["tail"] = []
        for j, spec in enumerate(cfg.tail):
            x, aux, nc = _apply_block(cfg, spec, params["tail"][j], x, aux,
                                      cache=cache["tail"][j], cache_pos=pos)
            new_cache["tail"].append(nc)

    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
    return logits, new_cache


# ----------------------------------------------------------------------
# losses / steps (pure; launch.train wires them into pjit)
# ----------------------------------------------------------------------

def lm_loss(cfg: LMConfig, params: Params, batch: Dict[str, jnp.ndarray],
            z_loss: float = 1e-4) -> jnp.ndarray:
    logits, aux = forward(cfg, params,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    # vocab-sharded-safe CE: reductions over the (possibly model-sharded)
    # vocab axis partition cleanly; no take_along_axis gather.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = (labels[..., None] ==
              jnp.arange(logits.shape[-1], dtype=labels.dtype))
    picked = jnp.sum(shifted * onehot, axis=-1) + m[..., 0]
    nll = logz - picked
    mask = batch.get("mask")
    if mask is None:
        loss = nll.mean()
        zl = jnp.square(logz).mean()
    else:
        denom = jnp.maximum(mask.sum(), 1)
        loss = (nll * mask).sum() / denom
        zl = (jnp.square(logz) * mask).sum() / denom
    return loss + z_loss * zl + aux
