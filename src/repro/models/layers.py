"""Functional LM building blocks (pure jnp: params are dict pytrees).

These power the 10 assigned architectures.  Everything here is pure
``f(params, x) -> y`` so it jits, pjits, vmaps, and differentiates through
JAX AD; the eager Module world wraps the same math where needed.

Param layout conventions:
  * linear weights are stored (in, out) — column-parallel friendly,
  * per-layer-group params are STACKED on a leading axis and the model
    scans over groups (compact HLO, fast multi-pod compile),
  * dtype: ``param_dtype`` for weights, f32 for norm/router stats.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from ..distributed import act_sharding as AS

Params = Dict[str, Any]

# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            * 0.02).astype(dtype)


# ----------------------------------------------------------------------
# norms (f32 statistics)
# ----------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             offset: float = 0.0) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (offset + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * weight + bias).astype(x.dtype)


# ----------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, H, S, D), positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if angles.ndim == 2:                              # (S, D/2)
        angles = angles[None, None]                   # (1,1,S,D/2)
    else:                                             # (B, S, D/2)
        angles = angles[:, None]                      # (B,1,S,D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention (GQA / MQA / sliding window) with optional KV cache
# ----------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, dtype, qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def attention(p: Params, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int,
              head_dim: int, causal: bool = True,
              window: Optional[int] = None,
              rope_theta: Optional[float] = 10000.0,
              positions: Optional[jnp.ndarray] = None,
              query_scale: Optional[float] = None,
              cache: Optional[Params] = None,
              cache_pos=None,
              cache_len=None,
              abs_pos_arg=None,
              q_norm: bool = False,
              backend: str = "auto") -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, S, D).  With ``cache`` given, performs decode: writes K/V at
    slot ``cache_pos`` and attends over ``cache_len`` valid slots (ring
    buffers pass cache_pos = pos % ring and cache_len = min(pos+1, ring);
    keys are stored pre-roped at absolute positions so slot order is
    irrelevant to the softmax)."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    q = AS.constrain(q, "bhsd", heads=n_heads)
    k = AS.constrain(k, "bhsd", heads=n_kv_heads)
    v = AS.constrain(v, "bhsd", heads=n_kv_heads)

    if positions is None:
        if cache is not None and cache_pos is not None:
            abs_pos = cache_pos if abs_pos_arg is None else abs_pos_arg
            positions = (jnp.asarray(abs_pos).reshape(-1)[None]
                         + jnp.arange(s)[None, :]).astype(jnp.int32)
            if positions.shape[0] == 1 and b > 1:
                positions = jnp.broadcast_to(positions, (b, s))
        else:
            positions = jnp.arange(s)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if q_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    scale = query_scale if query_scale is not None else head_dim ** -0.5

    if cache is None:
        out = A.sdpa(q, k, v, is_causal=causal, window=window, scale=scale,
                     backend=backend)
        out = AS.constrain(out, "bhsd", heads=n_heads)
        new_cache = None
    else:
        # decode: scatter new K/V into the ring/linear cache then attend
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype),
            (0, 0, jnp.asarray(cache_pos, jnp.int32), 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype),
            (0, 0, jnp.asarray(cache_pos, jnp.int32), 0))
        clen = (jnp.asarray(cache_pos) + s if cache_len is None
                else jnp.asarray(cache_len))
        out = A.decode_attention(q, k_cache, v_cache, cache_len=clen,
                                 scale=scale, window=window, backend=backend)
        new_cache = {"k": k_cache, "v": v_cache}

    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return out @ p["wo"], new_cache


# ----------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ----------------------------------------------------------------------

def mla_init(key, d_model: int, n_heads: int, *, q_lora_rank: int,
             kv_lora_rank: int, nope_dim: int, rope_dim: int, v_dim: int,
             dtype) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], d_model, q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], q_lora_rank,
                           n_heads * (nope_dim + rope_dim), dtype),
        "wkv_a": dense_init(ks[2], d_model, kv_lora_rank + rope_dim, dtype),
        "wkv_b": dense_init(ks[3], kv_lora_rank,
                            n_heads * (nope_dim + v_dim), dtype),
        "q_norm": jnp.ones((q_lora_rank,), jnp.float32),
        "kv_norm": jnp.ones((kv_lora_rank,), jnp.float32),
        "wo": dense_init(ks[4], n_heads * v_dim, d_model, dtype),
    }


def mla_attention(p: Params, x: jnp.ndarray, *, n_heads: int,
                  nope_dim: int, rope_dim: int, v_dim: int,
                  kv_lora_rank: int, causal: bool = True,
                  rope_theta: float = 10000.0,
                  cache: Optional[Params] = None, cache_pos=None,
                  backend: str = "auto") -> Tuple[jnp.ndarray, Optional[Params]]:
    """Latent-compressed attention.  The decode cache stores ONLY the
    latent c_kv (kv_lora_rank) + shared rope key (rope_dim) per token —
    the memory win that defines MLA."""
    b, s, _ = x.shape
    qd = nope_dim + rope_dim

    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, s, n_heads, qd).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]

    kv_a = x @ p["wkv_a"]                        # (B,S,rank+rope)
    c_kv = rms_norm(kv_a[..., :kv_lora_rank], p["kv_norm"])
    k_rope = kv_a[..., kv_lora_rank:]            # shared across heads

    if cache is None:
        positions = jnp.arange(s)
    else:
        positions = jnp.asarray(cache_pos) + jnp.arange(s)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    k_rope = apply_rope(k_rope[:, None], positions, rope_theta)  # (B,1,S,r)

    if cache is not None:
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
            (0, jnp.asarray(cache_pos, jnp.int32), 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, 0, jnp.asarray(cache_pos, jnp.int32), 0))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        kv_len = c_kv.shape[1]
    else:
        new_cache = None
        kv_len = s

    # expand latent to per-head K_nope and V
    kv = (c_kv @ p["wkv_b"]).reshape(b, kv_len, n_heads, nope_dim + v_dim)
    k_nope = kv[..., :nope_dim].transpose(0, 2, 1, 3)
    v = kv[..., nope_dim:].transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, n_heads, kv_len, rope_dim))],
        axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = qd ** -0.5

    if cache is None:
        out = A.sdpa(qfull, k, v, is_causal=causal, scale=scale,
                     backend=backend)
    else:
        out = A.decode_attention(qfull, k, v,
                                 cache_len=jnp.asarray(cache_pos) + s,
                                 scale=scale, backend="ref")
    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * v_dim)
    return out @ p["wo"], new_cache


# ----------------------------------------------------------------------
# FFN: dense GLU variants
# ----------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype,
             gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    up = x @ p["w_up"]
    if "w_gate" in p:
        gate = x @ p["w_gate"]
        h = _act(gate, activation) * up
    else:
        h = _act(up, activation)
    h = AS.constrain(h, "btf")
    return h @ p["w_down"]


def _act(x: jnp.ndarray, name: str) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


# ----------------------------------------------------------------------
# MoE: GShard-style capacity dispatch (EP-shardable over the expert axis)
# ----------------------------------------------------------------------

def moe_init(key, d_model: int, d_ff: int, n_experts: int, dtype,
             gated: bool = True, n_shared: int = 0,
             d_ff_shared: Optional[int] = None,
             n_padded: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 5)
    n_slots = n_padded or n_experts   # padded slots never receive tokens
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (n_slots, d_model, d_ff),
                                   jnp.float32)
                 / math.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (n_slots, d_ff, d_model),
                                     jnp.float32)
                   / math.sqrt(d_ff)).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (n_slots, d_model, d_ff),
                                         jnp.float32)
                       / math.sqrt(d_model)).astype(dtype)
    if n_shared:
        p["shared"] = mlp_init(ks[4], d_model,
                               d_ff_shared or (d_ff * n_shared), dtype,
                               gated=gated)
    return p


def moe(p: Params, x: jnp.ndarray, *, top_k: int, n_experts: int,
        capacity_factor: float = 1.25, activation: str = "silu",
        aux_loss_weight: float = 0.01,
        n_token_groups: Optional[int] = None,
        n_padded: Optional[int] = None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style grouped token-choice top-k with per-group capacity.

    Tokens are split into G groups (G = the data-parallel degree when a
    sharding scope is active, so each group is device-local); every group
    computes its own top-k + capacity C = cf·Tg·k/E.  The dispatch tensor
    is (G, Tg, E, C) — O(Tg·E·C) per group instead of the O(T²·E) a
    global-capacity formulation would need — and shards G over the batch
    axes, E over the model axis (EP).  Returns (output, aux_loss).
    """
    b, s, d = x.shape
    t = b * s
    if n_token_groups is None:
        scope = AS._get()
        ds = scope.data_size if scope is not None else 1
        # Group-size perf rule: the one-hot dispatch einsums cost
        # 2·Tg·(E·C)·D with E·C = cf·k·Tg  →  QUADRATIC in tokens/group.
        # Keep groups near REPRO_MOE_GROUP_TOKENS tokens (default 1024,
        # dispatch ≲ expert compute), rounded to a multiple of the DP
        # degree so groups shard evenly.  Set =0 for the naive
        # one-group-per-DP-shard baseline (§Perf iteration record).
        tgt = int(os.environ.get("REPRO_MOE_GROUP_TOKENS", "1024"))
        if tgt > 0 and t > tgt:
            n_token_groups = max(ds, (t // tgt) // max(ds, 1) * ds)
        else:
            n_token_groups = ds
    g = max(1, min(n_token_groups, t))
    while t % g:
        g -= 1
    tg = t // g
    xt = x.reshape(g, tg, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    e_slots = n_padded or n_experts
    if e_slots != n_experts:
        # dead expert slots (EP divisibility): never routed to
        probs = jnp.pad(probs, ((0, 0), (0, 0),
                                (0, e_slots - n_experts)))
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * tg * top_k / n_experts))
    capacity = min(capacity, tg)

    # position of each (token, k) within its expert queue (per group)
    onehot = jax.nn.one_hot(gate_idx, e_slots,
                            dtype=jnp.int32)                 # (G,Tg,k,E)
    flat = onehot.reshape(g, tg * top_k, e_slots)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        g, tg, top_k, e_slots)
    pos = (pos_in_expert * onehot).sum(-1)                   # (G, Tg, k)
    kept = pos < capacity

    # dispatch / combine (G, Tg, E, C)
    disp = (jax.nn.one_hot(gate_idx, e_slots, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos, capacity, dtype=x.dtype)[..., None, :]
            * kept[..., None, None].astype(x.dtype))         # (G,Tg,k,E,C)
    dispatch = disp.sum(2)                                   # (G,Tg,E,C)
    combine = (disp * gate_vals[..., None, None].astype(x.dtype)).sum(2)

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xt)   # (G,E,C,D)
    expert_in = AS.constrain(expert_in, "gecd", experts=e_slots)
    up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
        h = _act(gate, activation) * up
    else:
        h = _act(up, activation)
    h = AS.constrain(h, "gecf", experts=e_slots)
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    expert_out = AS.constrain(expert_out, "gecd", experts=e_slots)
    yt = jnp.einsum("gtec,gecd->gtd", combine, expert_out)

    # load-balancing aux loss (Switch):  E * Σ_e f_e · P_e
    density = onehot.sum(2).astype(jnp.float32).mean((0, 1))  # (E,)
    router_prob = probs.mean((0, 1))
    aux = aux_loss_weight * n_experts * jnp.sum(
        density[:n_experts] * router_prob[:n_experts])

    y = yt.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x, activation)
    return y, aux


# ----------------------------------------------------------------------
# Mamba (selective SSM) — Jamba's mixer
# ----------------------------------------------------------------------

def mamba_init(key, d_model: int, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: Optional[int] = None,
               dtype=jnp.bfloat16) -> Params:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                   / math.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (d_inner,)) * 0.1,
                     1e-3, 0.1))).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32),
            (d_inner, d_state))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, d_model, dtype),
        "norm": jnp.ones((d_inner,), jnp.float32),
    }


def _ssm_scan_ref(x, dt, B, C, A, D):
    """Sequential selective scan.  x:(B,S,Di) dt:(B,S,Di) B/C:(B,S,N).
    Returns y:(B,S,Di)."""
    dA = jnp.exp(dt[..., None] * A)                      # (B,S,Di,N)
    dBx = (dt * x)[..., None] * B[:, :, None, :]         # (B,S,Di,N)

    def step(h, inputs):
        dA_t, dBx_t, C_t = inputs
        h = dA_t * h + dBx_t                             # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    b, s, di = x.shape
    n = A.shape[-1]
    h0 = jnp.zeros((b, di, n), jnp.float32)
    xs = (dA.transpose(1, 0, 2, 3).astype(jnp.float32),
          dBx.transpose(1, 0, 2, 3).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return y + x * D.astype(x.dtype)


def mamba(p: Params, x: jnp.ndarray, *, d_state: int = 16, d_conv: int = 4,
          expand: int = 2, dt_rank: Optional[int] = None,
          cache: Optional[Params] = None,
          backend: str = "auto") -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, S, D).  With cache: single-step decode using (conv_state,
    ssm_state)."""
    b, s, d = x.shape
    d_inner = expand * d
    dt_rank = dt_rank or max(1, d // 16)

    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                    # (B,S,Di)

    if cache is None:
        # causal depthwise conv1d along seq
        pad = jnp.pad(xi, ((0, 0), (d_conv - 1, 0), (0, 0)))
        windows = jnp.stack(
            [pad[:, i:i + s] for i in range(d_conv)], axis=-1)  # (B,S,Di,K)
        xc = jnp.einsum("bsdk,kd->bsd", windows,
                        p["conv_w"]) + p["conv_b"]
        new_conv_state = pad[:, -(d_conv - 1):] if d_conv > 1 else None
    else:
        conv_state = cache["conv"]                       # (B, K-1, Di)
        pad = jnp.concatenate([conv_state, xi], axis=1)
        xc = jnp.einsum("bkd,kd->bd", pad[:, -d_conv:],
                        p["conv_w"])[:, None] + p["conv_b"]
        new_conv_state = pad[:, -(d_conv - 1):]
    xc = jax.nn.silu(xc)

    proj = xc @ p["x_proj"]                              # (B,S,R+2N)
    dt = jax.nn.softplus(
        proj[..., :dt_rank] @ p["dt_proj"]
        + p["dt_bias"].astype(x.dtype))                  # (B,S,Di)
    Bm = proj[..., dt_rank:dt_rank + d_state]
    Cm = proj[..., dt_rank + d_state:]
    A = -jnp.exp(p["A_log"])                             # (Di,N)

    if cache is None:
        if backend == "pallas":
            from ..kernels import ops as kops
            y = kops.mamba_scan(xc, dt, Bm, Cm, A, p["D"])
        else:
            y = _ssm_scan_ref(xc, dt, Bm, Cm, A, p["D"])
        new_ssm_state = None
    else:
        h = cache["ssm"]                                 # (B,Di,N) f32
        dA = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)
        dBx = (dt[:, 0] * xc[:, 0]).astype(jnp.float32)[..., None] \
            * Bm[:, 0, None, :].astype(jnp.float32)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h,
                       Cm[:, 0].astype(jnp.float32))[:, None]
        y = y.astype(x.dtype) + xc * p["D"].astype(x.dtype)
        new_ssm_state = h

    y = rms_norm(y, p["norm"])
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if cache is None:
        return out, None
    return out, {"conv": new_conv_state, "ssm": new_ssm_state}


# ----------------------------------------------------------------------
# RWKV-6 ("Finch") — data-dependent decay linear attention
# ----------------------------------------------------------------------

def rwkv6_init(key, d_model: int, *, head_dim: int = 64,
               lora_r: int = 64, dtype=jnp.bfloat16) -> Params:
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 12)
    p = {
        # token-shift interpolation weights (static mu per channel)
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_v": jnp.full((d_model,), 0.5, dtype),
        "mu_w": jnp.full((d_model,), 0.5, dtype),
        "mu_g": jnp.full((d_model,), 0.5, dtype),
        "w_r": dense_init(ks[0], d_model, d_model, dtype),
        "w_k": dense_init(ks[1], d_model, d_model, dtype),
        "w_v": dense_init(ks[2], d_model, d_model, dtype),
        "w_g": dense_init(ks[3], d_model, d_model, dtype),
        "w_o": dense_init(ks[4], d_model, d_model, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(base + lora(x)))
        "decay_base": jnp.full((d_model,), -6.0, jnp.float32),
        "decay_a": dense_init(ks[5], d_model, lora_r, dtype),
        "decay_b": dense_init(ks[6], lora_r, d_model, dtype),
        "bonus": (jax.random.normal(ks[7], (n_heads, head_dim),
                                    jnp.float32) * 0.02),
        "ln_out": jnp.ones((d_model,), jnp.float32),
        # channel-mix (FFN half of the RWKV block)
        "cm_mu_k": jnp.full((d_model,), 0.5, dtype),
        "cm_k": dense_init(ks[8], d_model, int(3.5 * d_model), dtype),
        "cm_v": dense_init(ks[9], int(3.5 * d_model), d_model, dtype),
        "cm_r": dense_init(ks[10], d_model, d_model, dtype),
    }
    return p


def _token_shift(x: jnp.ndarray,
                 prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x[t-1] (zero/`prev` at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv6_ref(r, k, v, w, u):
    """Sequential WKV-6.  r/k/v: (B,H,S,D); w: (B,H,S,D) decays in (0,1);
    u: (H,D) bonus.  Returns (out (B,H,S,D), final state (B,H,D,D))."""
    b, h, s, dd = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                        # (B,H,D)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,Dk,Dv)
        out = jnp.einsum(
            "bhd,bhde->bhe", r_t,
            state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, out

    state0 = jnp.zeros((b, h, dd, dd), jnp.float32)
    seq = (r.transpose(2, 0, 1, 3).astype(jnp.float32),
           k.transpose(2, 0, 1, 3).astype(jnp.float32),
           v.transpose(2, 0, 1, 3).astype(jnp.float32),
           w.transpose(2, 0, 1, 3).astype(jnp.float32))
    state, outs = jax.lax.scan(step, state0, seq)
    return outs.transpose(1, 2, 0, 3).astype(r.dtype), state


def rwkv6(p: Params, x: jnp.ndarray, *, head_dim: int = 64,
          cache: Optional[Params] = None,
          backend: str = "auto") -> Tuple[jnp.ndarray, Optional[Params]]:
    """Time-mix + channel-mix RWKV6 block body (pre-norms applied by the
    caller).  x: (B,S,D)."""
    b, s, d = x.shape
    n_heads = d // head_dim

    prev = cache["shift"] if cache is not None else None
    xs = _token_shift(x, prev)

    def mix(mu):
        return x + (xs - x) * mu

    r = mix(p["mu_r"]) @ p["w_r"]
    k = mix(p["mu_k"]) @ p["w_k"]
    v = mix(p["mu_v"]) @ p["w_v"]
    g = jax.nn.silu(mix(p["mu_g"]) @ p["w_g"])
    # data-dependent decay (Finch): per-token, per-channel
    decay_x = mix(p["mu_w"])
    w_log = p["decay_base"] + (jnp.tanh(decay_x @ p["decay_a"])
                               @ p["decay_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))                         # (B,S,D) in (0,1)

    def heads(t):
        return t.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)

    r_h, k_h, v_h, w_h = heads(r), heads(k), heads(v), heads(
        w.astype(x.dtype))

    state_in = cache["wkv"] if cache is not None else None
    if backend == "pallas" and cache is None:
        from ..kernels import ops as kops
        out, state = kops.rwkv6_scan(r_h, k_h, v_h, w_h, p["bonus"])
    else:
        if state_in is not None:
            # fold initial state: run scan from provided state
            out, state = _wkv6_ref_with_state(r_h, k_h, v_h, w_h,
                                              p["bonus"], state_in)
        else:
            out, state = _wkv6_ref(r_h, k_h, v_h, w_h, p["bonus"])

    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    out = rms_norm(out, p["ln_out"]) * g
    tm_out = out @ p["w_o"]

    # channel mix
    y = x + tm_out
    ys = _token_shift(y, cache["cm_shift"] if cache is not None else None)
    xk = y + (ys - y) * p["cm_mu_k"]
    cm = (jnp.square(jax.nn.relu(xk @ p["cm_k"]))) @ p["cm_v"]
    cm = jax.nn.sigmoid(y @ p["cm_r"]) * cm
    out_final = tm_out + cm  # caller adds residual over x

    if cache is None:
        return out_final, None
    return out_final, {"wkv": state, "shift": x[:, -1:],
                       "cm_shift": y[:, -1:]}


def _wkv6_ref_with_state(r, k, v, w, u, state0):
    b, h, s, dd = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", r_t,
                         state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, out

    seq = (r.transpose(2, 0, 1, 3).astype(jnp.float32),
           k.transpose(2, 0, 1, 3).astype(jnp.float32),
           v.transpose(2, 0, 1, 3).astype(jnp.float32),
           w.transpose(2, 0, 1, 3).astype(jnp.float32))
    state, outs = jax.lax.scan(step, state0.astype(jnp.float32), seq)
    return outs.transpose(1, 2, 0, 3).astype(r.dtype), state
