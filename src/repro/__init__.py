"""TorchFlow (package ``repro``): an imperative-style, high-performance
deep learning framework on JAX — a TPU-native reproduction of
"PyTorch: An Imperative Style, High-Performance Deep Learning Library"
(NeurIPS 2019).

Torch-shaped public API::

    import repro
    x = repro.randn(4, 8, requires_grad=True)
    y = (x @ x.T).sum()
    y.backward()              # define-by-run tape (eager)
    step = repro.compile(fn)  # fused/compiled path (jit bridge)
"""

from .core import *          # noqa: F401,F403  torch-like flat namespace
from .core import allocator, autograd, fuse, stream  # noqa: F401
from .core.tensor import Tensor  # noqa: F401

__version__ = "0.1.0"


def __getattr__(name):
    # lazy subpackage access: repro.nn, repro.optim, repro.data, ...
    import importlib
    if name in ("nn", "optim", "data", "distributed", "models", "kernels",
                "configs", "launch", "serving", "checkpoint", "utils"):
        mod = importlib.import_module(f"repro.{name}")
        globals()[name] = mod
        return mod
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
