"""DistributedDataParallel for the eager Module world (paper §5.4, §7).

"Users can easily implement heavily parallel programs that operate on
independent GPUs but later synchronize gradients using all-reduce style
primitives" — this module packages that pattern the way PyTorch's DDP
does, adapted to JAX collectives:

  * gradient BUCKETING: grads are packed into ~bucket_mb flat buffers in
    reverse parameter order, so all-reduce of early buckets overlaps the
    tail of backward (overlap is realized by async dispatch: each bucket's
    collective is enqueued as soon as it fills, ahead of the host loop),
  * all-reduce via ``shard_map``+``psum`` over the 'data' axis,
  * optional INT8 gradient compression with error feedback (per-bucket
    scale; the residual is fed back next step so compression error does
    not accumulate — standard large-scale trick).

On one device this degrades to a no-op sync (the tests exercise >1 via
``--xla_force_host_platform_device_count``).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.tensor import Tensor
from ..nn.module import Module


def _allreduce_mean(flat: jnp.ndarray, mesh: Mesh, axis: str) -> jnp.ndarray:
    from jax.experimental.shard_map import shard_map

    @functools.partial(
        shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
        check_rep=False)
    def _psum(x):
        return jax.lax.pmean(x, axis_name=axis)

    return _psum(flat)


def _compress_int8(flat: jnp.ndarray, residual: Optional[jnp.ndarray]
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 quantization: returns (q, scale, new_residual
    placeholder-corrected later)."""
    if residual is not None:
        flat = flat + residual
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_residual = flat - deq
    return q, scale, new_residual


class DistributedDataParallel(Module):
    """Wrap an eager module; ``sync_gradients()`` after backward averages
    grads across the data axis with bucketed (optionally compressed)
    all-reduces."""

    def __init__(self, module: Module, mesh: Optional[Mesh] = None,
                 axis: str = "data", bucket_mb: float = 25.0,
                 compress: Optional[str] = None):
        super().__init__()
        self.module = module
        self.mesh = mesh
        self.axis = axis
        self.compress = compress
        self._residuals: Dict[int, jnp.ndarray] = {}
        # buckets in REVERSE parameter order (grads become ready in
        # reverse order during backward — earliest-ready bucket first)
        params = list(module.parameters())[::-1]
        self.buckets: List[List[Tensor]] = []
        cur: List[Tensor] = []
        cur_bytes = 0
        limit = int(bucket_mb * 1e6)
        for p in params:
            cur.append(p)
            cur_bytes += p.size_bytes
            if cur_bytes >= limit:
                self.buckets.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            self.buckets.append(cur)
        self.stats = {"synced_bytes": 0, "compressed_bytes": 0,
                      "num_allreduce": 0}

    def forward(self, *args, **kwargs):
        return self.module(*args, **kwargs)

    def world_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get(self.axis, 1)

    def sync_gradients(self) -> None:
        if self.world_size() <= 1:
            return
        for bi, bucket in enumerate(self.buckets):
            grads = [p.grad for p in bucket]
            if all(g is None for g in grads):
                continue
            flats, shapes = [], []
            for p, g in zip(bucket, grads):
                arr = (g.data if g is not None
                       else jnp.zeros(p.shape, p.dtype))
                flats.append(arr.reshape(-1).astype(jnp.float32))
                shapes.append(p.shape)
            flat = jnp.concatenate(flats) if len(flats) > 1 else flats[0]

            if self.compress == "int8":
                q, scale, residual = _compress_int8(
                    flat / self.world_size(),
                    self._residuals.get(bi))
                summed = _allreduce_mean(q.astype(jnp.float32), self.mesh,
                                         self.axis) * self.world_size()
                flat = summed * scale
                self._residuals[bi] = residual
                self.stats["compressed_bytes"] += int(q.size)
            else:
                flat = _allreduce_mean(flat, self.mesh, self.axis)
            self.stats["synced_bytes"] += int(flat.size * 4)
            self.stats["num_allreduce"] += 1

            offset = 0
            for p, shape in zip(bucket, shapes):
                n = int(np.prod(shape)) if shape else 1
                piece = flat[offset:offset + n].reshape(shape)
                p.grad = Tensor(piece.astype(p.dtype))
                offset += n
