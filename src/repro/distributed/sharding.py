"""Sharding rules: param/cache/batch pytrees → PartitionSpecs.

Strategy (GSPMD; collectives inserted by the SPMD partitioner):

  * batch dims          → ('pod', 'data')            (DP across pods+data)
  * column-parallel w   → (..., 'data', 'model')     (TP out-dim, FSDP in)
  * row-parallel w      → (..., 'model', 'data')     (TP in-dim → psum)
  * experts             → expert axis over 'model' when divisible (EP),
                          otherwise expert-FFN hidden dim over 'model'
  * embeddings          → vocab over 'model' (vocab-parallel logits)
  * norms/scalars/small → replicated
  * KV caches (decode)  → heads over 'model' when divisible, else the
                          SEQUENCE dim over 'model' (context-parallel
                          decode — used by yi-34b/arctic whose 56 heads
                          don't divide TP=16, and by long_500k)

FSDP note: sharding a weight's contracting dim over 'data' combined with
batch-over-'data' is ZeRO-3 in GSPMD form — XLA all-gathers weights
per-layer on use and reduce-scatters gradients.  Optimizer state
automatically inherits these specs (tree-mapped), giving sharded Adam/
Adafactor state.
"""

from __future__ import annotations

import os
import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.lm import LMConfig

Params = Dict[str, Any]


@dataclass(frozen=True)
class AxisRules:
    data: Tuple[str, ...] = ("data",)
    model: str = "model"
    batch: Tuple[str, ...] = ("pod", "data")

    @classmethod
    def for_mesh(cls, mesh: Mesh) -> "AxisRules":
        names = mesh.axis_names
        batch = tuple(a for a in ("pod", "data") if a in names)
        return cls(data=("data",) if "data" in names else (),
                   model="model" if "model" in names else None,
                   batch=batch)


def _divides(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    if axis is None or axis not in mesh.shape:
        return False
    return n % mesh.shape[axis] == 0


def _fsdp_ok(dim: int, mesh: Mesh, rules: AxisRules) -> bool:
    return all(a in mesh.shape for a in rules.data) and rules.data and \
        dim % int(np.prod([mesh.shape[a] for a in rules.data])) == 0


def param_spec(path: str, leaf, cfg: LMConfig, mesh: Mesh,
               rules: AxisRules) -> P:
    """Name-based sharding table.  ``path`` is the '/'-joined pytree path;
    stacked group params have a leading group axis (never sharded)."""
    shape = leaf.shape
    ndim = len(shape)
    mdl = rules.model
    dat = rules.data if rules.data else None

    def lead(spec_tail: Tuple) -> P:
        """Pad spec with Nones for leading stack axes."""
        pad = ndim - len(spec_tail)
        return P(*([None] * pad + list(spec_tail)))

    def col() -> P:  # (..., in, out): FSDP in, TP out
        in_dim, out_dim = shape[-2], shape[-1]
        return lead(((dat if _fsdp_ok(in_dim, mesh, rules) else None),
                     (mdl if _divides(out_dim, mesh, mdl) else None)))

    def row() -> P:  # (..., in, out): TP in, FSDP out
        in_dim, out_dim = shape[-2], shape[-1]
        return lead(((mdl if _divides(in_dim, mesh, mdl) else None),
                     (dat if _fsdp_ok(out_dim, mesh, rules) else None)))

    if ndim <= 1:
        return P(*([None] * ndim))

    # --- embeddings / heads -----------------------------------------
    if re.search(r"(^|/)embed$", path):
        v, d = shape
        return P((mdl if _divides(v, mesh, mdl) else None),
                 (dat if _fsdp_ok(d, mesh, rules) else None))
    if re.search(r"(lm_head|cls_head)$", path):
        return col()

    # --- MoE ----------------------------------------------------------
    if "/moe/" in path:
        if path.endswith("router"):
            return P(*([None] * ndim))
        if path.endswith(("w_up", "w_gate", "w_down")):
            e = shape[-3]
            if _divides(e, mesh, mdl):                 # EP
                return lead((mdl,
                             (dat if _fsdp_ok(shape[-2], mesh, rules)
                              else None),
                             None))
            # non-divisible expert count (qwen 60e): TP the expert-FFN
            # dim over 'model', FSDP d_model over 'data'.  (§Perf qwen
            # iteration 2 tried replicating over 'data' instead — the
            # all-reduce volume did NOT move and HBM regressed; FSDP
            # restored.)
            if path.endswith("w_down"):
                return lead((None,
                             (mdl if _divides(shape[-2], mesh, mdl)
                              else None),
                             (dat if _fsdp_ok(shape[-1], mesh, rules)
                              else None)))
            return lead((None,
                         (dat if _fsdp_ok(shape[-2], mesh, rules)
                          else None),
                         (mdl if _divides(shape[-1], mesh, mdl)
                          else None)))
        # shared expert falls through to mlp rules below

    # --- attention ------------------------------------------------------
    # Non-head-divisible strategies (yi/arctic 56H vs TP=16):
    #   replicate  — attention fully replicated across model ranks
    #   seq-shard  — sequence-parallel residual: attention weights keep
    #                only FSDP (their head-carrying dim UNsharded so the
    #                (B,S,H*hd)→(B,H,S,hd) reshape never crosses shards;
    #                activations carry the model axis on S instead)
    _nondivisible = (mdl is not None
                     and cfg.n_heads % mesh.shape.get(mdl, 1) != 0)
    _no_head_tp = _nondivisible and (
        os.environ.get("REPRO_ATTN_FALLBACK") == "replicate"
        or os.environ.get("REPRO_SEQ_SHARD") == "1")
    if re.search(r"/attn/w[qkv]$", path) or path.endswith(("wq_b", "wkv_b")):
        if _no_head_tp:
            in_dim = shape[-2]
            return lead(((dat if _fsdp_ok(in_dim, mesh, rules) else None),
                         None))
        return col()
    if path.endswith(("/attn/wo", "wo")):
        if _no_head_tp:
            out_dim = shape[-1]
            return lead((None,
                         (dat if _fsdp_ok(out_dim, mesh, rules)
                          else None)))
        return row()
    if path.endswith(("wq_a", "wkv_a")):
        return col()

    # --- dense MLP / shared expert ---------------------------------------
    if path.endswith(("w_up", "w_gate", "cm_k")):
        return col()
    if path.endswith(("w_down", "cm_v")):
        return row()

    # --- mamba -------------------------------------------------------------
    if path.endswith("in_proj"):
        return col()
    if path.endswith("out_proj"):
        return row()
    if path.endswith("x_proj"):
        return lead(((mdl if _divides(shape[-2], mesh, mdl) else None),
                     None))
    if path.endswith("dt_proj"):
        return lead((None,
                     (mdl if _divides(shape[-1], mesh, mdl) else None)))
    if path.endswith("A_log"):
        return lead(((mdl if _divides(shape[-2], mesh, mdl) else None),
                     None))

    # --- rwkv ----------------------------------------------------------------
    if re.search(r"/rwkv/w_[rkvg]$", path) or path.endswith(
            ("decay_a", "cm_r")):
        return col()
    if path.endswith(("/rwkv/w_o", "decay_b")):
        return row()

    return P(*([None] * ndim))


def _tree_paths(tree) -> Any:
    """tree of '/'-joined string paths, matching tree structure."""
    paths = []
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def key_str(k) -> str:
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    for kp, _leaf in flat:
        paths.append("/".join(key_str(k) for k in kp))
    return jax.tree_util.tree_unflatten(treedef, paths)


def param_specs(cfg: LMConfig, params: Params, mesh: Mesh) -> Params:
    rules = AxisRules.for_mesh(mesh)
    paths = _tree_paths(params)
    return jax.tree_util.tree_map(
        lambda p, l: param_spec(p, l, cfg, mesh, rules), paths, params)


def param_shardings(cfg: LMConfig, params: Params, mesh: Mesh) -> Params:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(cfg, params, mesh))


# ----------------------------------------------------------------------
# batch + cache specs
# ----------------------------------------------------------------------

def batch_specs(cfg: LMConfig, batch: Dict[str, Any], mesh: Mesh) -> Dict:
    rules = AxisRules.for_mesh(mesh)
    bt = rules.batch

    def spec(name, leaf):
        nd = len(leaf.shape)
        if name == "pos" or nd == 0:
            return P()
        if leaf.shape[0] == 1:   # long_500k: batch 1 can't shard
            return P(*([None] * nd))
        return P(bt, *([None] * (nd - 1)))

    return {k: spec(k, v) for k, v in batch.items()}


def cache_specs(cfg: LMConfig, cache: Params, mesh: Mesh) -> Params:
    """KV cache sharding for decode: batch over ('pod','data'); heads over
    'model' when divisible, else sequence over 'model' (context-parallel
    decode); mamba/rwkv states shard their channel dim over 'model'."""
    rules = AxisRules.for_mesh(mesh)
    mdl = rules.model
    bt = rules.batch
    paths = _tree_paths(cache)

    def spec(path: str, leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        pad = [None] * (nd - 4) if nd > 4 else []
        batch_dim = shape[nd - 4] if nd >= 4 else (
            shape[nd - 3] if nd >= 3 else None)
        b_ax = bt if (batch_dim is not None and batch_dim > 1
                      and batch_dim % int(np.prod(
                          [mesh.shape[a] for a in bt])) == 0) else None

        if path.endswith(("/k", "/v")):           # (..., B, H, S, D)
            b, h, s, d = shape[-4:]
            if _divides(h, mesh, mdl):
                return P(*pad, b_ax, mdl, None, None)
            if _divides(s, mesh, mdl):
                return P(*pad, b_ax, None, mdl, None)
            return P(*pad, b_ax, None, None, None)
        if path.endswith("c_kv"):                 # (..., B, S, rank)
            b, s, r = shape[-3:]
            return P(*([None] * (nd - 3)), b_ax,
                     (mdl if _divides(s, mesh, mdl) else None), None)
        if path.endswith("k_rope"):               # (..., B, 1, S, r)
            b, _, s, r = shape[-4:]
            return P(*pad, b_ax, None,
                     (mdl if _divides(s, mesh, mdl) else None), None)
        if path.endswith(("/conv", "/ssm")):      # mamba states (.., B, *, Di*)
            ch = shape[-1] if path.endswith("/conv") else shape[-2]
            spec_tail = [b_ax] + [None] * (3 - 1)
            if path.endswith("/ssm"):             # (..., B, Di, N)
                return P(*([None] * (nd - 3)), b_ax,
                         (mdl if _divides(shape[-2], mesh, mdl) else None),
                         None)
            return P(*([None] * (nd - 3)), b_ax, None,
                     (mdl if _divides(shape[-1], mesh, mdl) else None))
        if path.endswith("/wkv"):                 # (..., B, H, D, D)
            return P(*pad, b_ax,
                     (mdl if _divides(shape[-3], mesh, mdl) else None),
                     None, None)
        if path.endswith(("shift", "cm_shift")):  # (..., B, 1, D)
            return P(*([None] * (nd - 3)), b_ax, None,
                     (mdl if _divides(shape[-1], mesh, mdl) else None))
        return P(*([None] * nd))

    return jax.tree_util.tree_map(spec, paths, cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# serving (paged-pool) specs — the (data, model) serving mesh
# ----------------------------------------------------------------------

def serving_rules(mesh: Mesh) -> AxisRules:
    """Serving axis rules: tensor parallel over ``model``, NO FSDP —
    a decode step is memory-bound, so gathering weight shards per layer
    (ZeRO-3) would put an all-gather on the latency path every step.
    Params replicate over ``data``; each data replica serves its own
    slot lanes against its own page range."""
    return AxisRules(
        data=(), batch=(),
        model="model" if "model" in mesh.axis_names else None)


def serving_param_specs(cfg: LMConfig, params: Params, mesh: Mesh
                        ) -> Params:
    """``param_spec`` col/row table under :func:`serving_rules` — the
    SAME head/MLP col/row split training uses, minus the FSDP axis."""
    rules = serving_rules(mesh)
    paths = _tree_paths(params)
    return jax.tree_util.tree_map(
        lambda p, l: param_spec(p, l, cfg, mesh, rules), paths, params)


def serving_param_shardings(cfg: LMConfig, params: Params, mesh: Mesh
                            ) -> Params:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        serving_param_specs(cfg, params, mesh))


def serving_kv_spec(n_kv_heads: int, mesh: Mesh, *,
                    pages_per_replica: int) -> P:
    """Spec for one per-layer page-pool array
    (num_pages_total, page_size, n_kv_heads, head_dim).

    The page axis splits over ``data`` — replica r owns the contiguous
    page range [r*pages_per_replica, (r+1)*pages_per_replica).  The KV
    head axis splits over ``model`` when it divides; when it doesn't
    (GQA head counts vs an awkward tp), fall back to CONTEXT-parallel
    KV: the page (sequence) axis also takes the ``model`` axis, so each
    model rank attends a page subset and GSPMD combines the partials."""
    dat = "data" if "data" in mesh.axis_names else None
    mdl = "model" if "model" in mesh.axis_names else None
    tp = mesh.shape.get("model", 1) if mdl else 1
    if tp > 1 and n_kv_heads % tp == 0:
        return P(dat, None, mdl, None)
    if tp > 1 and pages_per_replica % tp == 0:
        return P((dat, mdl) if dat else mdl, None, None, None)
    return P(dat, None, None, None)


def serving_kv_scale_spec(n_kv_heads: int, mesh: Mesh, *,
                          pages_per_replica: int) -> P:
    """Spec for a quantized pool's per-layer scale array
    (num_pages_total, page_size, n_kv_heads) — the same placement as
    :func:`serving_kv_spec` minus the head_dim axis, so every scale
    row lives on the devices holding its page's codes."""
    spec = serving_kv_spec(n_kv_heads, mesh,
                           pages_per_replica=pages_per_replica)
    return P(*spec[:3])


def serving_mirror_spec(mesh: Mesh) -> P:
    """Block-table mirror (R*S, W): slot rows split over ``data`` —
    replica r's S rows land on its own devices, widths replicate."""
    return P("data" if "data" in mesh.axis_names else None, None)
