"""Activation sharding constraints (logical annotations inside the model).

GSPMD propagates shardings from params/inputs, but for LM-scale tensors a
few explicit anchors prevent catastrophic choices (e.g. all-gathering the
(B, S, vocab) logits).  The model code calls ``constrain(x, kind)``; the
step builders activate a scope describing the mesh.  Outside any scope
(eager mode, smoke tests, single device) it is a no-op.

Kinds:
  btd     — (B, S, D) residual stream           → P(batch, None, None)
  btf     — (B, S, F) ffn hidden                → P(batch, None, model)
  bhsd    — (B, H, S, Dh) attention tensors     → heads over model when
            divisible, else sequence over model (context parallelism)
  logits  — (B, S, V) vocab-sharded             → P(batch, None, model)
  ecd     — (E, C, D) MoE dispatched tokens     → P(model, None, None)
            when E divides, else P(None, None, None)
  ecf     — (E, C, F) MoE expert hidden         → expert or hidden dim
"""

from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

_tls = threading.local()


class _Scope:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.batch = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        self.model = "model" if "model" in mesh.axis_names else None
        self.model_size = mesh.shape.get("model", 1)
        self.data_size = 1
        for a in self.batch:
            self.data_size *= mesh.shape[a]


@contextmanager
def scope(mesh: Optional[Mesh]):
    prev = getattr(_tls, "scope", None)
    _tls.scope = _Scope(mesh) if mesh is not None else None
    try:
        yield
    finally:
        _tls.scope = prev


def _get() -> Optional[_Scope]:
    return getattr(_tls, "scope", None)


def _apply(x, spec: P):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def constrain(x, kind: str, *, heads: Optional[int] = None,
              experts: Optional[int] = None):
    s = _get()
    if s is None or s.model is None:
        return x
    b_ok = x.shape[0] % max(s.data_size, 1) == 0 and x.shape[0] > 1
    batch = s.batch if b_ok else None

    if kind == "btd":
        # REPRO_SEQ_SHARD=1: shard the residual stream's sequence dim
        # over 'model' (Megatron sequence-parallel / context-parallel):
        # all dense matmuls run on S/TP slices, attention gathers K/V.
        if (os.environ.get("REPRO_SEQ_SHARD") == "1"
                and x.shape[1] % s.model_size == 0):
            return _apply(x, P(batch, s.model, None))
        return _apply(x, P(batch, None, None))
    if kind == "btf":
        if (os.environ.get("REPRO_SEQ_SHARD") == "1"
                and x.shape[1] % s.model_size == 0):
            return _apply(x, P(batch, s.model, None))
        f_ok = x.shape[-1] % s.model_size == 0
        return _apply(x, P(batch, None, s.model if f_ok else None))
    if kind == "logits":
        v_ok = x.shape[-1] % s.model_size == 0
        return _apply(x, P(batch, None, s.model if v_ok else None))
    if kind == "bhsd":
        h = heads if heads is not None else x.shape[1]
        if h % s.model_size == 0:
            return _apply(x, P(batch, s.model, None, None))
        # heads don't divide TP: strategy knob (perf hillclimb)
        #   context   — shard the sequence dim over model (ring-like)
        #   replicate — keep attention replicated across model ranks
        strategy = os.environ.get("REPRO_ATTN_FALLBACK", "context")
        if strategy == "context" and x.shape[2] % s.model_size == 0:
            return _apply(x, P(batch, None, s.model, None))
        if strategy == "replicate":
            return _apply(x, P(batch, None, None, None))
        return x
    if kind in ("ecd", "ecf"):
        e = experts if experts is not None else x.shape[0]
        if e % s.model_size == 0:
            return _apply(x, P(s.model, None, None))
        if kind == "ecf" and x.shape[-1] % s.model_size == 0:
            return _apply(x, P(None, None, s.model))
        return x
    if kind in ("gecd", "gecf"):
        e = experts if experts is not None else x.shape[1]
        g_ok = x.shape[0] % max(s.data_size, 1) == 0
        g_ax = s.batch if g_ok else None
        if e % s.model_size == 0:
            return _apply(x, P(g_ax, s.model, None, None))
        if kind == "gecf" and x.shape[-1] % s.model_size == 0:
            return _apply(x, P(g_ax, None, None, s.model))
        return _apply(x, P(g_ax, None, None, None))
    return x


def active() -> bool:
    return _get() is not None
