"""Pipeline parallelism over the 'pod' axis (GPipe-style, shard_map +
collective_permute).

The paper's §7 roadmap — "a Pythonic library for model parallelism" — maps
onto the multi-pod mesh as an OPTIONAL alternative role for the pod axis:
instead of pure DP across pods, stages of the layer stack live on
different pods and microbatches stream through with ``ppermute`` moving
activations stage→stage over DCN.

Schedule: GPipe fill-drain over M microbatches and S stages
(bubble fraction (S-1)/(M+S-1)).  Stage-local compute is whatever block
function the model provides; weights for stage i are sharded to pod i by
construction (leading stage axis over 'pod').
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x: jnp.ndarray, *,
                   mesh: Mesh, n_microbatches: int,
                   axis: str = "pod") -> jnp.ndarray:
    """Run ``x`` through S pipeline stages.

    stage_fn(params_i, x) -> x        (same shape in/out)
    stage_params: pytree with leading stage axis S == mesh.shape[axis]
    x: (B, ...) global batch; B % n_microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches

    p_specs = jax.tree_util.tree_map(lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(p_specs, P()), out_specs=P(),
        check_rep=False)
    def run(params, xs):
        stage = jax.lax.axis_index(axis)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        n_ticks = n_microbatches + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        micro = xs.reshape((n_microbatches, mb) + xs.shape[1:])
        out = jnp.zeros_like(micro)
        carry = jnp.zeros((mb,) + xs.shape[1:], xs.dtype)

        def tick(t, state):
            carry, out = state
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, n_microbatches - 1)
            inject = micro[mb_idx]
            inp = jnp.where(stage == 0, inject, carry)
            y = stage_fn(params, inp)
            # last stage emits microbatch t-(S-1)
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            valid_emit = jnp.logical_and(stage == n_stages - 1,
                                         t >= n_stages - 1)
            out = jax.lax.cond(
                valid_emit,
                lambda o: o.at[emit_idx].set(y),
                lambda o: o,
                out)
            # activations move stage -> stage+1
            carry = jax.lax.ppermute(y, axis, perm)
            return carry, out

        carry, out = jax.lax.fori_loop(0, n_ticks, tick, (carry, out))
        # only the last stage holds real outputs; psum of the masked
        # buffer broadcasts it so the replicated out_spec is truthful
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)),
            axis) if n_stages > 1 else out
        return out.reshape(xs.shape)

    return run(stage_params, x)


def stages_from_groups(params_groups, n_stages: int):
    """Re-slice scan-stacked group params (leading n_groups axis) into
    n_stages contiguous chunks with a leading stage axis."""
    def slice_leaf(a):
        g = a.shape[0]
        assert g % n_stages == 0, (g, n_stages)
        return a.reshape((n_stages, g // n_stages) + a.shape[1:])

    return jax.tree_util.tree_map(slice_leaf, params_groups)
