"""Flash attention forward kernel (Pallas/TPU).

Tiled online-softmax attention à la FlashAttention-2, adapted to the TPU
memory hierarchy: Q tiles live in VMEM for the duration of a KV sweep, the
(block_q, block_k) score tile is produced on the MXU via ``pl.dot`` with
f32 accumulation, and softmax statistics are carried in VMEM scratch across
the sequential KV grid dimension.

Supports: causal masking, sliding-window (local) masking, GQA (the KV
block index map folds the query-head → kv-head mapping), arbitrary
Sq != Skv offsets.  Block-level early-out: fully-masked KV tiles write
nothing and skip the MXU work under ``pl.when``.

Layout notes (TPU): head_dim is padded to a lane multiple (128) by the
wrapper in ``ops.py``; block_q/block_k default to 128/128 which keeps the
working set (q + k + v + scores + acc ≈ 4·128·128·4B + 128·head_dim·12B)
well under the ~16MB VMEM budget up to head_dim=256.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, kv_len: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile
    q_start = qi * block_q + q_offset      # first query's absolute position
    k_start = ki * block_k

    # tile-level skip tests (structural masking)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        # newest key this tile could need: q_pos >= k_pos > q_pos - window
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        v = v_ref[0]
        scores = pl.dot(q, k, trans_b=True,
                        precision=jax.lax.Precision.DEFAULT).astype(
            jnp.float32) * scale                       # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[:, :1]                          # (bq, 1)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new)                    # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)

        acc_scr[...] = acc_scr[...] * alpha + pl.dot(
            p.astype(v.dtype), v).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, scale: float,
                        window: Optional[int],
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (BHq, Sq, D), k/v: (BHkv, Skv, D), with BHq = B*Hq grouped so
    that query head h maps to kv head h // (Hq // Hkv) (done via the
    index map using ``group`` below).  Call through ops.flash_attention.
    """
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    group = bhq // bhkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    q_offset = skv - sq  # queries are the LAST sq positions (prefill)

    grid = (bhq, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=skv, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
