"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These re-export / wrap the reference math that the model layer uses, with
the exact argument conventions of the kernels in ``ops.py``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.attention import decode_attention as _decode_ref
from ..models.attention import paged_attention as _paged_ref
from ..models.attention import sdpa_ref as _sdpa_ref
from ..models.layers import _ssm_scan_ref, _wkv6_ref


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: Optional[float] = None,
                    window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) — GQA broadcast inside."""
    return _sdpa_ref(q, k, v, mask=None, is_causal=causal, scale=scale,
                     window=window)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len,
                     scale: Optional[float] = None,
                     window: Optional[int] = None) -> jnp.ndarray:
    return _decode_ref(q, k_cache, v_cache, cache_len, scale=scale,
                       window=window, backend="ref")


def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, tables: jnp.ndarray,
                    seg_ids: jnp.ndarray, positions: jnp.ndarray,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    k_scale: Optional[jnp.ndarray] = None,
                    v_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (T, Hq, D) vs the physical page pool (N, ps, Hkv, D) via
    (S, P) block tables — gather-then-attend oracle for the Pallas
    block-table-prefetching kernel.  For a quantized pool pass the
    (N, ps, Hkv) fp32 ``k_scale``/``v_scale`` arrays: the oracle
    dequantizes (codes × scales) before gathering, mirroring the
    kernel's in-VMEM dequantization."""
    return _paged_ref(q, k_pages, v_pages, tables, seg_ids, positions,
                      scale=scale, window=window, k_scale=k_scale,
                      v_scale=v_scale, backend="ref")


def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/w: (B, H, S, D); u: (H, D).  Returns (out, final_state)."""
    return _wkv6_ref(r, k, v, w, u)


def mamba_scan(x: jnp.ndarray, dt: jnp.ndarray, B: jnp.ndarray,
               C: jnp.ndarray, A: jnp.ndarray,
               D: jnp.ndarray) -> jnp.ndarray:
    """x/dt: (B, S, Di); B/C: (B, S, N); A: (Di, N); D: (Di,)."""
    return _ssm_scan_ref(x, dt, B, C, A, D)
