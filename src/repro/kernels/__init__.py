"""repro.kernels — Pallas TPU kernels for the architecture hot spots.

  flash_attention  — tiled online-softmax attention (GQA, causal, window)
  decode_attention — flash-decode over a KV cache (scalar-prefetch lengths)
  rwkv6_scan       — WKV6 recurrence with VMEM-resident (D,D) state
  mamba_scan       — selective SSM scan, channel-tiled, VMEM state

Each has a pure-jnp oracle in ``ref.py`` and a jit-ready wrapper in
``ops.py`` (auto-interpret on CPU, custom_vjp backward via the oracle).
"""
from . import ops, ref  # noqa: F401
