"""Mamba selective-scan kernel (Pallas/TPU).

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t x_t) Bᵀ_t        h: (Di, N)
    y_t = h_t C_t + D ⊙ x_t

TPU adaptation (vs. the CUDA kernel of the Mamba paper): the hidden state
is kept TRANSPOSED as (N, Di_block) so the small d_state=16 dimension sits
on sublanes and the large channel dim on the 128-wide lanes; the channel
dimension is tiled over a parallel grid axis and the sequence swept
sequentially in chunks with the state resident in VMEM scratch.  HBM
traffic per step is just the (chunk × block) inputs/outputs — the scan
reference materializes (B, S, Di, N) intermediates for backward.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64
DEFAULT_BLOCK_DI = 512


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr,
                  *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a_t = a_ref[...]                                   # (N, Di_blk)  (Aᵀ)
    d_vec = d_ref[...]                                 # (1, Di_blk)

    def step(t, _):
        x_t = x_ref[0, t].astype(jnp.float32)          # (Di_blk,)
        dt_t = dt_ref[0, t].astype(jnp.float32)        # (Di_blk,)
        b_t = b_ref[0, t].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)          # (N,)
        dA = jnp.exp(dt_t[None, :] * a_t)              # (N, Di_blk)
        dBx = b_t[:, None] * (dt_t * x_t)[None, :]     # (N, Di_blk)
        h = dA * h_scr[...] + dBx
        h_scr[...] = h
        y = jnp.sum(h * c_t[:, None], axis=0)          # (Di_blk,)
        y_ref[0, t] = (y + d_vec[0] * x_t).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


def mamba_scan_fwd(x: jnp.ndarray, dt: jnp.ndarray, B: jnp.ndarray,
                   C: jnp.ndarray, A: jnp.ndarray, D: jnp.ndarray, *,
                   chunk: int = DEFAULT_CHUNK,
                   block_di: int = DEFAULT_BLOCK_DI,
                   interpret: bool = False) -> jnp.ndarray:
    """x/dt: (B, S, Di); B/C: (B, S, N); A: (Di, N); D: (Di,).
    Returns y: (B, S, Di)."""
    bsz, s, di = x.shape
    n = A.shape[-1]
    block_di = min(block_di, di)
    chunk = min(chunk, s)
    ndi = pl.cdiv(di, block_di)
    nc = pl.cdiv(s, chunk)

    a_t = A.T.astype(jnp.float32)                      # (N, Di)
    d_row = D.reshape(1, di).astype(jnp.float32)       # (1, Di)

    kernel = functools.partial(_mamba_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, ndi, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_di),
                         lambda b, di_, ci: (b, ci, di_)),
            pl.BlockSpec((1, chunk, block_di),
                         lambda b, di_, ci: (b, ci, di_)),
            pl.BlockSpec((1, chunk, n), lambda b, di_, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, di_, ci: (b, ci, 0)),
            pl.BlockSpec((n, block_di), lambda b, di_, ci: (0, di_)),
            pl.BlockSpec((1, block_di), lambda b, di_, ci: (0, di_)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_di),
                               lambda b, di_, ci: (b, ci, di_)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, block_di), jnp.float32)],
        interpret=interpret,
        name="mamba_scan_fwd",
    )(x, dt, B, C, a_t, d_row)
