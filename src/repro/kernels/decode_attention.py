"""Decode + mixed-batch + paged attention kernels (Pallas/TPU).

``decode_attention_fwd``: one new query token per sequence attends over a
(B, Hkv, Smax, D) KV cache filled to ``cache_len[b]`` positions.
``mixed_attention_fwd``: a FLAT padded token batch (prefill chunks mixed
with decode tokens — the serving executor's unified step) where token t
selects its sequence's cache row via a scalar-prefetched segment id and
masks keys past its own position.
``paged_attention_fwd``: the same flat mixed batch, but attending the
PHYSICAL KV page pool directly — the block table rides in as a
scalar-prefetch operand and the KV BlockSpec index map resolves
(slot, page-position) -> physical page id before the body runs, so no
contiguous per-slot cache is ever gathered.  TPU adaptation of
flash-decoding:

  * grid = (B, Hkv, Smax/block_k) with the KV sweep as the sequential
    dimension; online-softmax stats live in VMEM scratch,
  * all G = Hq/Hkv query heads of a KV group are processed together as a
    (G, D) tile — the score matmul is (G, D)x(D, block_k), keeping the MXU
    busy even at batch 1,
  * ``cache_len`` is a scalar-prefetch operand (SMEM): block index maps and
    masks read it before the kernel body runs, so out-of-range KV tiles
    are masked with zero MXU waste.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, window: Optional[int], block_k: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[b]
    k_start = ki * block_k
    run = k_start < cache_len
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k > cache_len - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                               # (G, D)
        k = k_ref[0, 0]                               # (bk, D)
        v = v_ref[0, 0]
        scores = pl.dot(q, k, trans_b=True).astype(jnp.float32) * scale

        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1)
        mask = k_pos < cache_len
        if window is not None:
            mask = jnp.logical_and(mask, k_pos >= cache_len - window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + pl.dot(
            p.astype(v.dtype), v).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len: jnp.ndarray, *,
                         scale: float, window: Optional[int] = None,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hkv, G, D) — query heads grouped by their KV head;
    k_cache/v_cache: (B, Hkv, Smax, D); cache_len: (B,) int32.
    Returns (B, Hkv, G, D)."""
    b, hkv, g, d = q.shape
    smax = k_cache.shape[2]
    block_k = min(block_k, smax)
    nk = pl.cdiv(smax, block_k)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=block_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ki, lens: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ki, lens: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
        name="decode_attention_fwd",
    )(jnp.asarray(cache_len, jnp.int32), q, k_cache, v_cache)


def _mixed_kernel(seg_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, window: Optional[int], block_k: int):
    t = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[t]
    k_start = ki * block_k
    # keys at <= pos are live; padding tokens (seg<0) read slot 0 but the
    # caller discards their output
    run = k_start <= pos
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k > pos - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                               # (G, D)
        k = k_ref[0, 0]                               # (bk, D)
        v = v_ref[0, 0]
        scores = pl.dot(q, k, trans_b=True).astype(jnp.float32) * scale

        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1)
        mask = k_pos <= pos
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > pos - window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + pl.dot(
            p.astype(v.dtype), v).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def mixed_attention_fwd(q: jnp.ndarray, k_cache: jnp.ndarray,
                        v_cache: jnp.ndarray, seg_ids: jnp.ndarray,
                        positions: jnp.ndarray, *, scale: float,
                        window: Optional[int] = None,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (T, Hkv, G, D) — per-token query heads grouped by KV head;
    k_cache/v_cache: (S, Hkv, L, D) per-slot contiguous caches;
    seg_ids/positions: (T,) int32 scalar-prefetch operands.  The block
    index map routes each token's KV tiles from ITS slot's cache row —
    the paged-gather analogue of flash-decoding.  Returns (T, Hkv, G, D).
    """
    t, hkv, g, d = q.shape
    smax = k_cache.shape[2]
    block_k = min(block_k, smax)
    nk = pl.cdiv(smax, block_k)
    nslots = k_cache.shape[0]

    kernel = functools.partial(_mixed_kernel, scale=scale, window=window,
                               block_k=block_k)

    def kv_map(ti, h, ki, seg, pos):
        return (jnp.clip(seg[ti], 0, nslots - 1), h, ki, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ti, h, ki, seg, pos: (ti, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ti, h, ki, seg, pos: (ti, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hkv, g, d), q.dtype),
        interpret=interpret,
        name="mixed_attention_fwd",
    )(jnp.asarray(seg_ids, jnp.int32), jnp.asarray(positions, jnp.int32),
      q, k_cache, v_cache)


def _paged_kernel(tbl_ref, seg_ref, pos_ref, q_ref, *refs,
                  scale: float, window: Optional[int], page_size: int,
                  ppt: int, quantized: bool):
    # refs layout (set up by paged_attention_fwd): ppt K page refs,
    # ppt V page refs, [ppt K-scale refs, ppt V-scale refs when
    # quantized], then o_ref and the three VMEM scratch refs.
    k_refs = refs[:ppt]
    v_refs = refs[ppt:2 * ppt]
    if quantized:
        ks_refs = refs[2 * ppt:3 * ppt]
        vs_refs = refs[3 * ppt:4 * ppt]
        o_ref, m_scr, l_scr, acc_scr = refs[4 * ppt:]
    else:
        o_ref, m_scr, l_scr, acc_scr = refs[2 * ppt:]

    t = pl.program_id(0)
    ti_ = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti_ == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[t]
    # the tile packs ppt consecutive pages of token t's sequence; each
    # page j runs the SAME sequential online-softmax update the
    # single-page grid would, in the same order — fp32 outputs are
    # bitwise-equal for any tile size.  Only pages at or before the
    # token's own position hold live keys (causal); a tile page past
    # the table width is index-clamped in the BlockSpec map and its
    # k_start > pos predicate skips the compute.  Padding tokens
    # (seg<0) route to page-table row 0 and the caller discards their
    # output.
    for j in range(ppt):
        k_start = (ti_ * ppt + j) * page_size
        run = k_start <= pos
        if window is not None:
            run = jnp.logical_and(run,
                                  k_start + page_size > pos - window)

        @pl.when(run)
        def _body(j=j, k_start=k_start):
            q = q_ref[0, 0]                           # (G, D)
            k = k_refs[j][0, :, 0]                    # (ps, D)
            v = v_refs[j][0, :, 0]
            if quantized:
                # dequantize IN KERNEL: codes × per-(token, head)
                # scales — the fp32 pool never materializes in HBM
                q = q.astype(jnp.float32)
                k = k.astype(jnp.float32) \
                    * ks_refs[j][0, :, 0][:, None]
                v = v.astype(jnp.float32) \
                    * vs_refs[j][0, :, 0][:, None]
            scores = pl.dot(q, k, trans_b=True).astype(jnp.float32) \
                * scale

            k_pos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, scores.shape, 1)
            mask = k_pos <= pos
            if window is not None:
                mask = jnp.logical_and(mask, k_pos > pos - window)
            scores = jnp.where(mask, scores, NEG_INF)

            m_prev = m_scr[:, :1]
            m_new = jnp.maximum(m_prev,
                                jnp.max(scores, axis=-1, keepdims=True))
            p = jnp.exp(scores - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_scr[...] = jnp.broadcast_to(
                alpha * l_scr[:, :1]
                + jnp.sum(p, axis=-1, keepdims=True),
                l_scr.shape)
            acc_scr[...] = acc_scr[...] * alpha + pl.dot(
                p.astype(v.dtype), v).astype(jnp.float32)
            m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ti_ == nt - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def paged_attention_fwd(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, tables: jnp.ndarray,
                        seg_ids: jnp.ndarray, positions: jnp.ndarray, *,
                        scale: float, window: Optional[int] = None,
                        k_scale: Optional[jnp.ndarray] = None,
                        v_scale: Optional[jnp.ndarray] = None,
                        pages_per_tile: int = 1,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (T, Hkv, G, D) — per-token query heads grouped by KV head;
    k_pages/v_pages: (N, ps, Hkv, D) — the PHYSICAL page pool, not a
    gathered per-slot cache; tables: (S, P) int32 block tables;
    seg_ids/positions: (T,) int32.  All three index operands are
    scalar-prefetched: the KV BlockSpec index map reads
    ``tables[seg_ids[t], pi]`` before the body runs, so each grid step
    DMAs exactly one physical page into VMEM — the gather disappears
    into the memory system.

    Quantized pools pass ``k_scale``/``v_scale``: (N, ps, Hkv) fp32
    per-(token, head) scales.  They ride the SAME table-prefetch
    routing as the pages — their BlockSpecs share the kv index map, so
    the scale row for a page arrives with the page and dequantization
    happens in VMEM, never materializing an fp32 pool.

    ``pages_per_tile`` statically packs several pages into one grid
    step (ppt K refs + ppt V refs resolved per-page in the index maps);
    the kernel unrolls the identical per-page online-softmax update, so
    fp32 outputs are BITWISE-equal across tile sizes while small-page
    configs stop paying per-page grid overhead.  Returns (T, Hkv, G, D).
    """
    t, hkv, g, d = q.shape
    n_pages, ps = k_pages.shape[0], k_pages.shape[1]
    s_slots, p_pages = tables.shape
    ppt = max(1, min(pages_per_tile, p_pages))
    n_tiles = pl.cdiv(p_pages, ppt)
    quantized = k_scale is not None

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               page_size=ps, ppt=ppt,
                               quantized=quantized)

    def page_map(j):
        def kv_map(ti, h, tj, tbl, seg, pos):
            slot = jnp.clip(seg[ti], 0, s_slots - 1)
            # pages past the table width clamp to the last column; the
            # kernel's k_start <= pos predicate masks their compute
            page = jnp.minimum(tj * ppt + j, p_pages - 1)
            return (tbl[slot, page], 0, h, 0)
        return kv_map

    def scale_map(j):
        def sc_map(ti, h, tj, tbl, seg, pos):
            slot = jnp.clip(seg[ti], 0, s_slots - 1)
            page = jnp.minimum(tj * ppt + j, p_pages - 1)
            return (tbl[slot, page], 0, h)
        return sc_map

    in_specs = [pl.BlockSpec((1, 1, g, d),
                             lambda ti, h, tj, tbl, seg, pos:
                             (ti, h, 0, 0))]
    in_specs += [pl.BlockSpec((1, ps, 1, d), page_map(j))
                 for j in range(ppt)]
    in_specs += [pl.BlockSpec((1, ps, 1, d), page_map(j))
                 for j in range(ppt)]
    operands = [q] + [k_pages] * ppt + [v_pages] * ppt
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map(j))
                     for j in range(ppt)]
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map(j))
                     for j in range(ppt)]
        operands += [k_scale] * ppt + [v_scale] * ppt

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t, hkv, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ti, h, tj, tbl, seg, pos:
                               (ti, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hkv, g, d), q.dtype),
        interpret=interpret,
        name="paged_attention_fwd",
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(seg_ids, jnp.int32),
      jnp.asarray(positions, jnp.int32), *operands)
