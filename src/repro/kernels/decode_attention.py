"""Decode + mixed-batch + paged attention kernels (Pallas/TPU).

``decode_attention_fwd``: one new query token per sequence attends over a
(B, Hkv, Smax, D) KV cache filled to ``cache_len[b]`` positions.
``mixed_attention_fwd``: a FLAT padded token batch (prefill chunks mixed
with decode tokens — the serving executor's unified step) where token t
selects its sequence's cache row via a scalar-prefetched segment id and
masks keys past its own position.
``paged_attention_fwd``: the same flat mixed batch, but attending the
PHYSICAL KV page pool directly — the block table rides in as a
scalar-prefetch operand and the KV BlockSpec index map resolves
(slot, page-position) -> physical page id before the body runs, so no
contiguous per-slot cache is ever gathered.  TPU adaptation of
flash-decoding:

  * grid = (B, Hkv, Smax/block_k) with the KV sweep as the sequential
    dimension; online-softmax stats live in VMEM scratch,
  * all G = Hq/Hkv query heads of a KV group are processed together as a
    (G, D) tile — the score matmul is (G, D)x(D, block_k), keeping the MXU
    busy even at batch 1,
  * ``cache_len`` is a scalar-prefetch operand (SMEM): block index maps and
    masks read it before the kernel body runs, so out-of-range KV tiles
    are masked with zero MXU waste.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, window: Optional[int], block_k: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[b]
    k_start = ki * block_k
    run = k_start < cache_len
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k > cache_len - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                               # (G, D)
        k = k_ref[0, 0]                               # (bk, D)
        v = v_ref[0, 0]
        scores = pl.dot(q, k, trans_b=True).astype(jnp.float32) * scale

        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1)
        mask = k_pos < cache_len
        if window is not None:
            mask = jnp.logical_and(mask, k_pos >= cache_len - window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + pl.dot(
            p.astype(v.dtype), v).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len: jnp.ndarray, *,
                         scale: float, window: Optional[int] = None,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hkv, G, D) — query heads grouped by their KV head;
    k_cache/v_cache: (B, Hkv, Smax, D); cache_len: (B,) int32.
    Returns (B, Hkv, G, D)."""
    b, hkv, g, d = q.shape
    smax = k_cache.shape[2]
    block_k = min(block_k, smax)
    nk = pl.cdiv(smax, block_k)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=block_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ki, lens: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ki, lens: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
        name="decode_attention_fwd",
    )(jnp.asarray(cache_len, jnp.int32), q, k_cache, v_cache)


def _mixed_kernel(seg_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, window: Optional[int], block_k: int):
    t = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[t]
    k_start = ki * block_k
    # keys at <= pos are live; padding tokens (seg<0) read slot 0 but the
    # caller discards their output
    run = k_start <= pos
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k > pos - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                               # (G, D)
        k = k_ref[0, 0]                               # (bk, D)
        v = v_ref[0, 0]
        scores = pl.dot(q, k, trans_b=True).astype(jnp.float32) * scale

        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1)
        mask = k_pos <= pos
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > pos - window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + pl.dot(
            p.astype(v.dtype), v).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def mixed_attention_fwd(q: jnp.ndarray, k_cache: jnp.ndarray,
                        v_cache: jnp.ndarray, seg_ids: jnp.ndarray,
                        positions: jnp.ndarray, *, scale: float,
                        window: Optional[int] = None,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (T, Hkv, G, D) — per-token query heads grouped by KV head;
    k_cache/v_cache: (S, Hkv, L, D) per-slot contiguous caches;
    seg_ids/positions: (T,) int32 scalar-prefetch operands.  The block
    index map routes each token's KV tiles from ITS slot's cache row —
    the paged-gather analogue of flash-decoding.  Returns (T, Hkv, G, D).
    """
    t, hkv, g, d = q.shape
    smax = k_cache.shape[2]
    block_k = min(block_k, smax)
    nk = pl.cdiv(smax, block_k)
    nslots = k_cache.shape[0]

    kernel = functools.partial(_mixed_kernel, scale=scale, window=window,
                               block_k=block_k)

    def kv_map(ti, h, ki, seg, pos):
        return (jnp.clip(seg[ti], 0, nslots - 1), h, ki, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ti, h, ki, seg, pos: (ti, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ti, h, ki, seg, pos: (ti, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hkv, g, d), q.dtype),
        interpret=interpret,
        name="mixed_attention_fwd",
    )(jnp.asarray(seg_ids, jnp.int32), jnp.asarray(positions, jnp.int32),
      q, k_cache, v_cache)


def _paged_kernel(tbl_ref, seg_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, window: Optional[int], page_size: int):
    t = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[t]
    k_start = pi * page_size
    # page pi of token t's sequence covers key positions
    # [pi*ps, (pi+1)*ps); only pages at or before the token's own
    # position hold live keys (causal).  Padding tokens (seg<0) route
    # to page-table row 0 and the caller discards their output.
    run = k_start <= pos
    if window is not None:
        run = jnp.logical_and(run, k_start + page_size > pos - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                               # (G, D)
        k = k_ref[0, :, 0]                            # (ps, D)
        v = v_ref[0, :, 0]
        scores = pl.dot(q, k, trans_b=True).astype(jnp.float32) * scale

        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1)
        mask = k_pos <= pos
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > pos - window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + pl.dot(
            p.astype(v.dtype), v).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(pi == np_ - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def paged_attention_fwd(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, tables: jnp.ndarray,
                        seg_ids: jnp.ndarray, positions: jnp.ndarray, *,
                        scale: float, window: Optional[int] = None,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (T, Hkv, G, D) — per-token query heads grouped by KV head;
    k_pages/v_pages: (N, ps, Hkv, D) — the PHYSICAL page pool, not a
    gathered per-slot cache; tables: (S, P) int32 block tables;
    seg_ids/positions: (T,) int32.  All three index operands are
    scalar-prefetched: the KV BlockSpec index map reads
    ``tables[seg_ids[t], pi]`` before the body runs, so each grid step
    DMAs exactly one physical page into VMEM — the gather disappears
    into the memory system.  Returns (T, Hkv, G, D)."""
    t, hkv, g, d = q.shape
    n_pages, ps = k_pages.shape[0], k_pages.shape[1]
    s_slots, p_pages = tables.shape

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               page_size=ps)

    def kv_map(ti, h, pi, tbl, seg, pos):
        slot = jnp.clip(seg[ti], 0, s_slots - 1)
        return (tbl[slot, pi], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t, hkv, p_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ti, h, pi, tbl, seg, pos: (ti, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, d), kv_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ti, h, pi, tbl, seg, pos:
                               (ti, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hkv, g, d), q.dtype),
        interpret=interpret,
        name="paged_attention_fwd",
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(seg_ids, jnp.int32),
      jnp.asarray(positions, jnp.int32), q, k_pages, v_pages)
