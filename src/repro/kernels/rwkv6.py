"""RWKV-6 WKV recurrence kernel (Pallas/TPU).

The WKV6 state is a per-head (D, D) matrix updated per token with a
data-dependent diagonal decay:

    out_t = r_t · (S + diag(u) · k_tᵀ v_t)
    S    ← diag(w_t) · S + k_tᵀ v_t

TPU adaptation: the state matrix lives in VMEM scratch for the whole
sequence sweep (grid = (B·H, S/chunk) with the chunk dim sequential), so
HBM traffic is exactly the r/k/v/w inputs + outputs — the lax.scan
reference round-trips the state through HBM each step and saves every
step's state for backward.  Within a chunk the recurrence is a
``fori_loop`` of rank-1 updates on the VMEM-resident state; (D=64 heads
are padded to the 128-lane width by the wrapper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_out_ref,
                  s_scr, *, chunk: int, seq_len: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0]                                       # (D,)

    def step(t, _):
        # tail guard: positions past seq_len (partial final chunk) must
        # not touch the carried state
        valid = ci * chunk + t < seq_len
        r_t = r_ref[0, t].astype(jnp.float32)          # (D,)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]               # (Dk, Dv) rank-1
        s = s_scr[...]
        out = jnp.sum((s + u.astype(jnp.float32)[:, None] * kv)
                      * r_t[:, None], axis=0)          # (Dv,)
        o_ref[0, t] = out.astype(o_ref.dtype)
        s_scr[...] = jnp.where(valid, w_t[:, None] * s + kv, s)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == nc - 1)
    def _final():
        state_out_ref[0] = s_scr[...]


def rwkv6_scan_fwd(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   w: jnp.ndarray, u: jnp.ndarray, *,
                   chunk: int = DEFAULT_CHUNK,
                   interpret: bool = False):
    """r/k/v/w: (BH, S, D) (heads flattened into batch); u: (BH, D)
    (broadcast per head by the wrapper).  Returns (out (BH, S, D),
    state (BH, D, D) f32)."""
    bh, s, d = r.shape
    chunk = min(chunk, s)
    nc = pl.cdiv(s, chunk)

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, seq_len=s)
    out, state = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, d), lambda b, ci: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, d, d), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), r.dtype),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
        name="rwkv6_scan_fwd",
    )(r, k, v, w, u)
    return out, state
