"""Public, jit-ready wrappers around the Pallas kernels.

Each op:
  * normalizes layouts (GQA head grouping, lane-width padding),
  * runs the Pallas kernel (interpret mode automatically on CPU so the
    same code validates here and runs native on TPU),
  * exposes a ``jax.custom_vjp``: forward = kernel, backward = JAX AD
    through the ``ref.py`` oracle with recomputation (flash-style
    recompute; a fused backward kernel is a further optimization noted in
    DESIGN.md).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decode_attention import (decode_attention_fwd, mixed_attention_fwd,
                               paged_attention_fwd)
from .flash_attention import flash_attention_fwd
from .mamba import mamba_scan_fwd
from .rwkv6 import rwkv6_scan_fwd

LANE = 128


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_last(x: jnp.ndarray, to: int) -> jnp.ndarray:
    d = x.shape[-1]
    if d % to == 0:
        return x
    pad = to - d % to
    cfg = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, cfg)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: Optional[float] = None,
                    window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D).  GQA-aware."""
    return _flash_fwd_impl(q, k, v, causal, scale, window)


def _flash_fwd_impl(q, k, v, causal, scale, window):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    eff_scale = scale if scale is not None else d ** -0.5

    qp = _pad_last(q, LANE)
    kp = _pad_last(k, LANE)
    vp = _pad_last(v, LANE)
    dp = qp.shape[-1]

    out = flash_attention_fwd(
        qp.reshape(b * hq, sq, dp),
        kp.reshape(b * hkv, skv, dp),
        vp.reshape(b * hkv, skv, dp),
        causal=causal, scale=eff_scale, window=window,
        interpret=_interpret())
    return out.reshape(b, hq, sq, dp)[..., :d]


def _flash_fwd(q, k, v, causal, scale, window):
    return _flash_fwd_impl(q, k, v, causal, scale, window), (q, k, v)


def _flash_bwd(causal, scale, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention(
            q_, k_, v_, causal=causal, scale=scale, window=window),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------

def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len,
                     scale: Optional[float] = None,
                     window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Hq, 1, D) vs cache (B, Hkv, Smax, D), cache_len scalar or
    (B,).  Inference-only (no vjp)."""
    b, hq, one, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    g = hq // hkv
    eff_scale = scale if scale is not None else d ** -0.5

    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                            (b,))
    qg = _pad_last(q.reshape(b, hkv, g, d), LANE)
    kp = _pad_last(k_cache, LANE)
    vp = _pad_last(v_cache, LANE)

    out = decode_attention_fwd(qg, kp, vp, lens, scale=eff_scale,
                               window=window, interpret=_interpret())
    return out[..., :d].reshape(b, hq, 1, d)


# ----------------------------------------------------------------------
# mixed prefill/decode attention (serving unified step)
# ----------------------------------------------------------------------

def mixed_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, seg_ids: jnp.ndarray,
                    positions: jnp.ndarray,
                    scale: Optional[float] = None,
                    window: Optional[int] = None) -> jnp.ndarray:
    """q: (T, Hq, D) flat token batch vs per-slot caches (S, Hkv, L, D);
    seg_ids/positions (T,) int32.  Inference-only (no vjp)."""
    t, hq, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    g = hq // hkv
    eff_scale = scale if scale is not None else d ** -0.5

    qg = _pad_last(q.reshape(t, hkv, g, d), LANE)
    kp = _pad_last(k_cache, LANE)
    vp = _pad_last(v_cache, LANE)

    out = mixed_attention_fwd(
        qg, kp, vp, jnp.asarray(seg_ids, jnp.int32),
        jnp.asarray(positions, jnp.int32), scale=eff_scale,
        window=window, interpret=_interpret())
    return out[..., :d].reshape(t, hq, d)


# ----------------------------------------------------------------------
# paged attention (serving unified step, block table on device)
# ----------------------------------------------------------------------

def default_pages_per_tile(page_size: int, p_pages: int) -> int:
    """Static multi-page tile width: pack pages until a tile covers
    ~DEFAULT_BLOCK_K key positions (capped at 8 refs to bound the
    unrolled kernel body), so small-page configs don't pay one grid
    step per page.  fp32 outputs are bitwise-identical across tile
    sizes (the kernel unrolls the exact per-page update sequence)."""
    from .decode_attention import DEFAULT_BLOCK_K
    return max(1, min(8, DEFAULT_BLOCK_K // max(page_size, 1), p_pages))


def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, tables: jnp.ndarray,
                    seg_ids: jnp.ndarray, positions: jnp.ndarray,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    k_scale: Optional[jnp.ndarray] = None,
                    v_scale: Optional[jnp.ndarray] = None,
                    pages_per_tile: Optional[int] = None) -> jnp.ndarray:
    """q: (T, Hq, D) flat token batch vs the PHYSICAL page pool
    (N, ps, Hkv, D); tables (S, P), seg_ids/positions (T,) int32 ride as
    scalar-prefetch operands so the kernel's index maps resolve
    slot -> page id before each body runs.  A quantized pool (int8 /
    fp8_e4m3 codes) passes (N, ps, Hkv) fp32 ``k_scale``/``v_scale``;
    dequantization happens inside the kernel.  ``pages_per_tile``
    (default: :func:`default_pages_per_tile`) packs several pages per
    grid step.  Inference-only (no vjp)."""
    t, hq, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    g = hq // hkv
    eff_scale = scale if scale is not None else d ** -0.5
    if pages_per_tile is None:
        pages_per_tile = default_pages_per_tile(ps, tables.shape[1])

    qg = _pad_last(q.reshape(t, hkv, g, d), LANE)
    kp = _pad_last(k_pages, LANE)         # zero codes: dequant to 0
    vp = _pad_last(v_pages, LANE)

    out = paged_attention_fwd(
        qg, kp, vp, jnp.asarray(tables, jnp.int32),
        jnp.asarray(seg_ids, jnp.int32),
        jnp.asarray(positions, jnp.int32), scale=eff_scale,
        window=window, k_scale=k_scale, v_scale=v_scale,
        pages_per_tile=pages_per_tile, interpret=_interpret())
    return out[..., :d].reshape(t, hq, d)


# ----------------------------------------------------------------------
# rwkv6
# ----------------------------------------------------------------------

@jax.custom_vjp
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/w: (B, H, S, D); u: (H, D) bonus.
    Returns (out (B,H,S,D), state (B,H,D,D))."""
    return _rwkv6_impl(r, k, v, w, u)


def _rwkv6_impl(r, k, v, w, u):
    b, h, s, d = r.shape
    dp = ((d + LANE - 1) // LANE) * LANE

    def prep(x):
        return _pad_last(x, LANE).reshape(b * h, s, dp)

    rp, kp, vp = prep(r), prep(k), prep(v)
    # pad decay with ONES so padded state stays zero but stable
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, dp - d)),
                 constant_values=1.0).reshape(b * h, s, dp)
    up = jnp.broadcast_to(_pad_last(u, LANE)[None], (b, h, dp)) \
        .reshape(b * h, dp)

    out, state = rwkv6_scan_fwd(rp, kp, vp, wp, up,
                                interpret=_interpret())
    out = out.reshape(b, h, s, dp)[..., :d]
    state = state.reshape(b, h, dp, dp)[..., :d, :d]
    return out, state


def _rwkv6_fwd(r, k, v, w, u):
    return _rwkv6_impl(r, k, v, w, u), (r, k, v, w, u)


def _rwkv6_bwd(res, g):
    r, k, v, w, u = res
    _, vjp = jax.vjp(lambda *a: ref.rwkv6_scan(*a), r, k, v, w, u)
    return vjp(g)


rwkv6_scan.defvjp(_rwkv6_fwd, _rwkv6_bwd)


# ----------------------------------------------------------------------
# mamba selective scan
# ----------------------------------------------------------------------

@jax.custom_vjp
def mamba_scan(x: jnp.ndarray, dt: jnp.ndarray, B: jnp.ndarray,
               C: jnp.ndarray, A: jnp.ndarray,
               D: jnp.ndarray) -> jnp.ndarray:
    """x/dt: (B, S, Di); B/C: (B, S, N); A: (Di, N); D: (Di,)."""
    return mamba_scan_fwd(x, dt, B, C, A, D, interpret=_interpret())


def _mamba_fwd(x, dt, B, C, A, D):
    return mamba_scan_fwd(x, dt, B, C, A, D, interpret=_interpret()), \
        (x, dt, B, C, A, D)


def _mamba_bwd(res, g):
    x, dt, B, C, A, D = res
    _, vjp = jax.vjp(lambda *a: ref.mamba_scan(*a), x, dt, B, C, A, D)
    return vjp(g)


mamba_scan.defvjp(_mamba_fwd, _mamba_bwd)


# ----------------------------------------------------------------------
# fused elementwise chain (the fusion-queue lowering target)
# ----------------------------------------------------------------------

_EW_SUBLANE = 8       # f32 sublane granularity
_EW_BLOCK_ROWS = 256  # 256x128xf32 = 128KB per operand tile in VMEM


def fused_elementwise(fn, *xs, interpret: Optional[bool] = None):
    """Run an elementwise composite ``fn(*xs)`` as ONE Pallas kernel.

    ``fn`` may return one array or a tuple (a fusion-queue chain
    materializes every step output).  All operands and outputs must share
    a shape; the composite is applied blockwise over a (rows, 128)
    lane-major view of the raveled data — the padded tail goes through
    ``fn`` and is sliced off (elementwise, so garbage in the pad never
    contaminates real lanes).  Falls back to a plain call for
    scalars/odd layouts.
    """
    from jax.experimental import pallas as pl

    interpret = _interpret() if interpret is None else interpret
    x0 = xs[0]
    shape = x0.shape
    n = int(np.prod(shape)) if shape else 1
    out_avals = jax.eval_shape(fn, *xs)
    single = not isinstance(out_avals, tuple)
    outs = (out_avals,) if single else out_avals
    if (n == 0 or any(x.shape != shape for x in xs)
            or any(o.shape != shape for o in outs)):
        return fn(*xs)

    rows = -(-n // LANE)
    block_rows = min(_EW_BLOCK_ROWS,
                     -(-rows // _EW_SUBLANE) * _EW_SUBLANE)
    rows_p = -(-rows // block_rows) * block_rows
    pad = rows_p * LANE - n

    def prep(x):
        flat = x.reshape(-1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(rows_p, LANE)

    n_in = len(xs)

    def kernel(*refs):
        vals = fn(*[r[...] for r in refs[:n_in]])
        vals = (vals,) if not isinstance(vals, tuple) else vals
        for out_ref, v in zip(refs[n_in:], vals):
            out_ref[...] = v

    grid = (rows_p // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out2d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * n_in,
        out_specs=[spec] * len(outs),
        out_shape=[jax.ShapeDtypeStruct((rows_p, LANE), o.dtype)
                   for o in outs],
        interpret=interpret,
    )(*[prep(x) for x in xs])
    result = tuple(o.reshape(-1)[:n].reshape(shape) for o in out2d)
    return result[0] if single else result


def gumbel_perturb(logits: jnp.ndarray,
                   uniform: jnp.ndarray) -> jnp.ndarray:
    """Gumbel-max perturbation for in-jit sampling: ``logits +
    (-log(-log(u)))`` as ONE fused elementwise kernel.

    ``argmax`` of the result is a categorical draw from
    ``softmax(logits)`` (the Gumbel-max trick) — the serving sampler
    applies it to top-k/top-p-filtered logits so masked lanes
    (``-inf``) can never win.  ``uniform`` must be in (0, 1); shapes
    must match.  Elementwise, so the fusion-queue Pallas lowering
    (`fused_elementwise`) runs it as a single VPU pass on TPU and a
    single XLA fusion elsewhere."""
    def perturb(lg, u):
        return lg + -jnp.log(-jnp.log(u))
    return fused_elementwise(perturb, logits.astype(jnp.float32),
                             uniform.astype(jnp.float32))


def make_fused_elementwise(fn):
    """Dispatch-cache ``wrap`` hook: jitted Pallas lowering of an
    elementwise composite (used by the fusion queue on TPU)."""
    return jax.jit(functools.partial(fused_elementwise, fn))
