"""torch.multiprocessing analogue (paper §5.4): move array *data* to shared
memory instead of serializing it over the IPC channel.

``ShmChannel.send`` writes the ndarray into a ``multiprocessing.
shared_memory`` segment and sends only the (name, shape, dtype) descriptor;
``recv`` maps the segment zero-copy.  ``PickleChannel`` is the baseline the
paper improves on (full serialization).  ``benchmarks/bench_dataloader.py``
measures both, reproducing the §5.4 claim.
"""

from __future__ import annotations

import pickle
import queue
from dataclasses import dataclass
from multiprocessing import shared_memory
from typing import Any, Optional, Tuple

import numpy as np


@dataclass
class ShmDescriptor:
    name: str
    shape: Tuple[int, ...]
    dtype: str


class ShmChannel:
    """Single-process-pair channel: descriptors travel through a Queue,
    bytes travel through shared memory (constant-size message)."""

    def __init__(self, maxsize: int = 8):
        self._q: "queue.Queue[ShmDescriptor]" = queue.Queue(maxsize)
        self._owned = []
        self._mapped = []   # receiver-side segments kept alive for views
        self._pool: dict = {}   # rounded size -> reusable segments (the
                                # caching-allocator policy, §5.3, applied
                                # to IPC segments)

    def send(self, arr: np.ndarray) -> ShmDescriptor:
        size = max(arr.nbytes, 1)
        bucket = self._pool.setdefault(size, [])
        if bucket:
            seg = bucket.pop()
        else:
            seg = shared_memory.SharedMemory(create=True, size=size)
            self._owned.append(seg)
        view = np.ndarray(arr.shape, dtype=arr.dtype, buffer=seg.buf)
        np.copyto(view, arr)
        desc = ShmDescriptor(seg.name, arr.shape, str(arr.dtype))
        self._q.put(desc)
        return desc

    def recycle(self, desc: ShmDescriptor, seg=None) -> None:
        """Return a consumed segment to the pool for reuse."""
        for s_ in self._owned:
            if s_.name == desc.name:
                self._pool.setdefault(s_.size, []).append(s_)
                return

    def recv(self) -> np.ndarray:
        desc = self._q.get()
        seg = self._recv_cache.get(desc.name) if hasattr(
            self, "_recv_cache") else None
        if seg is None:
            if not hasattr(self, "_recv_cache"):
                self._recv_cache = {}
            seg = shared_memory.SharedMemory(name=desc.name)
            self._recv_cache[desc.name] = seg
            self._mapped.append(seg)  # keep mapping alive for views
        return np.ndarray(desc.shape, dtype=np.dtype(desc.dtype),
                          buffer=seg.buf)

    def close(self) -> None:
        for seg in self._mapped:
            try:
                seg.close()
            except Exception:
                pass
        self._mapped.clear()
        for seg in self._owned:
            try:
                seg.close()
                seg.unlink()
            except Exception:
                pass
        self._owned.clear()


class PickleChannel:
    """Baseline: the default multiprocessing transport (serialize bytes)."""

    def __init__(self, maxsize: int = 8):
        self._q: "queue.Queue[bytes]" = queue.Queue(maxsize)

    def send(self, arr: np.ndarray) -> None:
        self._q.put(pickle.dumps(arr, protocol=pickle.HIGHEST_PROTOCOL))

    def recv(self) -> np.ndarray:
        return pickle.loads(self._q.get())

    def close(self) -> None:
        pass
