"""repro.data — datasets and loaders (paper §4.2, §5.4).

``Dataset`` is the two-method protocol of the paper (``__getitem__`` +
``__len__``); ``DataLoader`` adds shuffling, batching, parallel workers and
staged ("pinned") host memory.

Hardware adaptation of §5.4: CPython's GIL pushed PyTorch to *processes* +
shared-memory tensor transport.  Here the hot loop is ``numpy``/JAX C code
that releases the GIL, so the default parallel worker is a *thread* pool
writing into shared staging buffers drawn from the host caching allocator
(the pinned-memory analogue; zero serialization, same property the paper
achieves with torch.multiprocessing).  A true process +
``multiprocessing.shared_memory`` channel is provided in
``repro.data.shared_memory`` and benchmarked against pickle transport in
``benchmarks/bench_dataloader.py``.

Straggler mitigation (framework-level): per-batch worker deadline; on
timeout the batch is refetched inline and the event is counted —
at cluster scale the same hook drives requeue-on-slow-host.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import (Any, Callable, Generic, Iterable, Iterator, List,
                    Optional, Sequence, TypeVar)

import jax.numpy as jnp
import numpy as np

from ..core import allocator as _alloc
from ..core.tensor import Tensor

T_co = TypeVar("T_co", covariant=True)


class Dataset(Generic[T_co]):
    """Map-style dataset: implement ``__getitem__`` and ``__len__``."""

    def __getitem__(self, index: int) -> T_co:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class IterableDataset(Generic[T_co]):
    def __iter__(self) -> Iterator[T_co]:
        raise NotImplementedError


class TensorDataset(Dataset):
    def __init__(self, *tensors: Tensor):
        assert all(t.shape[0] == tensors[0].shape[0] for t in tensors)
        self.tensors = [np.asarray(t.data if isinstance(t, Tensor) else t)
                        for t in tensors]

    def __getitem__(self, index: int):
        return tuple(t[index] for t in self.tensors)

    def __len__(self) -> int:
        return len(self.tensors[0])


class SyntheticLMDataset(Dataset):
    """Deterministic synthetic token stream (hash-based, no I/O) used by
    the end-to-end training examples and benchmarks."""

    def __init__(self, vocab_size: int, seq_len: int, size: int = 1 << 16,
                 seed: int = 0):
        self.vocab_size, self.seq_len, self.size = vocab_size, seq_len, size
        self.seed = seed

    def __getitem__(self, index: int):
        rng = np.random.default_rng(self.seed * 1_000_003 + index)
        tokens = rng.integers(0, self.vocab_size,
                              size=self.seq_len + 1).astype(np.int32)
        return tokens[:-1], tokens[1:]

    def __len__(self) -> int:
        return self.size


# ----------------------------------------------------------------------
# samplers
# ----------------------------------------------------------------------

class Sampler:
    def __iter__(self) -> Iterator[int]:
        raise NotImplementedError


class SequentialSampler(Sampler):
    def __init__(self, data_source):
        self.n = len(data_source)

    def __iter__(self):
        return iter(range(self.n))

    def __len__(self):
        return self.n


class RandomSampler(Sampler):
    def __init__(self, data_source, seed: Optional[int] = None):
        self.n = len(data_source)
        self.seed = seed
        self._epoch = 0

    def set_epoch(self, epoch: int):
        self._epoch = epoch

    def __iter__(self):
        rng = np.random.default_rng(
            None if self.seed is None else self.seed + self._epoch)
        return iter(rng.permutation(self.n).tolist())

    def __len__(self):
        return self.n


class DistributedSampler(Sampler):
    """Shards indices across data-parallel replicas (per-host loading for
    the multi-pod mesh): each rank sees len(dataset)/num_replicas samples,
    padded to equal length so collectives stay aligned."""

    def __init__(self, dataset, num_replicas: int, rank: int,
                 shuffle: bool = True, seed: int = 0,
                 drop_last: bool = False):
        if rank >= num_replicas:
            raise ValueError(f"rank {rank} >= num_replicas {num_replicas}")
        self.dataset_len = len(dataset)
        self.num_replicas = num_replicas
        self.rank = rank
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self._epoch = 0
        if drop_last:
            self.num_samples = self.dataset_len // num_replicas
        else:
            self.num_samples = -(-self.dataset_len // num_replicas)
        self.total_size = self.num_samples * num_replicas

    def set_epoch(self, epoch: int) -> None:
        self._epoch = epoch

    def __iter__(self):
        if self.shuffle:
            rng = np.random.default_rng(self.seed + self._epoch)
            indices = rng.permutation(self.dataset_len).tolist()
        else:
            indices = list(range(self.dataset_len))
        if not self.drop_last:
            pad = self.total_size - len(indices)
            indices += indices[:pad]
        else:
            indices = indices[: self.total_size]
        return iter(indices[self.rank: self.total_size: self.num_replicas])

    def __len__(self):
        return self.num_samples


class BatchSampler(Sampler):
    def __init__(self, sampler: Sampler, batch_size: int, drop_last: bool):
        self.sampler, self.batch_size, self.drop_last = \
            sampler, batch_size, drop_last

    def __iter__(self):
        batch: List[int] = []
        for idx in self.sampler:
            batch.append(idx)
            if len(batch) == self.batch_size:
                yield batch
                batch = []
        if batch and not self.drop_last:
            yield batch

    def __len__(self):
        n = len(self.sampler)
        return (n // self.batch_size if self.drop_last
                else -(-n // self.batch_size))


# ----------------------------------------------------------------------
# collation + pinned staging
# ----------------------------------------------------------------------

def default_collate(items: Sequence[Any]):
    first = items[0]
    if isinstance(first, (tuple, list)):
        return tuple(default_collate([it[i] for it in items])
                     for i in range(len(first)))
    if isinstance(first, dict):
        return {k: default_collate([it[k] for it in items]) for k in first}
    if isinstance(first, np.ndarray):
        return np.stack(items)
    if isinstance(first, Tensor):
        return np.stack([np.asarray(t.data) for t in items])
    return np.asarray(items)


def _stage_and_transfer(batch, pin_memory: bool):
    """numpy batch -> device Tensors, optionally via a staging block from
    the host caching allocator (pinned-memory analogue)."""

    def to_device(arr: np.ndarray) -> Tensor:
        if pin_memory:
            block = _alloc.host_allocator().allocate(
                arr.nbytes, stream=_staging_stream_id)
            if block.buffer is not None and arr.nbytes > 0:
                staged = block.buffer[: arr.nbytes].view(arr.dtype)
                np.copyto(staged, arr.reshape(-1).view(arr.dtype))
                dev = jnp.asarray(staged.reshape(arr.shape))
            else:
                dev = jnp.asarray(arr)
            _alloc.host_allocator().free(block)
            return Tensor(dev)
        return Tensor(jnp.asarray(arr))

    if isinstance(batch, tuple):
        return tuple(_stage_and_transfer(b, pin_memory) for b in batch)
    if isinstance(batch, dict):
        return {k: _stage_and_transfer(v, pin_memory)
                for k, v in batch.items()}
    return to_device(batch)


_staging_stream_id = 1  # dedicated "copy stream" pool in the host allocator


# ----------------------------------------------------------------------
# DataLoader
# ----------------------------------------------------------------------

class DataLoader(Generic[T_co]):
    def __init__(self, dataset: Dataset, batch_size: int = 1,
                 shuffle: bool = False, sampler: Optional[Sampler] = None,
                 batch_sampler: Optional[BatchSampler] = None,
                 num_workers: int = 0,
                 collate_fn: Optional[Callable] = None,
                 pin_memory: bool = False, drop_last: bool = False,
                 prefetch_factor: int = 2,
                 worker_timeout_s: Optional[float] = None,
                 seed: Optional[int] = None):
        self.dataset = dataset
        self.num_workers = num_workers
        self.collate_fn = collate_fn or default_collate
        self.pin_memory = pin_memory
        self.prefetch_factor = max(1, prefetch_factor)
        self.worker_timeout_s = worker_timeout_s
        self.straggler_events = 0

        if batch_sampler is not None:
            self.batch_sampler = batch_sampler
        else:
            if sampler is None:
                sampler = (RandomSampler(dataset, seed=seed) if shuffle
                           else SequentialSampler(dataset))
            self.sampler = sampler
            self.batch_sampler = BatchSampler(sampler, batch_size, drop_last)

    def __len__(self):
        return len(self.batch_sampler)

    def set_epoch(self, epoch: int):
        s = getattr(self, "sampler", None)
        if s is not None and hasattr(s, "set_epoch"):
            s.set_epoch(epoch)

    def _fetch(self, indices: List[int]):
        return self.collate_fn([self.dataset[i] for i in indices])

    def __iter__(self):
        if self.num_workers == 0:
            for indices in self.batch_sampler:
                yield _stage_and_transfer(self._fetch(indices),
                                          self.pin_memory)
            return

        # threaded prefetch pipeline with bounded depth
        depth = self.num_workers * self.prefetch_factor
        with ThreadPoolExecutor(max_workers=self.num_workers) as pool:
            batches = iter(self.batch_sampler)
            inflight: "queue.Queue" = queue.Queue()
            submitted = 0
            for indices in batches:
                inflight.put((pool.submit(self._fetch, indices), indices))
                submitted += 1
                if submitted >= depth:
                    break
            while not inflight.empty():
                fut, indices = inflight.get()
                # straggler mitigation: deadline + inline refetch
                try:
                    batch = fut.result(timeout=self.worker_timeout_s)
                except (TimeoutError, _FuturesTimeout):
                    # pre-3.11 futures.TimeoutError is not the builtin
                    self.straggler_events += 1
                    fut.cancel()
                    batch = self._fetch(indices)
                nxt = next(batches, None)
                if nxt is not None:
                    inflight.put((pool.submit(self._fetch, nxt), nxt))
                yield _stage_and_transfer(batch, self.pin_memory)
