"""repro.core — the eager runtime (the paper's contribution, in JAX).

Layers:
  tensor     — operator-overloaded Tensor, views, versioning, storage
  autograd   — define-by-run tape, Function, no_grad, backward engine
  allocator  — caching block allocator (512B rounding, per-stream pools)
  stream     — streams/events: separate control flow from data flow
  dispatch   — signature-keyed op/VJP cache (the eager fast path)
  fuse       — the compiled path (jit bridge) + elementwise fusion queue
"""

from . import allocator
from . import autograd
from . import dispatch
from . import fuse
from . import stream
from .autograd import Function, enable_grad, grad, is_grad_enabled, no_grad
from .dispatch import (
    dispatch_cache_stats,
    reset_dispatch_cache,
)
from .fuse import block_until_ready, compile, fusion, value_and_grad
from .stream import Event, Stream, current_stream, default_stream, \
    stream as stream_ctx, synchronize
from .tensor import (
    Tensor,
    arange,
    cat,
    concat,
    einsum,
    empty,
    eye,
    from_numpy,
    full,
    logsumexp,
    manual_seed,
    matmul,
    maximum,
    minimum,
    normal,
    one_hot,
    ones,
    ones_like,
    rand,
    randint,
    randn,
    softmax,
    split,
    stack,
    take_along_dim,
    tensor,
    tril,
    triu,
    uniform,
    where,
    zeros,
    zeros_like,
)


