"""The eager Tensor (paper §4, §5.5).

A :class:`Tensor` wraps a ``jax.Array`` and provides the imperative,
operator-overloaded programming model of the paper:

* every op executes immediately (async-dispatched on the current stream),
* the autograd tape records a vjp node per op (``jax.vjp`` supplies the
  exact derivative closure),
* in-place ops mutate through a shared :class:`VersionCounter` so the
  engine can detect use-after-mutate (§4.3),
* storage is refcounted — Python's own refcounting (the paper's CPython
  integration argument, §5.5) drives immediate frees back into the caching
  allocator,
* Tensors are registered pytrees, so the same model code runs eagerly *and*
  under ``jax.jit``/``pjit`` — the TorchScript-analogue compiled path.

When any operand is a JAX tracer (i.e. we are inside a ``jit`` trace), the
tape is skipped and ops lower straight to XLA; differentiation of compiled
code is handled by JAX's AD.  This is the eager/compiled split of the paper.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import allocator as _alloc
from . import stream as _stream
from .autograd import (
    Node,
    VersionCounter,
    backward as _backward,
    is_grad_enabled,
    no_grad,
)

Array = jax.Array
DTypeLike = Any

# ----------------------------------------------------------------------
# Storage: refcounted allocation accounting (§5.5)
# ----------------------------------------------------------------------

class Storage:
    """Owns one accounting block in the caching allocator.

    Python's refcounting destroys this object the moment the last Tensor
    (or autograd closure) referencing it dies, returning the block to the
    allocator pool immediately — no deferred GC (§5.5).
    """

    __slots__ = ("nbytes", "_block", "stream_id")

    def __init__(self, nbytes: int, stream_id: int):
        self.nbytes = nbytes
        self.stream_id = stream_id
        self._block = _alloc.device_allocator().allocate(nbytes, stream_id)

    def __del__(self):
        try:
            _alloc.device_allocator().free(self._block)
        except Exception:
            pass


def _nbytes_of(data: Array) -> int:
    try:
        return int(np.prod(data.shape)) * data.dtype.itemsize
    except Exception:
        return 0


def _is_tracer(x: Any) -> bool:
    return isinstance(x, jax.core.Tracer)


# ----------------------------------------------------------------------
# Tensor
# ----------------------------------------------------------------------

class Tensor:
    __slots__ = (
        "_data",
        "requires_grad",
        "grad",
        "grad_fn",
        "_output_index",
        "_version",
        "_storage",
        "_base",        # for views: the viewed-into tensor
        "_view_index",  # the indexing expression creating the view
        "__weakref__",
    )

    def __init__(self, data: Any, requires_grad: bool = False,
                 _storage: Optional[Storage] = None,
                 _version: Optional[VersionCounter] = None):
        if isinstance(data, Tensor):
            data = data._data
        if not isinstance(data, (jax.Array, jax.core.Tracer)):
            data = jnp.asarray(data)
        if requires_grad and not jnp.issubdtype(data.dtype, jnp.inexact):
            raise RuntimeError(
                "Only Tensors of floating point and complex dtype can "
                "require gradients"
            )
        self._data = data
        self.requires_grad = requires_grad
        self.grad: Optional[Tensor] = None
        self.grad_fn: Optional[Node] = None
        self._output_index = 0
        self._version = _version if _version is not None else VersionCounter()
        self._base: Optional[Tensor] = None
        self._view_index = None
        if _storage is not None:
            self._storage = _storage
        elif _is_tracer(data):
            self._storage = None  # tracing: XLA owns memory
        else:
            self._storage = Storage(
                _nbytes_of(data), _stream.current_stream().stream_id
            )

    # -- basic properties ----------------------------------------------
    @property
    def data(self) -> Array:
        return self._data

    @data.setter
    def data(self, value):
        self._data = value._data if isinstance(value, Tensor) else value

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self._data.shape)

    @property
    def dtype(self):
        return self._data.dtype

    @property
    def ndim(self) -> int:
        return self._data.ndim

    @property
    def size_bytes(self) -> int:
        return _nbytes_of(self._data)

    @property
    def is_leaf(self) -> bool:
        return self.grad_fn is None

    @property
    def device(self):
        try:
            return next(iter(self._data.devices()))
        except Exception:
            return jax.devices()[0]

    def size(self, dim: Optional[int] = None):
        return self.shape if dim is None else self.shape[dim]

    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def dim(self) -> int:
        return self.ndim

    def numpy(self) -> np.ndarray:
        return np.asarray(self._data)

    def item(self):
        return self._data.item()

    def tolist(self):
        return np.asarray(self._data).tolist()

    def __len__(self):
        return self.shape[0]

    def __repr__(self):
        grad_part = ""
        if self.grad_fn is not None:
            grad_part = f", grad_fn=<{self.grad_fn.name}>"
        elif self.requires_grad:
            grad_part = ", requires_grad=True"
        if _is_tracer(self._data):
            return f"Tensor(<traced {self.shape} {self.dtype}>{grad_part})"
        return f"Tensor({np.asarray(self._data)!r}{grad_part})"

    def __hash__(self):
        return id(self)

    def __bool__(self):
        return bool(self._data)

    # -- autograd --------------------------------------------------------
    def backward(self, gradient: Optional["Tensor"] = None,
                 retain_graph: bool = False) -> None:
        _backward(self, [gradient] if gradient is not None else None,
                  retain_graph=retain_graph)

    def _accumulate_grad(self, g: Array) -> None:
        if self.grad is None:
            self.grad = Tensor(g)
        else:
            self.grad = Tensor(self.grad._data + g)

    def detach(self) -> "Tensor":
        t = Tensor(self._data, _storage=self._storage,
                   _version=self._version)
        return t

    def detach_(self) -> "Tensor":
        self.grad_fn = None
        self.requires_grad = False
        return self

    def requires_grad_(self, flag: bool = True) -> "Tensor":
        if flag and not jnp.issubdtype(self.dtype, jnp.inexact):
            raise RuntimeError(
                "Only Tensors of floating point and complex dtype can "
                "require gradients"
            )
        self.requires_grad = flag
        return self

    def clone(self) -> "Tensor":
        return _apply_op("clone", lambda x: x + 0, self)

    def retain_grad(self) -> "Tensor":
        # non-leaf grads: wrap identity so engine treats as leaf-like sink
        self.requires_grad = True
        return self

    # -- dtype / device movement ----------------------------------------
    def astype(self, dtype) -> "Tensor":
        return _apply_op("astype", lambda x: x.astype(dtype), self)

    def to(self, dtype=None) -> "Tensor":
        if dtype is None:
            return self
        return self.astype(dtype)

    def float(self):
        return self.astype(jnp.float32)

    def bfloat16(self):
        return self.astype(jnp.bfloat16)

    def half(self):
        return self.astype(jnp.float16)

    def int(self):
        return self.astype(jnp.int32)

    def bool(self):
        return self.astype(jnp.bool_)

    def cpu(self):
        return self

    def cuda(self):
        return self

    # -- arithmetic (operator overloading: the define-by-run surface) ----
    def __add__(self, other):
        return add(self, other)

    __radd__ = __add__

    def __sub__(self, other):
        return sub(self, other)

    def __rsub__(self, other):
        return sub(_coerce(other, like=self), self)

    def __mul__(self, other):
        return mul(self, other)

    __rmul__ = __mul__

    def __truediv__(self, other):
        return div(self, other)

    def __rtruediv__(self, other):
        return div(_coerce(other, like=self), self)

    def __pow__(self, other):
        return pow_(self, other)

    def __rpow__(self, other):
        return pow_(_coerce(other, like=self), self)

    def __matmul__(self, other):
        return matmul(self, other)

    def __rmatmul__(self, other):
        return matmul(_coerce(other, like=self), self)

    def __neg__(self):
        return _apply_op("neg", lambda x: -x, self)

    def __abs__(self):
        return _apply_op("abs", jnp.abs, self)

    def __mod__(self, other):
        return _apply_op("mod", jnp.mod, self, _coerce(other, like=self))

    # comparisons (non-differentiable)
    def __eq__(self, other):  # type: ignore[override]
        return Tensor(self._data == _raw(other))

    def __ne__(self, other):  # type: ignore[override]
        return Tensor(self._data != _raw(other))

    def __lt__(self, other):
        return Tensor(self._data < _raw(other))

    def __le__(self, other):
        return Tensor(self._data <= _raw(other))

    def __gt__(self, other):
        return Tensor(self._data > _raw(other))

    def __ge__(self, other):
        return Tensor(self._data >= _raw(other))

    # -- indexing ---------------------------------------------------------
    def __getitem__(self, index) -> "Tensor":
        index = _raw_index(index)
        out = _apply_op("getitem", lambda x: x[index], self)
        # basic-indexing results are views: share version counter so
        # mutation through either side is detected / written through.
        if _is_basic_index(index):
            out._version = self._version
            out._base = self._base if self._base is not None else self
            out._view_index = index
            out._storage = self._storage
        return out

    def __setitem__(self, index, value) -> None:
        index = _raw_index(index)
        self._inplace_guard("__setitem__")
        val = _raw(value)
        self._write_through(lambda x: x.at[index].set(val))

    # -- in-place ops (mutation; §4.3 versioning) -------------------------
    def _inplace_guard(self, opname: str) -> None:
        if self.requires_grad and self.grad_fn is None and is_grad_enabled():
            raise RuntimeError(
                f"a leaf Variable that requires grad is being used in an "
                f"in-place operation ({opname})"
            )

    def _write_through(self, fn: Callable[[Array], Array]) -> None:
        """Apply ``fn`` to this tensor's data, writing through views to the
        base storage, and bump the shared version counter."""
        if self._base is not None:
            base = self._base
            idx = self._view_index
            new_base = base._data.at[idx].set(fn(base._data[idx]))
            base._data = new_base
            self._data = new_base[idx]
        else:
            self._data = fn(self._data)
        self._version.bump()

    def _inplace_binary(self, opname: str, fn, other, alpha=None):
        self._inplace_guard(opname)
        o = _raw(other)
        if alpha is not None:
            o = o * alpha
        if (is_grad_enabled()
                and self.grad_fn is not None
                and jnp.issubdtype(self.dtype, jnp.inexact)
                and not _is_tracer(self._data)):
            # differentiable in-place: record as out-of-place op against a
            # snapshot of the pre-mutation value (so the new node points at
            # the OLD grad_fn, not at itself), then mutate this object.
            # The version bump happens BEFORE the node records its saved
            # versions: this very op is consistent with the new version,
            # while any later mutation is still caught.
            self._version.bump()
            snapshot = Tensor(self._data, _storage=self._storage,
                              _version=self._version)
            snapshot.grad_fn = self.grad_fn
            snapshot._output_index = self._output_index
            snapshot.requires_grad = self.requires_grad
            other_t = other if isinstance(other, Tensor) else Tensor(o)
            res = _apply_op(opname, fn, snapshot, other_t)
            self._data = res._data
            self.grad_fn = res.grad_fn
            self._output_index = res._output_index
            # the mutated tensor starts a fresh version lineage: the
            # recorded node holds the OLD counter via the snapshot, so
            # chained differentiable in-place ops don't trip each other
            self._version = VersionCounter()
        else:
            self._write_through(lambda x: fn(x, o))
        return self

    def add_(self, other, alpha=None):
        return self._inplace_binary("add_", jnp.add, other, alpha)

    def sub_(self, other, alpha=None):
        return self._inplace_binary("sub_", jnp.subtract, other, alpha)

    def mul_(self, other):
        return self._inplace_binary("mul_", jnp.multiply, other)

    def div_(self, other):
        return self._inplace_binary("div_", jnp.divide, other)

    def zero_(self):
        self._write_through(lambda x: jnp.zeros_like(x))
        return self

    def fill_(self, value):
        self._write_through(lambda x: jnp.full_like(x, value))
        return self

    def copy_(self, other):
        src = _raw(other)
        self._write_through(lambda x: jnp.broadcast_to(src, x.shape).astype(x.dtype))
        return self

    def clamp_(self, min=None, max=None):
        self._write_through(lambda x: jnp.clip(x, min, max))
        return self

    # -- shape ops ---------------------------------------------------------
    def reshape(self, *shape) -> "Tensor":
        shape = _norm_shape(shape)
        return _apply_op("reshape", lambda x: x.reshape(shape), self)

    view = reshape

    def transpose(self, dim0: int, dim1: int) -> "Tensor":
        perm = list(range(self.ndim))
        perm[dim0], perm[dim1] = perm[dim1], perm[dim0]
        return _apply_op("transpose", lambda x: jnp.transpose(x, perm), self)

    def permute(self, *dims) -> "Tensor":
        dims = _norm_shape(dims)
        return _apply_op("permute", lambda x: jnp.transpose(x, dims), self)

    @property
    def T(self) -> "Tensor":
        return _apply_op("T", lambda x: x.T, self)

    def squeeze(self, dim: Optional[int] = None) -> "Tensor":
        return _apply_op("squeeze", lambda x: jnp.squeeze(x, dim), self)

    def unsqueeze(self, dim: int) -> "Tensor":
        return _apply_op("unsqueeze", lambda x: jnp.expand_dims(x, dim), self)

    def flatten(self, start_dim: int = 0, end_dim: int = -1) -> "Tensor":
        shape = self.shape
        end = end_dim % self.ndim
        new = shape[:start_dim] + (-1,) + shape[end + 1:]
        return self.reshape(new)

    def expand(self, *sizes) -> "Tensor":
        sizes = _norm_shape(sizes)
        tgt = tuple(
            s if s != -1 else self.shape[i - (len(sizes) - self.ndim)]
            for i, s in enumerate(sizes)
        )
        return _apply_op("expand", lambda x: jnp.broadcast_to(x, tgt), self)

    def repeat(self, *reps) -> "Tensor":
        reps = _norm_shape(reps)
        return _apply_op("repeat", lambda x: jnp.tile(x, reps), self)

    def chunk(self, chunks: int, dim: int = 0):
        return split(self, self.shape[dim] // chunks, dim)

    def split(self, size: int, dim: int = 0):
        return split(self, size, dim)

    # -- math methods -------------------------------------------------------
    def sum(self, dim=None, keepdim: bool = False):
        return _apply_op("sum", lambda x: jnp.sum(x, axis=dim,
                                                  keepdims=keepdim), self)

    def mean(self, dim=None, keepdim: bool = False):
        return _apply_op("mean", lambda x: jnp.mean(x, axis=dim,
                                                    keepdims=keepdim), self)

    def var(self, dim=None, keepdim: bool = False, unbiased: bool = True):
        ddof = 1 if unbiased else 0
        return _apply_op("var", lambda x: jnp.var(x, axis=dim, ddof=ddof,
                                                  keepdims=keepdim), self)

    def std(self, dim=None, keepdim: bool = False, unbiased: bool = True):
        ddof = 1 if unbiased else 0
        return _apply_op("std", lambda x: jnp.std(x, axis=dim, ddof=ddof,
                                                  keepdims=keepdim), self)

    def max(self, dim=None, keepdim: bool = False):
        if dim is None:
            return _apply_op("max", jnp.max, self)
        values = _apply_op(
            "max", lambda x: jnp.max(x, axis=dim, keepdims=keepdim), self)
        indices = Tensor(jnp.argmax(self._data, axis=dim))
        return values, indices

    def min(self, dim=None, keepdim: bool = False):
        if dim is None:
            return _apply_op("min", jnp.min, self)
        values = _apply_op(
            "min", lambda x: jnp.min(x, axis=dim, keepdims=keepdim), self)
        indices = Tensor(jnp.argmin(self._data, axis=dim))
        return values, indices

    def argmax(self, dim=None):
        return Tensor(jnp.argmax(self._data, axis=dim))

    def argmin(self, dim=None):
        return Tensor(jnp.argmin(self._data, axis=dim))

    def prod(self, dim=None, keepdim: bool = False):
        return _apply_op("prod", lambda x: jnp.prod(x, axis=dim,
                                                    keepdims=keepdim), self)

    def cumsum(self, dim: int):
        return _apply_op("cumsum", lambda x: jnp.cumsum(x, axis=dim), self)

    def exp(self):
        return _apply_op("exp", jnp.exp, self)

    def log(self):
        return _apply_op("log", jnp.log, self)

    def sqrt(self):
        return _apply_op("sqrt", jnp.sqrt, self)

    def rsqrt(self):
        return _apply_op("rsqrt", lambda x: jax.lax.rsqrt(x), self)

    def abs(self):
        return _apply_op("abs", jnp.abs, self)

    def sin(self):
        return _apply_op("sin", jnp.sin, self)

    def cos(self):
        return _apply_op("cos", jnp.cos, self)

    def tanh(self):
        return _apply_op("tanh", jnp.tanh, self)

    def sigmoid(self):
        return _apply_op("sigmoid", jax.nn.sigmoid, self)

    def relu(self):
        return _apply_op("relu", jax.nn.relu, self)

    def erf(self):
        return _apply_op("erf", jax.scipy.special.erf, self)

    def clamp(self, min=None, max=None):
        return _apply_op("clamp", lambda x: jnp.clip(x, min, max), self)

    def softmax(self, dim: int = -1):
        return _apply_op("softmax",
                         lambda x: jax.nn.softmax(x, axis=dim), self)

    def log_softmax(self, dim: int = -1):
        return _apply_op("log_softmax",
                         lambda x: jax.nn.log_softmax(x, axis=dim), self)

    def masked_fill(self, mask, value):
        m = _raw(mask)
        return _apply_op("masked_fill",
                         lambda x: jnp.where(m, value, x), self)

    def matmul(self, other):
        return matmul(self, other)

    mm = matmul
    bmm = matmul

    def dot(self, other):
        return matmul(self, other)

    def record_stream(self, s: "_stream.Stream") -> None:
        """Mark this tensor as used on stream ``s`` (cross-stream safety,
        §5.3): its storage free will then require a sync before reuse."""
        if self._storage is not None:
            _alloc.device_allocator().free  # accounting path exists
            self._storage.stream_id = s.stream_id


# ----------------------------------------------------------------------
# op dispatcher: forward + tape recording
# ----------------------------------------------------------------------

def _raw(x: Any) -> Any:
    return x._data if isinstance(x, Tensor) else x


def _raw_index(index):
    if isinstance(index, tuple):
        return tuple(_raw(i) for i in index)
    return _raw(index)


def _is_basic_index(index) -> bool:
    items = index if isinstance(index, tuple) else (index,)
    return all(isinstance(i, (int, slice, type(Ellipsis), type(None)))
               for i in items)


def _coerce(x: Any, like: Optional[Tensor] = None) -> Tensor:
    if isinstance(x, Tensor):
        return x
    arr = jnp.asarray(x)
    if (like is not None and jnp.issubdtype(like.dtype, jnp.inexact)
            and not jnp.issubdtype(arr.dtype, jnp.inexact)):
        arr = arr.astype(like.dtype)
    elif (like is not None and jnp.issubdtype(like.dtype, jnp.inexact)
            and arr.dtype != like.dtype and np.isscalar(x)):
        arr = arr.astype(like.dtype)
    return Tensor(arr)


def _wrap_outputs(raw, node: Optional[Node]):
    """Wrap raw jnp outputs in Tensors attached to ``node``."""
    single = not isinstance(raw, tuple)
    outs = (raw,) if single else raw
    tensors = []
    for i, o in enumerate(outs):
        t = Tensor(o)
        if node is not None:
            t.grad_fn = node
            t._output_index = i
        tensors.append(t)
    _stream.current_stream().enqueue(*[t._data for t in tensors])
    return tensors[0] if single else tuple(tensors)


def _apply_op(name: str, fn: Callable, *tensors: Tensor,
              num_outputs: int = 1):
    """Execute ``fn`` over tensor data; record a tape node when needed.

    This is the single funnel for every differentiable eager op.  Inside a
    ``jax.jit`` trace (tracer operands) the tape is skipped entirely and the
    op lowers to XLA — the compiled path differentiates via JAX AD.
    """
    datas = [t._data for t in tensors]
    tracing = any(_is_tracer(d) for d in datas)

    diffable = [
        i for i, t in enumerate(tensors)
        if jnp.issubdtype(t.dtype, jnp.inexact)
    ]
    needs_grad = (
        not tracing
        and is_grad_enabled()
        and any(tensors[i].requires_grad or tensors[i].grad_fn is not None
                for i in diffable)
    )

    if not needs_grad:
        raw = fn(*datas)
        return _wrap_outputs(raw, None)

    if len(diffable) == len(datas):
        out, vjp_fn = jax.vjp(fn, *datas)
        inputs = list(tensors)
    else:
        # close over non-differentiable (integer/bool) operands
        frozen = {i: d for i, d in enumerate(datas) if i not in diffable}

        def fn_diff(*diff_args):
            full = list(frozen.get(i) for i in range(len(datas)))
            it = iter(diff_args)
            for i in diffable:
                full[i] = next(it)
            return fn(*full)

        out, vjp_fn = jax.vjp(fn_diff, *[datas[i] for i in diffable])
        inputs = [tensors[i] for i in diffable]

    node = Node(name, vjp_fn, inputs, num_outputs=num_outputs)
    outs = out if isinstance(out, tuple) else (out,)
    node.metadata["out_avals"] = [(o.shape, o.dtype) for o in outs]
    for t in inputs:
        node.save_version(t)
    return _wrap_outputs(out, node)


# ----------------------------------------------------------------------
# module-level functional ops
# ----------------------------------------------------------------------

def add(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("add", jnp.add, a, b)


def sub(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("sub", jnp.subtract, a, b)


def mul(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("mul", jnp.multiply, a, b)


def div(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("div", jnp.divide, a, b)


def pow_(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("pow", jnp.power, a, b)


def matmul(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("matmul", jnp.matmul, a, b)


def maximum(a, b):
    a, b = _coerce(a), _coerce(b)
    return _apply_op("maximum", jnp.maximum, a, b)


def minimum(a, b):
    a, b = _coerce(a), _coerce(b)
    return _apply_op("minimum", jnp.minimum, a, b)


def where(cond, a, b):
    cond = _coerce(cond)
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("where", jnp.where, cond, a, b)


def cat(tensors: Sequence[Tensor], dim: int = 0) -> Tensor:
    tensors = [_coerce(t) for t in tensors]
    return _apply_op("cat", lambda *xs: jnp.concatenate(xs, axis=dim),
                     *tensors)


concat = cat


def stack(tensors: Sequence[Tensor], dim: int = 0) -> Tensor:
    tensors = [_coerce(t) for t in tensors]
    return _apply_op("stack", lambda *xs: jnp.stack(xs, axis=dim), *tensors)


def split(t: Tensor, size: int, dim: int = 0):
    n = t.shape[dim]
    pieces = []
    for start in range(0, n, size):
        idx = [slice(None)] * t.ndim
        idx[dim] = slice(start, min(start + size, n))
        pieces.append(t[tuple(idx)])
    return tuple(pieces)


def einsum(subscripts: str, *tensors) -> Tensor:
    tensors = [_coerce(t) for t in tensors]
    return _apply_op("einsum",
                     lambda *xs: jnp.einsum(subscripts, *xs), *tensors)


def logsumexp(t: Tensor, dim=None, keepdim: bool = False) -> Tensor:
    return _apply_op(
        "logsumexp",
        lambda x: jax.scipy.special.logsumexp(x, axis=dim, keepdims=keepdim),
        _coerce(t))


def exp(t):
    return _coerce(t).exp()


def log(t):
    return _coerce(t).log()


def sqrt(t):
    return _coerce(t).sqrt()


def tanh(t):
    return _coerce(t).tanh()


def sigmoid(t):
    return _coerce(t).sigmoid()


def relu(t):
    return _coerce(t).relu()


def softmax(t, dim: int = -1):
    return _coerce(t).softmax(dim)


def tril(t, k: int = 0):
    return _apply_op("tril", lambda x: jnp.tril(x, k), _coerce(t))


def triu(t, k: int = 0):
    return _apply_op("triu", lambda x: jnp.triu(x, k), _coerce(t))


def take_along_dim(t, indices, dim: int):
    idx = _raw(indices)
    return _apply_op("take_along_dim",
                     lambda x: jnp.take_along_axis(x, idx, axis=dim),
                     _coerce(t))


def one_hot(t, num_classes: int, dtype=jnp.float32):
    return Tensor(jax.nn.one_hot(_raw(t), num_classes, dtype=dtype))


def _norm_shape(shape):
    if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
        return tuple(shape[0])
    return tuple(shape)


# ----------------------------------------------------------------------
# factories + RNG
# ----------------------------------------------------------------------

_rng_lock = threading.Lock()
_np_rng = np.random.default_rng(0)


def manual_seed(seed: int) -> None:
    global _np_rng
    with _rng_lock:
        _np_rng = np.random.default_rng(seed)


def _factory(arr, dtype=None, requires_grad: bool = False) -> Tensor:
    data = jnp.asarray(arr)
    if dtype is not None:
        data = data.astype(dtype)
    return Tensor(data, requires_grad=requires_grad)


def tensor(data, dtype=None, requires_grad: bool = False) -> Tensor:
    return _factory(data, dtype, requires_grad)


def zeros(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    return Tensor(jnp.zeros(_norm_shape(shape), dtype), requires_grad)


def ones(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    return Tensor(jnp.ones(_norm_shape(shape), dtype), requires_grad)


def full(shape, fill_value, dtype=jnp.float32,
         requires_grad: bool = False) -> Tensor:
    return Tensor(jnp.full(shape, fill_value, dtype), requires_grad)


def empty(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    return zeros(*shape, dtype=dtype, requires_grad=requires_grad)


def zeros_like(t, dtype=None) -> Tensor:
    return Tensor(jnp.zeros_like(_raw(t), dtype=dtype))


def ones_like(t, dtype=None) -> Tensor:
    return Tensor(jnp.ones_like(_raw(t), dtype=dtype))


def arange(*args, dtype=None) -> Tensor:
    return Tensor(jnp.arange(*args, dtype=dtype))


def eye(n, m=None, dtype=jnp.float32) -> Tensor:
    return Tensor(jnp.eye(n, m, dtype=dtype))


def randn(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    with _rng_lock:
        arr = _np_rng.standard_normal(_norm_shape(shape), dtype=np.float32)
    return _factory(arr, dtype, requires_grad)


def rand(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    with _rng_lock:
        arr = _np_rng.random(_norm_shape(shape), dtype=np.float32)
    return _factory(arr, dtype, requires_grad)


def randint(low, high, shape, dtype=jnp.int32) -> Tensor:
    with _rng_lock:
        arr = _np_rng.integers(low, high, size=shape)
    return _factory(arr, dtype)


def normal(mean: float, std: float, shape, dtype=jnp.float32,
           requires_grad: bool = False) -> Tensor:
    with _rng_lock:
        arr = _np_rng.normal(mean, std, size=shape).astype(np.float32)
    return _factory(arr, dtype, requires_grad)


def uniform(low: float, high: float, shape, dtype=jnp.float32,
            requires_grad: bool = False) -> Tensor:
    with _rng_lock:
        arr = _np_rng.uniform(low, high, size=shape).astype(np.float32)
    return _factory(arr, dtype, requires_grad)


def from_numpy(arr: np.ndarray) -> Tensor:
    """Zero-copy-intent interop (§4.2): on CPU backends jax aliases the
    numpy buffer when dtype/layout allow."""
    return Tensor(jnp.asarray(arr))


# ----------------------------------------------------------------------
# pytree registration: Tensors flow through jit/pjit/scan transparently
# ----------------------------------------------------------------------

def _tensor_flatten(t: Tensor):
    return (t._data,), (t.requires_grad,)


def _tensor_unflatten(aux, children):
    (data,) = children
    t = Tensor.__new__(Tensor)
    t._data = data if isinstance(data, (jax.Array, jax.core.Tracer)) \
        else jnp.asarray(data)
    t.requires_grad = aux[0]
    t.grad = None
    t.grad_fn = None
    t._output_index = 0
    t._version = VersionCounter()
    t._base = None
    t._view_index = None
    t._storage = None
    return t


jax.tree_util.register_pytree_node(Tensor, _tensor_flatten, _tensor_unflatten)
