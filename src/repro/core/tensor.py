"""The eager Tensor (paper §4, §5.5).

A :class:`Tensor` wraps a ``jax.Array`` and provides the imperative,
operator-overloaded programming model of the paper:

* every op executes immediately (async-dispatched on the current stream),
* the autograd tape records a vjp node per op (``jax.vjp`` supplies the
  exact derivative closure),
* in-place ops mutate through a shared :class:`VersionCounter` so the
  engine can detect use-after-mutate (§4.3),
* storage is refcounted — Python's own refcounting (the paper's CPython
  integration argument, §5.5) drives immediate frees back into the caching
  allocator,
* Tensors are registered pytrees, so the same model code runs eagerly *and*
  under ``jax.jit``/``pjit`` — the TorchScript-analogue compiled path.

When any operand is a JAX tracer (i.e. we are inside a ``jit`` trace), the
tape is skipped and ops lower straight to XLA; differentiation of compiled
code is handled by JAX's AD.  This is the eager/compiled split of the paper.

Dispatch fast path (§5 "as fast as the hardware allows"):

* every differentiable op funnels through :func:`_apply_op`, which consults
  the signature-keyed **dispatch cache** (``core.dispatch``): the first
  call for a given (op, static args, input shapes/dtypes, grad flag) traces
  a jitted forward and a jitted VJP replay; every subsequent call is a dict
  lookup + XLA executable replay — no ``jax.vjp`` re-trace.  Call sites
  pass ``static=...`` tuples naming everything their closure captures;
  unhashable statics fall back to the uncached re-traced path with a
  warning counter instead of raising.
* when the **elementwise fusion queue** is enabled
  (``repro.fuse.fusion()``), elementwise ops return *pending* tensors that
  record the chain instead of dispatching; materialization points
  (``.numpy()``, ``.item()``, reductions, matmul, ``backward``, in-place
  mutation, jit boundaries) flush the chain as one fused kernel.  Reads of
  ``Tensor._data`` are the single materialization funnel.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import allocator as _alloc
from . import dispatch as _dispatch
from . import stream as _stream
from .autograd import (
    Node,
    VersionCounter,
    backward as _backward,
    is_grad_enabled,
    no_grad,
)

_fuse_mod = None


def _fuse():
    """Lazy import of ``core.fuse`` (it imports this module at top level)."""
    global _fuse_mod
    if _fuse_mod is None:
        from . import fuse as f
        _fuse_mod = f
    return _fuse_mod

Array = jax.Array
DTypeLike = Any

# ----------------------------------------------------------------------
# Storage: refcounted allocation accounting (§5.5)
# ----------------------------------------------------------------------

class Storage:
    """Owns one accounting block in the caching allocator.

    Python's refcounting destroys this object the moment the last Tensor
    (or autograd closure) referencing it dies, returning the block to the
    allocator pool immediately — no deferred GC (§5.5).
    """

    __slots__ = ("nbytes", "_block", "stream_id")

    def __init__(self, nbytes: int, stream_id: int):
        self.nbytes = nbytes
        self.stream_id = stream_id
        self._block = _alloc.device_allocator().allocate(nbytes, stream_id)

    def __del__(self):
        try:
            _alloc.device_allocator().free(self._block)
        except Exception:
            pass


def _nbytes_of(data: Array) -> int:
    try:
        return math.prod(data.shape) * data.dtype.itemsize
    except Exception:
        return 0


_inexact_cache: dict = {}


def _is_inexact(dtype) -> bool:
    """Cached ``jnp.issubdtype(dtype, jnp.inexact)`` — on the per-op hot
    path twice per operand."""
    r = _inexact_cache.get(dtype)
    if r is None:
        r = _inexact_cache[dtype] = bool(
            jnp.issubdtype(dtype, jnp.inexact))
    return r


def _is_tracer(x: Any) -> bool:
    return isinstance(x, jax.core.Tracer)


# ----------------------------------------------------------------------
# Tensor
# ----------------------------------------------------------------------

class Tensor:
    """Operator-overloaded eager tensor over a ``jax.Array``.

    The define-by-run surface of the framework: arithmetic/indexing
    build autograd tape nodes as they execute, ``backward()`` walks the
    tape, in-place ops bump a version counter so stale autograd
    references fail loudly, and views write through to their base.
    Ops dispatch through the signature-keyed executable cache
    (``core.dispatch``); inside ``with repro.fuse.fusion():``
    elementwise chains defer and flush as one fused kernel.
    """

    __slots__ = (
        "_d",           # the jax.Array (None while a fusion chain pends)
        "_pending",     # fuse.PendingOp when lazily enqueued, else None
        "requires_grad",
        "grad",
        "grad_fn",
        "_output_index",
        "_version",
        "_storage",
        "_base",        # for views: the viewed-into tensor
        "_view_index",  # the indexing expression creating the view
        "__weakref__",
    )

    # ``_data`` is the materialization funnel: reading it flushes any
    # pending fusion chain; every path that needs concrete values
    # (numpy(), reductions via _apply_op, backward, jit boundaries)
    # goes through here.
    @property
    def _data(self) -> Array:
        if self._pending is not None:
            _fuse().flush_tensor(self)
        return self._d

    @_data.setter
    def _data(self, value) -> None:
        self._d = value
        self._pending = None

    def __init__(self, data: Any, requires_grad: bool = False,
                 _storage: Optional[Storage] = None,
                 _version: Optional[VersionCounter] = None):
        if isinstance(data, Tensor):
            data = data._data
        if not isinstance(data, (jax.Array, jax.core.Tracer)):
            data = jnp.asarray(data)
        if requires_grad and not jnp.issubdtype(data.dtype, jnp.inexact):
            raise RuntimeError(
                "Only Tensors of floating point and complex dtype can "
                "require gradients"
            )
        self._data = data
        self.requires_grad = requires_grad
        self.grad: Optional[Tensor] = None
        self.grad_fn: Optional[Node] = None
        self._output_index = 0
        self._version = _version if _version is not None else VersionCounter()
        self._base: Optional[Tensor] = None
        self._view_index = None
        if _storage is not None:
            self._storage = _storage
        elif _is_tracer(data):
            self._storage = None  # tracing: XLA owns memory
        else:
            self._storage = Storage(
                _nbytes_of(data), _stream.current_stream().stream_id
            )

    # -- basic properties ----------------------------------------------
    @property
    def data(self) -> Array:
        return self._data

    @data.setter
    def data(self, value):
        self._data = value._data if isinstance(value, Tensor) else value

    @property
    def shape(self) -> Tuple[int, ...]:
        # metadata reads must not force a pending chain to materialize
        if self._pending is not None:
            return self._pending.shape
        return tuple(self._d.shape)

    @property
    def dtype(self):
        if self._pending is not None:
            return self._pending.dtype
        return self._d.dtype

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size_bytes(self) -> int:
        return int(np.prod(self.shape) if self.shape else 1) * \
            np.dtype(self.dtype).itemsize

    @property
    def is_leaf(self) -> bool:
        return self.grad_fn is None

    @property
    def device(self):
        try:
            return next(iter(self._data.devices()))
        except Exception:
            return jax.devices()[0]

    def size(self, dim: Optional[int] = None):
        return self.shape if dim is None else self.shape[dim]

    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def dim(self) -> int:
        return self.ndim

    def numpy(self) -> np.ndarray:
        return np.asarray(self._data)

    def item(self):
        return self._data.item()

    def tolist(self):
        return np.asarray(self._data).tolist()

    def __len__(self):
        return self.shape[0]

    def __repr__(self):
        grad_part = ""
        if self.grad_fn is not None:
            grad_part = f", grad_fn=<{self.grad_fn.name}>"
        elif self.requires_grad:
            grad_part = ", requires_grad=True"
        if _is_tracer(self._data):
            return f"Tensor(<traced {self.shape} {self.dtype}>{grad_part})"
        return f"Tensor({np.asarray(self._data)!r}{grad_part})"

    def __hash__(self):
        return id(self)

    def __bool__(self):
        return bool(self._data)

    # -- autograd --------------------------------------------------------
    def backward(self, gradient: Optional["Tensor"] = None,
                 retain_graph: bool = False) -> None:
        _backward(self, [gradient] if gradient is not None else None,
                  retain_graph=retain_graph)

    def _accumulate_grad(self, g: Array) -> None:
        if self.grad is None:
            self.grad = Tensor(g)
        else:
            self.grad = Tensor(self.grad._data + g)

    def detach(self) -> "Tensor":
        t = Tensor(self._data, _storage=self._storage,
                   _version=self._version)
        return t

    def detach_(self) -> "Tensor":
        self.grad_fn = None
        self.requires_grad = False
        return self

    def requires_grad_(self, flag: bool = True) -> "Tensor":
        if flag and not jnp.issubdtype(self.dtype, jnp.inexact):
            raise RuntimeError(
                "Only Tensors of floating point and complex dtype can "
                "require gradients"
            )
        self.requires_grad = flag
        return self

    def clone(self) -> "Tensor":
        return _apply_op("clone", lambda x: x + 0, self, static=())

    def retain_grad(self) -> "Tensor":
        # non-leaf grads: wrap identity so engine treats as leaf-like sink
        self.requires_grad = True
        return self

    # -- dtype / device movement ----------------------------------------
    def astype(self, dtype) -> "Tensor":
        return _apply_op("astype", lambda x: x.astype(dtype), self,
                         static=(np.dtype(dtype).name,))

    def to(self, dtype=None) -> "Tensor":
        if dtype is None:
            return self
        return self.astype(dtype)

    def float(self):
        return self.astype(jnp.float32)

    def bfloat16(self):
        return self.astype(jnp.bfloat16)

    def half(self):
        return self.astype(jnp.float16)

    def int(self):
        return self.astype(jnp.int32)

    def bool(self):
        return self.astype(jnp.bool_)

    def cpu(self):
        return self

    def cuda(self):
        return self

    # -- arithmetic (operator overloading: the define-by-run surface) ----
    def __add__(self, other):
        return add(self, other)

    __radd__ = __add__

    def __sub__(self, other):
        return sub(self, other)

    def __rsub__(self, other):
        return sub(_coerce(other, like=self), self)

    def __mul__(self, other):
        return mul(self, other)

    __rmul__ = __mul__

    def __truediv__(self, other):
        return div(self, other)

    def __rtruediv__(self, other):
        return div(_coerce(other, like=self), self)

    def __pow__(self, other):
        return pow_(self, other)

    def __rpow__(self, other):
        return pow_(_coerce(other, like=self), self)

    def __matmul__(self, other):
        return matmul(self, other)

    def __rmatmul__(self, other):
        return matmul(_coerce(other, like=self), self)

    def __neg__(self):
        return _apply_op("neg", lambda x: -x, self, static=())

    def __abs__(self):
        return _apply_op("abs", jnp.abs, self, static=())

    def __mod__(self, other):
        return _apply_op("mod", jnp.mod, self, _coerce(other, like=self),
                         static=())

    # comparisons (non-differentiable)
    def __eq__(self, other):  # type: ignore[override]
        return Tensor(self._data == _raw(other))

    def __ne__(self, other):  # type: ignore[override]
        return Tensor(self._data != _raw(other))

    def __lt__(self, other):
        return Tensor(self._data < _raw(other))

    def __le__(self, other):
        return Tensor(self._data <= _raw(other))

    def __gt__(self, other):
        return Tensor(self._data > _raw(other))

    def __ge__(self, other):
        return Tensor(self._data >= _raw(other))

    # -- indexing ---------------------------------------------------------
    def __getitem__(self, index) -> "Tensor":
        index = _raw_index(index)
        tok = _hashable_index_token(index)
        out = _apply_op("getitem", lambda x: x[index], self,
                        static=(tok,) if tok is not None else None)
        # basic-indexing results are views: share version counter so
        # mutation through either side is detected / written through.
        if _is_basic_index(index):
            out._version = self._version
            out._base = self._base if self._base is not None else self
            out._view_index = index
            out._storage = self._storage
        return out

    def __setitem__(self, index, value) -> None:
        index = _raw_index(index)
        self._inplace_guard("__setitem__")
        val = _raw(value)
        self._write_through(lambda x: x.at[index].set(val))

    # -- in-place ops (mutation; §4.3 versioning) -------------------------
    def _inplace_guard(self, opname: str) -> None:
        if self.requires_grad and self.grad_fn is None and is_grad_enabled():
            raise RuntimeError(
                f"a leaf Variable that requires grad is being used in an "
                f"in-place operation ({opname})"
            )

    def _write_through(self, fn: Callable[[Array], Array]) -> None:
        """Apply ``fn`` to this tensor's data, writing through views to the
        base storage, and bump the shared version counter."""
        # mutation is a fusion barrier: pending chains captured this
        # tensor's pre-mutation value, so they must materialize first
        _fuse().flush_all()
        if self._base is not None:
            base = self._base
            idx = self._view_index
            new_base = base._data.at[idx].set(fn(base._data[idx]))
            base._data = new_base
            self._data = new_base[idx]
        else:
            self._data = fn(self._data)
        self._version.bump()

    def _inplace_binary(self, opname: str, fn, other, alpha=None):
        self._inplace_guard(opname)
        _fuse().flush_all()  # mutation is a fusion barrier
        o = _raw(other)
        if alpha is not None:
            o = o * alpha
        if (is_grad_enabled()
                and self.grad_fn is not None
                and jnp.issubdtype(self.dtype, jnp.inexact)
                and not _is_tracer(self._data)):
            # differentiable in-place: record as out-of-place op against a
            # snapshot of the pre-mutation value (so the new node points at
            # the OLD grad_fn, not at itself), then mutate this object.
            # The version bump happens BEFORE the node records its saved
            # versions: this very op is consistent with the new version,
            # while any later mutation is still caught.
            self._version.bump()
            snapshot = Tensor(self._data, _storage=self._storage,
                              _version=self._version)
            snapshot.grad_fn = self.grad_fn
            snapshot._output_index = self._output_index
            snapshot.requires_grad = self.requires_grad
            other_t = other if isinstance(other, Tensor) else Tensor(o)
            res = _apply_op(opname, fn, snapshot, other_t, static=())
            self._data = res._data
            self.grad_fn = res.grad_fn
            self._output_index = res._output_index
            # the mutated tensor starts a fresh version lineage: the
            # recorded node holds the OLD counter via the snapshot, so
            # chained differentiable in-place ops don't trip each other
            self._version = VersionCounter()
        else:
            self._write_through(lambda x: fn(x, o))
        return self

    def add_(self, other, alpha=None):
        return self._inplace_binary("add_", jnp.add, other, alpha)

    def sub_(self, other, alpha=None):
        return self._inplace_binary("sub_", jnp.subtract, other, alpha)

    def mul_(self, other):
        return self._inplace_binary("mul_", jnp.multiply, other)

    def div_(self, other):
        return self._inplace_binary("div_", jnp.divide, other)

    def zero_(self):
        self._write_through(lambda x: jnp.zeros_like(x))
        return self

    def fill_(self, value):
        self._write_through(lambda x: jnp.full_like(x, value))
        return self

    def copy_(self, other):
        src = _raw(other)
        self._write_through(lambda x: jnp.broadcast_to(src, x.shape).astype(x.dtype))
        return self

    def clamp_(self, min=None, max=None):
        self._write_through(lambda x: jnp.clip(x, min, max))
        return self

    # -- shape ops ---------------------------------------------------------
    def reshape(self, *shape) -> "Tensor":
        shape = _norm_shape(shape)
        return _apply_op("reshape", lambda x: x.reshape(shape), self,
                         static=(shape,))

    view = reshape

    def transpose(self, dim0: int, dim1: int) -> "Tensor":
        perm = list(range(self.ndim))
        perm[dim0], perm[dim1] = perm[dim1], perm[dim0]
        return _apply_op("transpose", lambda x: jnp.transpose(x, perm), self,
                         static=(tuple(perm),))

    def permute(self, *dims) -> "Tensor":
        dims = _norm_shape(dims)
        return _apply_op("permute", lambda x: jnp.transpose(x, dims), self,
                         static=(dims,))

    @property
    def T(self) -> "Tensor":
        return _apply_op("T", lambda x: x.T, self, static=())

    def squeeze(self, dim: Optional[int] = None) -> "Tensor":
        return _apply_op("squeeze", lambda x: jnp.squeeze(x, dim), self,
                         static=(dim,))

    def unsqueeze(self, dim: int) -> "Tensor":
        return _apply_op("unsqueeze", lambda x: jnp.expand_dims(x, dim),
                         self, static=(dim,))

    def flatten(self, start_dim: int = 0, end_dim: int = -1) -> "Tensor":
        shape = self.shape
        end = end_dim % self.ndim
        new = shape[:start_dim] + (-1,) + shape[end + 1:]
        return self.reshape(new)

    def expand(self, *sizes) -> "Tensor":
        sizes = _norm_shape(sizes)
        tgt = tuple(
            s if s != -1 else self.shape[i - (len(sizes) - self.ndim)]
            for i, s in enumerate(sizes)
        )
        return _apply_op("expand", lambda x: jnp.broadcast_to(x, tgt), self,
                         static=(tgt,))

    def repeat(self, *reps) -> "Tensor":
        reps = _norm_shape(reps)
        return _apply_op("repeat", lambda x: jnp.tile(x, reps), self,
                         static=(reps,))

    def chunk(self, chunks: int, dim: int = 0):
        return split(self, self.shape[dim] // chunks, dim)

    def split(self, size: int, dim: int = 0):
        return split(self, size, dim)

    # -- math methods -------------------------------------------------------
    def sum(self, dim=None, keepdim: bool = False):
        return _apply_op("sum", lambda x: jnp.sum(x, axis=dim,
                                                  keepdims=keepdim), self,
                         static=(_hashable_axis(dim), keepdim))

    def mean(self, dim=None, keepdim: bool = False):
        return _apply_op("mean", lambda x: jnp.mean(x, axis=dim,
                                                    keepdims=keepdim), self,
                         static=(_hashable_axis(dim), keepdim))

    def var(self, dim=None, keepdim: bool = False, unbiased: bool = True):
        ddof = 1 if unbiased else 0
        return _apply_op("var", lambda x: jnp.var(x, axis=dim, ddof=ddof,
                                                  keepdims=keepdim), self,
                         static=(_hashable_axis(dim), keepdim, ddof))

    def std(self, dim=None, keepdim: bool = False, unbiased: bool = True):
        ddof = 1 if unbiased else 0
        return _apply_op("std", lambda x: jnp.std(x, axis=dim, ddof=ddof,
                                                  keepdims=keepdim), self,
                         static=(_hashable_axis(dim), keepdim, ddof))

    def max(self, dim=None, keepdim: bool = False):
        if dim is None:
            return _apply_op("max", jnp.max, self, static=())
        values = _apply_op(
            "max", lambda x: jnp.max(x, axis=dim, keepdims=keepdim), self,
            static=(_hashable_axis(dim), keepdim))
        indices = Tensor(jnp.argmax(self._data, axis=dim))
        return values, indices

    def min(self, dim=None, keepdim: bool = False):
        if dim is None:
            return _apply_op("min", jnp.min, self, static=())
        values = _apply_op(
            "min", lambda x: jnp.min(x, axis=dim, keepdims=keepdim), self,
            static=(_hashable_axis(dim), keepdim))
        indices = Tensor(jnp.argmin(self._data, axis=dim))
        return values, indices

    def argmax(self, dim=None):
        return Tensor(jnp.argmax(self._data, axis=dim))

    def argmin(self, dim=None):
        return Tensor(jnp.argmin(self._data, axis=dim))

    def prod(self, dim=None, keepdim: bool = False):
        return _apply_op("prod", lambda x: jnp.prod(x, axis=dim,
                                                    keepdims=keepdim), self,
                         static=(_hashable_axis(dim), keepdim))

    def cumsum(self, dim: int):
        return _apply_op("cumsum", lambda x: jnp.cumsum(x, axis=dim), self,
                         static=(dim,))

    def exp(self):
        return _apply_op("exp", jnp.exp, self, static=())

    def log(self):
        return _apply_op("log", jnp.log, self, static=())

    def sqrt(self):
        return _apply_op("sqrt", jnp.sqrt, self, static=())

    def rsqrt(self):
        return _apply_op("rsqrt", lambda x: jax.lax.rsqrt(x), self,
                         static=())

    def abs(self):
        return _apply_op("abs", jnp.abs, self, static=())

    def sin(self):
        return _apply_op("sin", jnp.sin, self, static=())

    def cos(self):
        return _apply_op("cos", jnp.cos, self, static=())

    def tanh(self):
        return _apply_op("tanh", jnp.tanh, self, static=())

    def sigmoid(self):
        return _apply_op("sigmoid", jax.nn.sigmoid, self, static=())

    def relu(self):
        return _apply_op("relu", jax.nn.relu, self, static=())

    def erf(self):
        return _apply_op("erf", jax.scipy.special.erf, self, static=())

    def clamp(self, min=None, max=None):
        return _apply_op("clamp", lambda x: jnp.clip(x, min, max), self,
                         static=(min, max))

    def softmax(self, dim: int = -1):
        return _apply_op("softmax",
                         lambda x: jax.nn.softmax(x, axis=dim), self,
                         static=(dim,))

    def log_softmax(self, dim: int = -1):
        return _apply_op("log_softmax",
                         lambda x: jax.nn.log_softmax(x, axis=dim), self,
                         static=(dim,))

    def masked_fill(self, mask, value):
        return _apply_op("masked_fill",
                         lambda x, m: jnp.where(m, value, x), self,
                         _coerce(mask), static=(value,))

    def matmul(self, other):
        return matmul(self, other)

    mm = matmul
    bmm = matmul

    def dot(self, other):
        return matmul(self, other)

    def record_stream(self, s: "_stream.Stream") -> None:
        """Mark this tensor as used on stream ``s`` (cross-stream safety,
        §5.3): its storage free will then require a sync before reuse."""
        if self._storage is not None:
            _alloc.device_allocator().free  # accounting path exists
            self._storage.stream_id = s.stream_id


# ----------------------------------------------------------------------
# op dispatcher: forward + tape recording
# ----------------------------------------------------------------------

def _raw(x: Any) -> Any:
    return x._data if isinstance(x, Tensor) else x


def _raw_index_item(i):
    i = _raw(i)
    # torch allows list indices (`x[[0, 2]]`); jax wants real arrays
    if isinstance(i, list):
        return jnp.asarray(i)
    return i


def _raw_index(index):
    if isinstance(index, tuple):
        return tuple(_raw_index_item(i) for i in index)
    return _raw_index_item(index)


def _is_basic_index(index) -> bool:
    items = index if isinstance(index, tuple) else (index,)
    return all(isinstance(i, (int, slice, type(Ellipsis), type(None)))
               for i in items)


def _hashable_axis(dim):
    """Reduction axes as a cache-key token (lists become tuples)."""
    return tuple(dim) if isinstance(dim, list) else dim


def _hashable_index_token(index):
    """A hashable token for a basic index expression, or ``None`` for
    advanced (array) indexing — which then dispatches uncached.  Needed
    because ``slice`` is unhashable before Python 3.12."""
    items = index if isinstance(index, tuple) else (index,)
    toks = []
    for i in items:
        if isinstance(i, (bool, np.bool_)):
            # bool is an int subclass: x[True] must not replay x[1]
            toks.append(("b", bool(i)))
        elif isinstance(i, (int, np.integer)):
            toks.append(("i", int(i)))
        elif i is None:
            toks.append(("n",))
        elif i is Ellipsis:
            toks.append(("e",))
        elif isinstance(i, slice):
            parts = (i.start, i.stop, i.step)
            if not all(isinstance(v, (int, np.integer, type(None)))
                       for v in parts):
                return None
            toks.append(("s",) + tuple(
                int(v) if v is not None else None for v in parts))
        else:
            return None
    return tuple(toks)


_scalar_cache: dict = {}


def _coerce(x: Any, like: Optional[Tensor] = None) -> Tensor:
    if isinstance(x, Tensor):
        return x
    if type(x) in (int, float, bool):
        # hot path: `t * 2.0` pays a device transfer per dispatch unless
        # the scalar constant is cached (jax arrays are immutable, so
        # sharing the buffer across Tensors is safe)
        dt = like.dtype if (like is not None and _is_inexact(like.dtype)) \
            else None
        key = (type(x), x, str(dt))
        arr = _scalar_cache.get(key)
        if arr is None:
            arr = jnp.asarray(x) if dt is None \
                else jnp.asarray(x, dtype=dt)
            if len(_scalar_cache) > 1024:
                _scalar_cache.clear()
            _scalar_cache[key] = arr
        return Tensor(arr)
    arr = jnp.asarray(x)
    if (like is not None and _is_inexact(like.dtype)
            and not _is_inexact(arr.dtype)):
        arr = arr.astype(like.dtype)
    elif (like is not None and _is_inexact(like.dtype)
            and arr.dtype != like.dtype and np.isscalar(x)):
        arr = arr.astype(like.dtype)
    return Tensor(arr)


def _wrap_outputs(raw, node: Optional[Node]):
    """Wrap raw jnp outputs in Tensors attached to ``node``."""
    single = not isinstance(raw, tuple)
    outs = (raw,) if single else raw
    tensors = []
    for i, o in enumerate(outs):
        t = Tensor(o)
        if node is not None:
            t.grad_fn = node
            t._output_index = i
        tensors.append(t)
    _stream.current_stream().enqueue(*[t._data for t in tensors])
    return tensors[0] if single else tuple(tensors)


_STATIC_OK_TYPES = (int, float, bool, str, bytes, type(None), type,
                    type(Ellipsis), np.dtype)


def _static_ok(static) -> bool:
    """True when a static descriptor is safe to use as a cache-key
    component: plain hashable scalars/axes/dtypes only.  Tensors are
    hashable (by id) but must NOT be baked into a cached closure — data
    would go stale under mutation — so they disqualify the key."""
    if isinstance(static, tuple):
        return all(_static_ok(s) for s in static)
    if isinstance(static, _STATIC_OK_TYPES):
        return True
    return isinstance(static, np.integer) or isinstance(static, np.floating)


def _apply_op(name: str, fn: Callable, *tensors: Tensor,
              num_outputs: int = 1, static=None):
    """Execute ``fn`` over tensor data; record a tape node when needed.

    This is the single funnel for every differentiable eager op.  Inside a
    ``jax.jit`` trace (tracer operands) the tape is skipped entirely and the
    op lowers to XLA — the compiled path differentiates via JAX AD.

    ``static`` is the dispatch-cache contract: a hashable tuple naming
    everything ``fn``'s closure captures besides the tensor operands.
    When supplied, repeated dispatches with the same signature replay
    cached jitted executables instead of re-tracing ``jax.vjp``; when
    ``None`` (or unhashable), the op takes the legacy uncached path.
    """
    cacheable = static is not None and _static_ok(static)

    # Elementwise fusion queue: defer the op entirely, returning a
    # pending tensor that records the chain (flushed as ONE kernel at a
    # materialization point).  Must run before touching operand data.
    if cacheable and num_outputs == 1:
        pending = _fuse().try_enqueue(name, fn, static, tensors)
        if pending is not None:
            return pending

    datas = [t._data for t in tensors]
    tracing = any(_is_tracer(d) for d in datas)

    diffable = [
        i for i, t in enumerate(tensors) if _is_inexact(t.dtype)
    ]
    needs_grad = (
        not tracing
        and is_grad_enabled()
        and any(tensors[i].requires_grad or tensors[i].grad_fn is not None
                for i in diffable)
    )

    entry = None
    if tracing:
        # dispatch-cache-aware compile: a repro.compile(seed_cache=True)
        # trace pre-creates eager entries from the traced signatures
        if cacheable and _dispatch.seeding_enabled() \
                and _dispatch.is_enabled():
            _dispatch.seed_op(name, static, datas, fn, diffable)
    elif _dispatch.is_enabled():
        cache = _dispatch.dispatch_cache()
        if not cacheable:
            if static is not None:
                cache.record_fallback(name)
            else:
                cache.record_uncached(name)
        else:
            key = _dispatch.make_key(name, static, datas, needs_grad)
            if key is None:
                cache.record_fallback(name)
            else:
                entry = cache.get_or_create(key, fn, diffable, len(datas))

    if not needs_grad:
        raw = entry.fwd(*datas) if entry is not None else fn(*datas)
        return _wrap_outputs(raw, None)

    if entry is not None:
        # warm path: jitted forward replay + jitted VJP replay closure
        out = entry.fwd(*datas)
        bwd = entry.bwd()
        saved = tuple(datas)
        vjp_fn = lambda cot: bwd(saved, cot)  # noqa: E731
        inputs = (list(tensors) if len(diffable) == len(datas)
                  else [tensors[i] for i in diffable])
    else:
        out, vjp_fn = _dispatch.partial_vjp(fn, datas, diffable)
        inputs = (list(tensors) if len(diffable) == len(datas)
                  else [tensors[i] for i in diffable])

    node = Node(name, vjp_fn, inputs, num_outputs=num_outputs)
    outs = out if isinstance(out, tuple) else (out,)
    node.metadata["out_avals"] = [(o.shape, o.dtype) for o in outs]
    for t in inputs:
        node.save_version(t)
    return _wrap_outputs(out, node)


# ----------------------------------------------------------------------
# module-level functional ops
# ----------------------------------------------------------------------

def add(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("add", jnp.add, a, b, static=())


def sub(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("sub", jnp.subtract, a, b, static=())


def mul(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("mul", jnp.multiply, a, b, static=())


def div(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("div", jnp.divide, a, b, static=())


def pow_(a, b):
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("pow", jnp.power, a, b, static=())


def matmul(a, b):
    """Matrix product ``a @ b`` (same as the ``@`` operator)."""
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("matmul", jnp.matmul, a, b, static=())


def maximum(a, b):
    """Elementwise maximum of two tensors (broadcasting)."""
    a, b = _coerce(a), _coerce(b)
    return _apply_op("maximum", jnp.maximum, a, b, static=())


def minimum(a, b):
    """Elementwise minimum of two tensors (broadcasting)."""
    a, b = _coerce(a), _coerce(b)
    return _apply_op("minimum", jnp.minimum, a, b, static=())


def where(cond, a, b):
    """Elementwise select: ``a`` where ``cond`` is true, else ``b``."""
    cond = _coerce(cond)
    a = _coerce(a)
    b = _coerce(b, like=a)
    return _apply_op("where", jnp.where, cond, a, b, static=())


def cat(tensors: Sequence[Tensor], dim: int = 0) -> Tensor:
    """Concatenate tensors along ``dim`` (alias: ``concat``)."""
    tensors = [_coerce(t) for t in tensors]
    return _apply_op("cat", lambda *xs: jnp.concatenate(xs, axis=dim),
                     *tensors, static=(dim,))


concat = cat


def stack(tensors: Sequence[Tensor], dim: int = 0) -> Tensor:
    """Stack tensors along a NEW axis ``dim``."""
    tensors = [_coerce(t) for t in tensors]
    return _apply_op("stack", lambda *xs: jnp.stack(xs, axis=dim),
                     *tensors, static=(dim,))


def split(t: Tensor, size: int, dim: int = 0):
    """Split ``t`` into chunks of ``size`` along ``dim`` (last chunk
    may be smaller).  Returns a tuple of views."""
    n = t.shape[dim]
    pieces = []
    for start in range(0, n, size):
        idx = [slice(None)] * t.ndim
        idx[dim] = slice(start, min(start + size, n))
        pieces.append(t[tuple(idx)])
    return tuple(pieces)


def einsum(subscripts: str, *tensors) -> Tensor:
    """Einstein-summation contraction, e.g. ``einsum("ij,jk->ik", a, b)``."""
    tensors = [_coerce(t) for t in tensors]
    return _apply_op("einsum",
                     lambda *xs: jnp.einsum(subscripts, *xs), *tensors,
                     static=(subscripts,))


def logsumexp(t: Tensor, dim=None, keepdim: bool = False) -> Tensor:
    """Numerically stable ``log(sum(exp(t)))`` over ``dim``."""
    return _apply_op(
        "logsumexp",
        lambda x: jax.scipy.special.logsumexp(x, axis=dim, keepdims=keepdim),
        _coerce(t), static=(_hashable_axis(dim), keepdim))


def exp(t):
    return _coerce(t).exp()


def log(t):
    return _coerce(t).log()


def sqrt(t):
    return _coerce(t).sqrt()


def tanh(t):
    return _coerce(t).tanh()


def sigmoid(t):
    return _coerce(t).sigmoid()


def relu(t):
    return _coerce(t).relu()


def softmax(t, dim: int = -1):
    """Softmax over ``dim`` (statistics computed in f32)."""
    return _coerce(t).softmax(dim)


def tril(t, k: int = 0):
    """Lower-triangular part of ``t`` (zero above diagonal ``k``)."""
    return _apply_op("tril", lambda x: jnp.tril(x, k), _coerce(t),
                     static=(k,))


def triu(t, k: int = 0):
    """Upper-triangular part of ``t`` (zero below diagonal ``k``)."""
    return _apply_op("triu", lambda x: jnp.triu(x, k), _coerce(t),
                     static=(k,))


def take_along_dim(t, indices, dim: int):
    """Gather values along ``dim`` at ``indices`` (torch.take_along_dim;
    indices ride as a non-differentiable operand, never a static)."""
    return _apply_op("take_along_dim",
                     lambda x, i: jnp.take_along_axis(x, i, axis=dim),
                     _coerce(t), _coerce(indices), static=(dim,))


def one_hot(t, num_classes: int, dtype=jnp.float32):
    """One-hot encode integer tensor ``t`` to ``num_classes`` columns."""
    return Tensor(jax.nn.one_hot(_raw(t), num_classes, dtype=dtype))


def _norm_shape(shape):
    if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
        return tuple(shape[0])
    return tuple(shape)


# ----------------------------------------------------------------------
# factories + RNG
# ----------------------------------------------------------------------

_rng_lock = threading.Lock()
_np_rng = np.random.default_rng(0)


def manual_seed(seed: int) -> None:
    """Re-seed the host RNG behind ``randn``/``rand``/``randint``/
    ``normal``/``uniform`` (reproducible eager initialization)."""
    global _np_rng
    with _rng_lock:
        _np_rng = np.random.default_rng(seed)


def _factory(arr, dtype=None, requires_grad: bool = False) -> Tensor:
    data = jnp.asarray(arr)
    if dtype is not None:
        data = data.astype(dtype)
    return Tensor(data, requires_grad=requires_grad)


def tensor(data, dtype=None, requires_grad: bool = False) -> Tensor:
    """Build a Tensor from array-like ``data`` (list, numpy, scalar)."""
    return _factory(data, dtype, requires_grad)


def zeros(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    """All-zeros tensor of ``shape``."""
    return Tensor(jnp.zeros(_norm_shape(shape), dtype), requires_grad)


def ones(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    """All-ones tensor of ``shape``."""
    return Tensor(jnp.ones(_norm_shape(shape), dtype), requires_grad)


def full(shape, fill_value, dtype=jnp.float32,
         requires_grad: bool = False) -> Tensor:
    """Tensor of ``shape`` filled with ``fill_value``."""
    return Tensor(jnp.full(shape, fill_value, dtype), requires_grad)


def empty(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    """Uninitialized-by-contract tensor (zeros under XLA)."""
    return zeros(*shape, dtype=dtype, requires_grad=requires_grad)


def zeros_like(t, dtype=None) -> Tensor:
    """All-zeros tensor with ``t``'s shape (and dtype unless given)."""
    return Tensor(jnp.zeros_like(_raw(t), dtype=dtype))


def ones_like(t, dtype=None) -> Tensor:
    """All-ones tensor with ``t``'s shape (and dtype unless given)."""
    return Tensor(jnp.ones_like(_raw(t), dtype=dtype))


def arange(*args, dtype=None) -> Tensor:
    """``arange(stop)`` / ``arange(start, stop[, step])`` range tensor."""
    return Tensor(jnp.arange(*args, dtype=dtype))


def eye(n, m=None, dtype=jnp.float32) -> Tensor:
    """Identity matrix of shape (n, m or n)."""
    return Tensor(jnp.eye(n, m, dtype=dtype))


def randn(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    """Standard-normal tensor of ``shape`` (host RNG; ``manual_seed``)."""
    with _rng_lock:
        arr = _np_rng.standard_normal(_norm_shape(shape), dtype=np.float32)
    return _factory(arr, dtype, requires_grad)


def rand(*shape, dtype=jnp.float32, requires_grad: bool = False) -> Tensor:
    """Uniform-[0, 1) tensor of ``shape`` (host RNG; ``manual_seed``)."""
    with _rng_lock:
        arr = _np_rng.random(_norm_shape(shape), dtype=np.float32)
    return _factory(arr, dtype, requires_grad)


def randint(low, high, shape, dtype=jnp.int32) -> Tensor:
    """Integer tensor uniform in [low, high) of ``shape``."""
    with _rng_lock:
        arr = _np_rng.integers(low, high, size=shape)
    return _factory(arr, dtype)


def normal(mean: float, std: float, shape, dtype=jnp.float32,
           requires_grad: bool = False) -> Tensor:
    """Normal(mean, std) tensor of ``shape`` (host RNG; ``manual_seed``)."""
    with _rng_lock:
        arr = _np_rng.normal(mean, std, size=shape).astype(np.float32)
    return _factory(arr, dtype, requires_grad)


def uniform(low: float, high: float, shape, dtype=jnp.float32,
            requires_grad: bool = False) -> Tensor:
    """Uniform-[low, high) tensor of ``shape`` (host RNG; ``manual_seed``)."""
    with _rng_lock:
        arr = _np_rng.uniform(low, high, size=shape).astype(np.float32)
    return _factory(arr, dtype, requires_grad)


def from_numpy(arr: np.ndarray) -> Tensor:
    """Zero-copy-intent interop (§4.2): on CPU backends jax aliases the
    numpy buffer when dtype/layout allow."""
    return Tensor(jnp.asarray(arr))


# ----------------------------------------------------------------------
# pytree registration: Tensors flow through jit/pjit/scan transparently
# ----------------------------------------------------------------------

def _tensor_flatten(t: Tensor):
    return (t._data,), (t.requires_grad,)


def _tensor_unflatten(aux, children):
    (data,) = children
    t = Tensor.__new__(Tensor)
    t._data = data if isinstance(data, (jax.Array, jax.core.Tracer)) \
        else jnp.asarray(data)
    t.requires_grad = aux[0]
    t.grad = None
    t.grad_fn = None
    t._output_index = 0
    t._version = VersionCounter()
    t._base = None
    t._view_index = None
    t._storage = None
    return t


jax.tree_util.register_pytree_node(Tensor, _tensor_flatten, _tensor_unflatten)
