"""Alias for the ``repro.core.tensor`` *module* (the package attribute is
shadowed by the ``tensor()`` factory re-export)."""
import importlib as _importlib
import sys as _sys

_sys.modules[__name__] = _importlib.import_module("repro.core.tensor")
