"""Streams and events (paper §5.2): separate control and data flow.

PyTorch queues CUDA kernels onto hardware FIFOs so host control flow runs
ahead of device compute.  JAX's runtime already dispatches asynchronously —
``jnp`` calls return futures-like Arrays immediately and only
``block_until_ready`` joins.  This module makes that implicit machinery an
explicit, PyTorch-shaped API:

* ``Stream`` — an ordered work queue.  Eager ops dispatch on the *current*
  stream; tensors remember their stream so the allocator can keep one block
  pool per stream (§5.3) and flag cross-stream reuse.
* ``Event`` — record/wait/synchronize for cross-stream ordering.
* ``current_stream() / stream(s)`` — context manager mirroring
  ``torch.cuda.stream``.

On a single host device all streams map onto the one XLA dispatch queue, so
``wait_stream`` degenerates to ordering bookkeeping — but the *semantics*
(allocator pools, cross-stream sync requirements, per-stream pending work)
are fully exercised and tested, and carry over unchanged to a multi-queue
backend.
"""

from __future__ import annotations

import threading
import time
from typing import Any, List, Optional

import jax

from . import allocator as _alloc


class Stream:
    """An ordered queue of device work (§5.1): ops enqueue results here
    so the host can run ahead; ``synchronize()`` joins the tail.  The
    caching allocator keeps one block pool per stream."""

    _next_id = 0
    _lock = threading.Lock()

    def __init__(self, priority: int = 0):
        with Stream._lock:
            self.stream_id = Stream._next_id
            Stream._next_id += 1
        self.priority = priority
        # Tail of asynchronously dispatched work: jax Arrays not yet known
        # to be ready.  Bounded ring so host can run ahead without leaking.
        self._pending: List[Any] = []
        self._max_pending = 64

    # -- dispatch ------------------------------------------------------
    def enqueue(self, *arrays: Any) -> None:
        """Note asynchronously-dispatched results on this stream."""
        for a in arrays:
            if isinstance(a, jax.Array):
                self._pending.append(a)
        if len(self._pending) > self._max_pending:
            # keep the queue bounded: oldest work is almost surely done
            del self._pending[: -self._max_pending]

    def synchronize(self) -> None:
        """Block the host until all work on this stream has completed."""
        for a in self._pending:
            try:
                a.block_until_ready()
            except Exception:
                pass
        self._pending.clear()
        _alloc.device_allocator().synchronize()

    def query(self) -> bool:
        """True if all submitted work has completed."""
        for a in self._pending:
            if not a.is_ready():
                return False
        return True

    def wait_stream(self, other: "Stream") -> None:
        """Make future work on self wait for work already queued on other."""
        other.synchronize()  # single-queue backend: conservative join

    def record_event(self, event: Optional["Event"] = None) -> "Event":
        event = event or Event()
        event.record(self)
        return event

    def wait_event(self, event: "Event") -> None:
        event.wait(self)

    def __repr__(self):
        return f"Stream(id={self.stream_id}, pending={len(self._pending)})"


class Event:
    """Marker on a stream's work (torch.cuda.Event): ``record()`` then
    ``wait()``/``synchronize()``/``query()``; with
    ``enable_timing=True``, ``elapsed_time()`` gives milliseconds."""

    def __init__(self, enable_timing: bool = False):
        self.enable_timing = enable_timing
        self._recorded: Optional[List[Any]] = None
        self._time: Optional[float] = None

    def record(self, stream: Optional[Stream] = None) -> None:
        stream = stream or current_stream()
        self._recorded = list(stream._pending)
        if self.enable_timing:
            self._time = time.perf_counter()

    def wait(self, stream: Optional[Stream] = None) -> None:
        # Future work on `stream` must observe `self`'s work: join here.
        self.synchronize()

    def synchronize(self) -> None:
        if self._recorded:
            for a in self._recorded:
                try:
                    a.block_until_ready()
                except Exception:
                    pass
            self._recorded = None

    def query(self) -> bool:
        if not self._recorded:
            return True
        return all(a.is_ready() for a in self._recorded)

    def elapsed_time(self, end: "Event") -> float:
        """Milliseconds between two timing events."""
        if self._time is None or end._time is None:
            raise RuntimeError("events must be created with enable_timing=True")
        return (end._time - self._time) * 1e3


# -- current-stream state ------------------------------------------------
_tls = threading.local()
_default_stream = Stream()


def default_stream() -> Stream:
    """The process-wide stream ops run on outside ``with stream(s):``."""
    return _default_stream


def current_stream() -> Stream:
    """The stream new work lands on in this thread (default unless a
    ``with repro.stream(s):`` scope is active)."""
    return getattr(_tls, "stream", _default_stream)


class stream:
    """Context manager: ``with repro.stream(s): ...``"""

    def __init__(self, s: Stream):
        self._s = s
        self._prev: Optional[Stream] = None

    def __enter__(self) -> Stream:
        self._prev = current_stream()
        _tls.stream = self._s
        return self._s

    def __exit__(self, *exc) -> None:
        _tls.stream = self._prev


def synchronize() -> None:
    """Device-wide synchronize (torch.cuda.synchronize analogue)."""
    _default_stream.synchronize()
    s = getattr(_tls, "stream", None)
    if s is not None and s is not _default_stream:
        s.synchronize()
