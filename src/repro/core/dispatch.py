"""Signature-keyed eager dispatch cache (the fast path for §5's claim).

Eager dispatch used to pay a full ``jax.vjp`` re-trace on *every* operator
call, so Python + trace overhead dominated the small-op regime the paper
benchmarks in Table 1.  This module removes that cost: each distinct
dispatch *signature*

    (op name, static args, per-input (shape, dtype), grad-enabled flag)

maps to a cached entry holding

  * ``fwd``  — a ``jax.jit`` of the op's forward, traced once and then
    replayed as an XLA executable (a dict lookup + replay per dispatch),
  * ``bwd``  — a lazily-built ``jax.jit`` of ``cot -> jax.vjp(fn,
    *inputs)[1](cot)``.  Residuals are the op's *inputs* (which the tape
    holds alive anyway), so the cached VJP recomputes the forward inside
    the backward executable — the flash-attention-style recompute trade:
    exact gradients, no retracing, and XLA fuses the recompute away for
    elementwise ops.

Cache-key contract: the ``static`` tuple supplied by a call site must
capture **everything** the op closure depends on besides the tensor
operands (axes, dtypes, scalar clamp bounds, ...).  Call sites that cannot
guarantee that pass ``static=None`` and stay uncached.  Unhashable or
array-valued statics fall back to the uncached path and bump a warning
counter instead of raising (``num_fallback_unhashable``).

Invalidation: entries are immutable pure functions of their key — shapes
or dtypes changing produces a *different* key, and in-place tensor
mutation is handled by the autograd version counters, not the cache — so
there is no invalidation protocol beyond wholesale eviction when the
entry table exceeds ``max_entries``.

Observability mirrors the caching allocator's stats API::

    repro.dispatch_cache_stats()   # dict of counters
    repro.reset_dispatch_cache()   # drop entries + zero counters
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax

# ----------------------------------------------------------------------
# stats
# ----------------------------------------------------------------------


@dataclass
class DispatchCacheStats:
    num_hits: int = 0                  # warm dispatch: executable replay
    num_misses: int = 0                # first-signature dispatch: trace
    num_uncached: int = 0              # no static descriptor supplied
    num_fallback_unhashable: int = 0   # statics present but unhashable
    num_evictions: int = 0             # wholesale clears on overflow
    num_seeded: int = 0                # entries pre-created from jit traces
    num_entries: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


_PER_OP_FIELDS = ("hits", "misses", "uncached", "fallback_unhashable",
                  "seeded")


# ----------------------------------------------------------------------
# cache entries
# ----------------------------------------------------------------------


def partial_vjp(fn: Callable, args: Sequence[Any],
                diffable: Sequence[int]):
    """``jax.vjp`` of ``fn`` w.r.t. the ``diffable`` argument positions
    only, closing over the rest (integer/bool operands).  Returns
    ``(out, vjp_fn)`` where ``vjp_fn`` yields cotangents for the
    diffable positions.  The single implementation behind the cached
    backward, the uncached ``_apply_op`` branch, and fused-chain
    flushes."""
    n = len(args)
    diffable = tuple(diffable)
    if len(diffable) == n:
        return jax.vjp(fn, *args)

    frozen = {i: args[i] for i in range(n) if i not in diffable}

    def fn_diff(*diff_args):
        full = [frozen.get(i) for i in range(n)]
        it = iter(diff_args)
        for i in diffable:
            full[i] = next(it)
        return fn(*full)

    return jax.vjp(fn_diff, *[args[i] for i in diffable])


class CacheEntry:
    """Jitted forward + lazily-built jitted VJP for one dispatch key."""

    __slots__ = ("fwd", "_fn", "_diffable", "_n_args", "_bwd")

    def __init__(self, fn: Callable, diffable: Sequence[int], n_args: int,
                 wrap: Optional[Callable] = None):
        self._fn = fn
        self._diffable = tuple(diffable)
        self._n_args = n_args
        self.fwd = (wrap or jax.jit)(fn)
        self._bwd = None

    def bwd(self) -> Callable:
        """``(inputs_tuple, cotangent) -> input cotangents`` (diffable
        positions only), jitted on first use."""
        if self._bwd is None:
            fn, diffable = self._fn, self._diffable

            def bwd_fn(args, cot):
                return partial_vjp(fn, args, diffable)[1](cot)

            self._bwd = jax.jit(bwd_fn)
        return self._bwd


# ----------------------------------------------------------------------
# the cache
# ----------------------------------------------------------------------


class DispatchCache:
    def __init__(self, max_entries: int = 4096):
        self.max_entries = max_entries
        self._lock = threading.RLock()
        self._entries: Dict[Any, CacheEntry] = {}
        self.stats = DispatchCacheStats()
        self._per_op: Dict[str, Dict[str, int]] = {}

    def _op_rec(self, name: str) -> Dict[str, int]:
        rec = self._per_op.get(name)
        if rec is None:
            rec = self._per_op[name] = dict.fromkeys(_PER_OP_FIELDS, 0)
        return rec

    def get_or_create(self, key, fn: Callable, diffable: Sequence[int],
                      n_args: int,
                      wrap: Optional[Callable] = None) -> CacheEntry:
        # every dispatch key leads with the op name (make_key contract) —
        # the per-op breakdown that makes regressions attributable keys
        # off it
        name = key[0]
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.num_hits += 1
                self._op_rec(name)["hits"] += 1
                return entry
            if len(self._entries) >= self.max_entries:
                # runaway-signature backstop: wholesale clear, like
                # allocator.empty_cache() — correctness is unaffected
                self._entries.clear()
                self.stats.num_evictions += 1
            entry = CacheEntry(fn, diffable, n_args, wrap=wrap)
            self._entries[key] = entry
            self.stats.num_misses += 1
            self._op_rec(name)["misses"] += 1
            self.stats.num_entries = len(self._entries)
            return entry

    def seed_entry(self, key, fn: Callable, diffable: Sequence[int],
                   n_args: int) -> None:
        """Pre-create an entry (from a ``repro.compile`` trace) without
        counting a miss: the first eager dispatch after the trace is then
        already warm."""
        with self._lock:
            if key in self._entries:
                return
            if len(self._entries) >= self.max_entries:
                self._entries.clear()
                self.stats.num_evictions += 1
            self._entries[key] = CacheEntry(fn, diffable, n_args)
            self.stats.num_seeded += 1
            self._op_rec(key[0])["seeded"] += 1
            self.stats.num_entries = len(self._entries)

    def record_uncached(self, name: str) -> None:
        with self._lock:
            self.stats.num_uncached += 1
            self._op_rec(name)["uncached"] += 1

    def record_fallback(self, name: str) -> None:
        with self._lock:
            self.stats.num_fallback_unhashable += 1
            self._op_rec(name)["fallback_unhashable"] += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.stats = DispatchCacheStats()
            self._per_op = {}

    def memory_stats(self) -> Dict[str, Any]:
        with self._lock:
            self.stats.num_entries = len(self._entries)
            out: Dict[str, Any] = self.stats.as_dict()
            per_op = {}
            for name, rec in self._per_op.items():
                warm = rec["hits"] + rec["misses"]
                per_op[name] = dict(
                    rec,
                    hit_rate=(rec["hits"] / warm) if warm else 0.0)
            out["per_op"] = per_op
            return out


_cache = DispatchCache()

_enabled = os.environ.get("REPRO_DISPATCH_CACHE", "1") != "0"


def dispatch_cache() -> DispatchCache:
    return _cache


def is_enabled() -> bool:
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Toggle the cache globally; returns the previous setting."""
    global _enabled
    prev = _enabled
    _enabled = bool(flag)
    return prev


class cache_disabled:
    """Context manager: run a block with the dispatch cache off (the
    cold / re-traced path — used by benchmarks and A/B tests)."""

    def __enter__(self):
        self._prev = set_enabled(False)
        return self

    def __exit__(self, *exc):
        set_enabled(self._prev)


def dispatch_cache_stats() -> Dict[str, Any]:
    """Counter snapshot.  Besides the global counters, ``"per_op"`` maps
    each op name to its own hits/misses/uncached/fallback_unhashable/
    seeded counts plus a derived ``hit_rate`` — so a call site regressing
    off the fast path is attributable to the op that did it."""
    return _cache.memory_stats()


def reset_dispatch_cache() -> None:
    """Drop every cached op/VJP executable and zero the hit/miss
    counters (see ``repro.dispatch_cache_stats()``)."""
    _cache.clear()


# ----------------------------------------------------------------------
# trace-time seeding (dispatch-cache-aware ``repro.compile``)
# ----------------------------------------------------------------------

_seed_tls = threading.local()


def seeding_enabled() -> bool:
    return getattr(_seed_tls, "on", False)


class seeding:
    """Context manager: while active, ops dispatched with tracer operands
    (i.e. inside a ``jax.jit``/``repro.compile`` trace) *seed* dispatch
    cache entries from their traced signatures instead of being invisible
    to the cache.  A model traced once by ``repro.compile`` then starts
    its eager life warm.  ``sink``, when given, collects seeded op names.
    """

    def __init__(self, enabled: bool = True, sink: Optional[list] = None):
        self._enabled = enabled
        self._sink = sink

    def __enter__(self):
        self._prev = (seeding_enabled(),
                      getattr(_seed_tls, "sink", None))
        _seed_tls.on = self._enabled
        _seed_tls.sink = self._sink
        return self

    def __exit__(self, *exc):
        _seed_tls.on, _seed_tls.sink = self._prev


def seed_op(name: str, static, datas: Sequence[Any], fn: Callable,
            diffable: Sequence[int]) -> None:
    """Seed entries for one traced op.  Tracer avals carry concrete
    shapes/dtypes, so the eager key is reconstructible; both grad-flag
    keys are seeded (entry contents don't depend on the flag — it only
    partitions the key space)."""
    seeded = False
    for grad in (False, True):
        key = make_key(name, static, datas, grad)
        if key is not None:
            _cache.seed_entry(key, fn, diffable, len(datas))
            seeded = True
    sink = getattr(_seed_tls, "sink", None)
    if seeded and sink is not None and name not in sink:
        sink.append(name)


# ----------------------------------------------------------------------
# key construction
# ----------------------------------------------------------------------


def signature_of(datas: Sequence[Any]) -> Tuple:
    return tuple((tuple(d.shape), str(d.dtype)) for d in datas)


def _typed(static):
    """Type-tag static leaves: ``0``, ``0.0``, and ``False`` hash and
    compare equal in Python, but bake into *different* closures (dtype
    promotion differs), so they must occupy different cache keys."""
    if isinstance(static, tuple):
        return tuple(_typed(s) for s in static)
    return (static.__class__.__name__, static)


def make_key(name: str, static, datas: Sequence[Any],
             grad: bool) -> Optional[Tuple]:
    """Build the dispatch key, or ``None`` when the statics are not
    usable as a key (unhashable values — the caller falls back to the
    uncached path and bumps ``num_fallback_unhashable``)."""
    key = (name, _typed(static), signature_of(datas), grad)
    try:
        hash(key)
    except TypeError:
        return None
    return key
