"""The compiled path + the elementwise fusion queue.

Two layers of the paper's performance story live here:

1. **The jit bridge** (paper §5.1/§7 "PyTorch JIT" → TorchScript analogue).
   Eager mode pays per-op Python dispatch, exactly as PyTorch does; the
   paper's answer is a JIT that runs the model outside the interpreter.  On
   JAX the natural analogue is ``jax.jit``: because :class:`repro.Tensor`
   is a registered pytree, *unmodified* eager model code can be traced once
   and replayed as a single fused XLA executable.  ``repro.compile(fn)`` is
   therefore the ``torch.jit.trace``/``torch.compile`` of this framework:
   tensor compute is captured, Python control flow is resolved at trace
   time, and retracing happens per input signature (shape/dtype), cached
   thereafter.  Unhashable static arguments fall back to uncached eager
   execution with a warning counter instead of raising.

2. **The elementwise fusion queue** (the §5 small-op fast path).  Inside
   ``with repro.fuse.fusion():`` every elementwise op (add, mul, exp,
   relu, ...) returns a *pending* tensor recording (op, statics, parents)
   instead of dispatching.  At a materialization point — ``.numpy()``,
   ``.item()``, a reduction or matmul consuming the chain, ``backward()``,
   any in-place mutation, or a jit boundary — the maximal pending subgraph
   is lowered through the dispatch cache as ONE jitted (or Pallas, on TPU)
   kernel: N Python dispatches become one executable replay.  Semantics
   are preserved exactly:

   * parent values are snapshotted at enqueue (jax arrays are immutable,
     so holding the reference *is* the snapshot), and every in-place
     mutation flushes all pending chains first, so a fused chain always
     computes what eager execution would have computed;
   * autograd records one tape node per flushed chain whose VJP replays a
     cached jitted backward against the chain's external inputs — version
     counters are captured at enqueue time, so mutate-after-use is
     detected exactly as in the per-op tape.
"""

from __future__ import annotations

import functools
import os
import threading
import warnings
import weakref
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import dispatch as _dispatch
from . import stream as _stream
from .autograd import Node, VersionCounter, is_grad_enabled
from .tensor import Storage, Tensor, _is_inexact, _is_tracer, _nbytes_of


# ----------------------------------------------------------------------
# the jit bridge (repro.compile)
# ----------------------------------------------------------------------

def compile(fn: Optional[Callable] = None, *, static_argnums=(),
            donate_argnums=(), seed_cache: bool = False,
            **jit_kwargs) -> Callable:
    """Trace-and-fuse an eager function (models, train steps, ...).

    Works on any function whose tensor arguments are ``repro.Tensor`` /
    pytrees thereof.  Inside the trace the autograd tape is automatically
    disabled (operands are tracers); use :func:`value_and_grad` to compile
    a differentiated step.

    ``seed_cache=True`` makes the compile dispatch-cache-aware: while the
    function is being traced, every op dispatched with a ``static=``
    descriptor *seeds* an eager dispatch-cache entry from its traced
    signature (see ``dispatch.seeding``).  Tracing a model once then
    leaves its eager ``F.*`` surface warm — and the seeded op names are
    exposed on ``wrapper.seeded_ops`` with per-op hit rates available via
    ``repro.dispatch_cache_stats()["per_op"]``.

    If a call hits jax's non-hashable-static-argument error the wrapper
    falls back to running ``fn`` eagerly (uncached) and bumps the dispatch
    cache's ``num_fallback_unhashable`` counter instead of raising.
    """

    def wrap(f: Callable) -> Callable:
        jitted = jax.jit(f, static_argnums=static_argnums,
                         donate_argnums=donate_argnums, **jit_kwargs)
        warned = []
        seeded_ops: list = []

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            try:
                if seed_cache:
                    # the flag is thread-local and only consulted when f
                    # is actually (re)traced; warm replays never enter
                    # Python, so keeping it armed per call is free
                    with _dispatch.seeding(sink=seeded_ops):
                        return jitted(*args, **kwargs)
                return jitted(*args, **kwargs)
            except (TypeError, ValueError) as e:
                if "hashable" not in str(e):
                    raise
                _dispatch.dispatch_cache().record_fallback("__compile__")
                if not warned:
                    warned.append(True)
                    warnings.warn(
                        f"repro.compile({f.__name__}): non-hashable "
                        f"static argument; running uncompiled "
                        f"(cached counter: num_fallback_unhashable)")
                return f(*args, **kwargs)

        wrapper._jitted = jitted  # expose for .lower()/.compile() tooling
        wrapper.seeded_ops = seeded_ops  # op names seeded at trace time
        return wrapper

    if fn is not None:
        return wrap(fn)
    return wrap


def value_and_grad(fn: Callable, argnums=0, has_aux: bool = False) -> Callable:
    """Functional gradient of an eager-style function, for the compiled
    path.  Differentiation happens in XLA (JAX AD), not on the tape —
    mirroring how TorchScript code is differentiated by its own engine.
    """
    def scalar_fn(*args, **kwargs):
        out = fn(*args, **kwargs)
        if has_aux:
            out, aux = out
            return (out.data if isinstance(out, Tensor) else out), aux
        return out.data if isinstance(out, Tensor) else out

    vg = jax.value_and_grad(scalar_fn, argnums=argnums, has_aux=has_aux)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        return vg(*args, **kwargs)

    return wrapper


def grad(fn: Callable, argnums=0, has_aux: bool = False) -> Callable:
    def scalar_fn(*args, **kwargs):
        out = fn(*args, **kwargs)
        if has_aux:
            out, aux = out
            return (out.data if isinstance(out, Tensor) else out), aux
        return out.data if isinstance(out, Tensor) else out

    g = jax.grad(scalar_fn, argnums=argnums, has_aux=has_aux)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        return g(*args, **kwargs)

    return wrapper


def block_until_ready(tree: Any) -> Any:
    """Join on async-dispatched work for a pytree of Tensors/arrays."""
    def _block(x):
        if isinstance(x, Tensor):
            x.data.block_until_ready()
        elif isinstance(x, jax.Array):
            x.block_until_ready()
        return x

    return jax.tree_util.tree_map(
        _block, tree, is_leaf=lambda x: isinstance(x, Tensor))


# ----------------------------------------------------------------------
# elementwise fusion queue
# ----------------------------------------------------------------------

# Ops that are safe to defer and fuse: one output, elementwise (or
# pure dtype-cast), no data-dependent shapes.  The second group is the
# nn.functional activation surface — with their ``static=`` descriptors
# in place they fuse across module boundaries (an MLP's
# linear->act->linear chain defers the activations, not just raw-tensor
# arithmetic).  softmax/log_softmax stay out: they reduce over an axis.
ELEMENTWISE_OPS = frozenset({
    "add", "sub", "mul", "div", "pow", "mod", "neg", "abs", "clone",
    "astype", "exp", "log", "sqrt", "rsqrt", "sin", "cos", "tanh",
    "sigmoid", "relu", "erf", "clamp", "maximum", "minimum", "where",
    "masked_fill",
    "relu6", "gelu", "silu", "softplus", "hardswish", "leaky_relu",
    "elu", "dropout",
})

# Chains deeper than this flush eagerly — bounds pending-graph size and
# XLA program length.
MAX_CHAIN_DEPTH = 32

_tls = threading.local()
_FUSION_DEFAULT = os.environ.get("REPRO_FUSION", "0") == "1"


def fusion_enabled() -> bool:
    return getattr(_tls, "fusion_on", _FUSION_DEFAULT)


def set_fusion(flag: bool) -> bool:
    """Enable/disable the fusion queue for this thread; returns the
    previous setting.  Disabling flushes outstanding chains."""
    prev = fusion_enabled()
    if not flag:
        flush_all()
    _tls.fusion_on = bool(flag)
    return prev


class fusion:
    """Context manager: batch elementwise chains into fused kernels.

    >>> with repro.fuse.fusion():
    ...     y = (x * 2 + 1).tanh().exp()   # zero dispatches so far
    ... loss = y.sum()                      # one fused kernel + one sum
    """

    def __init__(self, enabled: bool = True):
        self._enabled = enabled

    def __enter__(self):
        self._prev = fusion_enabled()
        _tls.fusion_on = self._enabled
        return self

    def __exit__(self, *exc):
        flush_all()
        _tls.fusion_on = self._prev


class PendingOp:
    """One deferred elementwise op in a fusion chain."""

    __slots__ = ("name", "fn", "static", "parents", "parent_snap",
                 "shape", "dtype", "needs_grad", "depth")

    def __init__(self, name, fn, static, parents, parent_snap, shape,
                 dtype, needs_grad, depth):
        self.name = name
        self.fn = fn
        self.static = static
        self.parents = parents          # tuple[Tensor]
        self.parent_snap = parent_snap  # jax.Array | None (None: pending)
        self.shape = shape              # inferred output shape
        self.dtype = dtype              # inferred output dtype
        self.needs_grad = needs_grad
        self.depth = depth


def _registry() -> List:
    reg = getattr(_tls, "pending_reg", None)
    if reg is None:
        reg = _tls.pending_reg = []
    return reg


_aval_cache = {}


def _out_aval(name, static, fn, parent_sigs):
    """(shape, dtype) of the op's output, via cached ``jax.eval_shape``.
    ``parent_sigs`` are plain (shape, dtype) tuples — constructing
    ShapeDtypeStructs only on cache miss keeps enqueue cheap."""
    key = (name, static, parent_sigs)
    out = _aval_cache.get(key)
    if out is None:
        aval = jax.eval_shape(
            fn, *[jax.ShapeDtypeStruct(s, d) for (s, d) in parent_sigs])
        out = (tuple(aval.shape), aval.dtype)
        _aval_cache[key] = out
    return out


def try_enqueue(name: str, fn: Callable, static, tensors) -> Optional[Tensor]:
    """Defer an elementwise op, returning its pending output tensor —
    or ``None`` when the op must dispatch immediately (fusion off,
    non-elementwise, tracer operands)."""
    if not fusion_enabled() or name not in ELEMENTWISE_OPS:
        return None
    for t in tensors:
        if t._pending is None and _is_tracer(t._d):
            return None  # inside a jit trace: lower straight to XLA

    parent_sigs = tuple((t.shape, t.dtype) for t in tensors)
    try:
        out_shape, out_dtype = _out_aval(name, static, fn, parent_sigs)
    except Exception:
        return None  # shape inference failed: let the eager path report

    needs_grad = is_grad_enabled() and any(
        (t.requires_grad or t.grad_fn is not None
         or (t._pending is not None and t._pending.needs_grad))
        and _is_inexact(t.dtype)
        for t in tensors)
    # never fuse across a grad-mode boundary: a chain built under
    # no_grad must stay constant (no shared node), and a grad chain must
    # not differentiate through a constant subchain — flush mismatched
    # pending parents so they join as materialized ext inputs
    for t in tensors:
        if t._pending is not None and t._pending.needs_grad != needs_grad:
            flush_tensor(t)
    depth = 1 + max(
        (t._pending.depth for t in tensors if t._pending is not None),
        default=0)
    pend = PendingOp(
        name, fn, static,
        parents=tuple(tensors),
        parent_snap=tuple(
            None if t._pending is not None else t._d for t in tensors),
        shape=out_shape,
        dtype=out_dtype,
        needs_grad=needs_grad,
        depth=depth,
    )

    out = Tensor.__new__(Tensor)
    out._d = None
    out._pending = pend
    out.requires_grad = False
    out.grad = None
    out.grad_fn = None
    out._output_index = 0
    out._version = VersionCounter()
    out._base = None
    out._view_index = None
    out._storage = None

    reg = _registry()
    reg.append(weakref.ref(out))
    if len(reg) > 4096:  # compact dead/flushed refs
        _tls.pending_reg = [r for r in reg
                            if (x := r()) is not None
                            and x._pending is not None]

    if depth >= MAX_CHAIN_DEPTH:
        flush_tensor(out)
    return out


def flush_all() -> None:
    """Materialize every pending chain in this thread (mutation barrier,
    explicit sync point).  Newest-first: flushing a chain's terminal
    materializes its whole subgraph in one fused kernel, so earlier
    registry entries are usually already done by the time we reach them."""
    reg = getattr(_tls, "pending_reg", None)
    if not reg:
        return
    for ref in reversed(list(reg)):
        t = ref()
        if t is not None and t._pending is not None:
            flush_tensor(t)
    reg.clear()


def _can_use_pallas(ext_data, shape) -> bool:
    if jax.default_backend() != "tpu":
        return False
    return (len(shape) >= 1
            and all(tuple(d.shape) == shape for d in ext_data))


def flush_tensor(t: Tensor) -> None:
    """Lower the maximal pending subgraph feeding ``t`` as ONE fused
    multi-output kernel (via the dispatch cache), execute it, and attach
    a single shared tape node.

    Every pending tensor in the subgraph — intermediates included — is
    materialized from the same kernel: tensor ``i`` becomes output ``i``
    of the fused node (the engine's multi-output cotangent accounting
    handles partial consumption, zero-filling unused outputs)."""
    pend = t._pending
    if pend is None:
        return

    steps = []          # (fn, arg_slots, name, static)
    by_slot: List[Tensor] = []  # tmp index -> its pending tensor
    slot_of = {}        # id(pending tensor) -> tmp index
    ext_tensors: List[Tensor] = []
    ext_data: List = []
    ext_ids = {}
    version_records = {}  # ext index -> (counter, value)

    def ext_slot(p: Tensor, snap) -> Tuple[str, int]:
        idx = ext_ids.get(id(p))
        if idx is None:
            idx = len(ext_tensors)
            ext_ids[id(p)] = idx
            ext_tensors.append(p)
            # enqueue-time snapshot; a parent that was pending at enqueue
            # but flushed since uses its materialized value (mutation
            # cannot have intervened: mutation flushes all chains first,
            # which also makes flush-time version records equal to
            # enqueue-time ones)
            ext_data.append(snap if snap is not None else p._d)
            version_records[idx] = (p._version, p._version.value)
        return ("e", idx)

    def visit(x: Tensor) -> int:
        if id(x) in slot_of:
            return slot_of[id(x)]
        p = x._pending
        slots = []
        for parent, snap in zip(p.parents, p.parent_snap):
            if parent._pending is not None:
                slots.append(("t", visit(parent)))
            else:
                slots.append(ext_slot(parent, snap))
        idx = len(steps)
        steps.append((p.fn, tuple(slots), p.name, p.static))
        by_slot.append(x)
        slot_of[id(x)] = idx
        return idx

    visit(t)

    descriptor = tuple((name, static, slots)
                       for (_, slots, name, static) in steps)
    run_steps = [(fn, slots) for (fn, slots, _, _) in steps]

    def fused_fn(*ext):
        tmp = []
        for fn, slots in run_steps:
            args = [ext[i] if kind == "e" else tmp[i]
                    for (kind, i) in slots]
            tmp.append(fn(*args))
        return tuple(tmp)

    diffable = [i for i, d in enumerate(ext_data)
                if _is_inexact(d.dtype)]
    # any step needing grad means the shared node must exist (grad-mode
    # boundaries inside a chain are prevented at enqueue time)
    needs_grad = any(x._pending.needs_grad for x in by_slot)

    wrap = None
    if (_can_use_pallas(ext_data, pend.shape)
            and all(x._pending.shape == pend.shape for x in by_slot)):
        from ..kernels.ops import make_fused_elementwise
        wrap = make_fused_elementwise

    key = _dispatch.make_key("__fused__", descriptor, ext_data,
                             bool(needs_grad))
    if key is not None and _dispatch.is_enabled():
        entry = _dispatch.dispatch_cache().get_or_create(
            key, fused_fn, diffable, len(ext_data), wrap=wrap)
        out_data = entry.fwd(*ext_data)
    else:
        entry = None
        if key is None:
            _dispatch.dispatch_cache().record_fallback("__fused__")
        out_data = fused_fn(*ext_data)

    node = None
    if needs_grad:
        # the engine hands a bare cotangent for single-output nodes but
        # fused_fn always returns a tuple — normalize
        def _norm(cot):
            return cot if isinstance(cot, tuple) else (cot,)

        if entry is not None:
            bwd = entry.bwd()
            saved = tuple(ext_data)
            vjp_fn = lambda cot: bwd(saved, _norm(cot))  # noqa: E731
        else:
            _, raw_vjp = _dispatch.partial_vjp(fused_fn, ext_data,
                                               diffable)
            vjp_fn = lambda cot: raw_vjp(_norm(cot))  # noqa: E731
        inputs = [ext_tensors[i] for i in diffable]
        chain = "+".join(name for (_, _, name, _) in steps)
        node = Node(f"fused[{chain}]", vjp_fn, inputs,
                    num_outputs=len(steps))
        node.metadata["out_avals"] = [
            (x._pending.shape, x._pending.dtype) for x in by_slot]
        for i in diffable:
            node.saved_versions.append(version_records[i])

    stream = _stream.current_stream()
    tracing = _is_tracer(out_data[0])
    for idx, x in enumerate(by_slot):
        x._d = out_data[idx]
        x._pending = None
        x.grad_fn = node
        x._output_index = idx
        if not tracing:
            x._storage = Storage(_nbytes_of(out_data[idx]),
                                 stream.stream_id)
    if not tracing:
        stream.enqueue(*out_data)
