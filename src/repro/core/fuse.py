"""The compiled path (paper §5.1/§7 "PyTorch JIT" → TorchScript analogue).

Eager mode pays per-op Python dispatch, exactly as PyTorch does; the paper's
answer is a JIT that runs the model outside the interpreter.  On JAX the
natural analogue is ``jax.jit``: because :class:`repro.Tensor` is a
registered pytree, *unmodified* eager model code can be traced once and
replayed as a single fused XLA executable — Python overhead disappears and
XLA fuses across op boundaries.

``repro.compile(fn)`` is therefore the ``torch.jit.trace``/``torch.compile``
of this framework, with the same contract: tensor compute is captured,
Python control flow is resolved at trace time, and retracing happens per
input signature (shape/dtype), cached thereafter.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax

from .tensor import Tensor


def compile(fn: Optional[Callable] = None, *, static_argnums=(),
            donate_argnums=(), **jit_kwargs) -> Callable:
    """Trace-and-fuse an eager function (models, train steps, ...).

    Works on any function whose tensor arguments are ``repro.Tensor`` /
    pytrees thereof.  Inside the trace the autograd tape is automatically
    disabled (operands are tracers); use :func:`value_and_grad` to compile
    a differentiated step.
    """

    def wrap(f: Callable) -> Callable:
        jitted = jax.jit(f, static_argnums=static_argnums,
                         donate_argnums=donate_argnums, **jit_kwargs)

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            return jitted(*args, **kwargs)

        wrapper._jitted = jitted  # expose for .lower()/.compile() tooling
        return wrapper

    if fn is not None:
        return wrap(fn)
    return wrap


def value_and_grad(fn: Callable, argnums=0, has_aux: bool = False) -> Callable:
    """Functional gradient of an eager-style function, for the compiled
    path.  Differentiation happens in XLA (JAX AD), not on the tape —
    mirroring how TorchScript code is differentiated by its own engine.
    """
    def scalar_fn(*args, **kwargs):
        out = fn(*args, **kwargs)
        if has_aux:
            out, aux = out
            return (out.data if isinstance(out, Tensor) else out), aux
        return out.data if isinstance(out, Tensor) else out

    vg = jax.value_and_grad(scalar_fn, argnums=argnums, has_aux=has_aux)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        return vg(*args, **kwargs)

    return wrapper


def grad(fn: Callable, argnums=0, has_aux: bool = False) -> Callable:
    def scalar_fn(*args, **kwargs):
        out = fn(*args, **kwargs)
        if has_aux:
            out, aux = out
            return (out.data if isinstance(out, Tensor) else out), aux
        return out.data if isinstance(out, Tensor) else out

    g = jax.grad(scalar_fn, argnums=argnums, has_aux=has_aux)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        return g(*args, **kwargs)

    return wrapper


def block_until_ready(tree: Any) -> Any:
    """Join on async-dispatched work for a pytree of Tensors/arrays."""
    def _block(x):
        if isinstance(x, Tensor):
            x.data.block_until_ready()
        elif isinstance(x, jax.Array):
            x.block_until_ready()
        return x

    return jax.tree_util.tree_map(
        _block, tree, is_leaf=lambda x: isinstance(x, Tensor))
