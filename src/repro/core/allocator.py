"""Caching memory allocator (paper §5.3), adapted for the JAX/TPU runtime.

PyTorch's CUDA caching allocator exists because ``cudaMalloc``/``cudaFree``
synchronize the device.  On TPU under XLA the *compiler* owns HBM for
compiled programs, so the faithful adaptation has three parts:

1. :class:`CachingAllocator` — a block allocator with the exact policies of
   the paper: allocations rounded up to multiples of 512 bytes, one free-pool
   per stream, blocks reused without touching the underlying system
   allocator, ``empty_cache()`` to release.  It backs *host staging buffers*
   (the pinned-memory analogue used by the DataLoader) with real
   ``numpy`` arenas, and it tracks *device tensor lifetimes* for the eager
   runtime so that refcounted frees (paper §5.5) return blocks to the cache
   immediately.

2. Device-side statistics — every eager tensor allocation/free is routed
   through the allocator's accounting even though XLA owns the physical
   bytes; this reproduces the observability of ``torch.cuda.memory_stats``
   and lets the Fig.-2 benchmark show the first-iteration ``malloc`` storm
   vs. steady-state cache hits.

3. The serving-side *paged KV-cache allocator* (``repro.serving.kv_cache``)
   reuses :class:`CachingAllocator` block logic at page granularity — the
   TPU-native descendant of the one-pool-per-stream design.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

# Paper §5.3: "it rounds up allocations to multiples of 512 bytes to avoid
# fragmentation issues."
ROUND_BYTES = 512
# Large allocations get their own segments (mirrors the CUDA allocator's
# small/large pool split at 1MB).
SMALL_LIMIT = 1 << 20


def round_size(nbytes: int) -> int:
    if nbytes <= 0:
        return ROUND_BYTES
    return (nbytes + ROUND_BYTES - 1) // ROUND_BYTES * ROUND_BYTES


@dataclass
class Block:
    """One cached allocation."""

    size: int                      # rounded size in bytes
    stream: int                    # owning stream id (one pool per stream)
    requested: int = 0             # last requested (un-rounded) size
    buffer: Optional[np.ndarray] = None   # host arena backing, if any
    live: bool = False
    alloc_id: int = -1


@dataclass
class AllocatorStats:
    num_system_allocs: int = 0     # "cudaMalloc" equivalents
    num_system_frees: int = 0      # "cudaFree" equivalents
    num_cache_hits: int = 0
    num_cache_misses: int = 0
    bytes_active: int = 0          # currently live
    bytes_reserved: int = 0        # live + cached
    peak_bytes_active: int = 0
    peak_bytes_reserved: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


class CachingAllocator:
    """Incremental caching block allocator with one free-pool per stream.

    ``backed=True`` makes blocks carry real ``numpy`` buffers (host staging /
    pinned-memory analogue); ``backed=False`` runs pure accounting for device
    tensors whose physical memory is owned by XLA.
    """

    def __init__(self, *, backed: bool = False, name: str = "device"):
        self.backed = backed
        self.name = name
        self._lock = threading.RLock()
        # (stream, rounded_size) -> free blocks.  One pool per stream:
        # paper §5.3 "maintains a distinct pool of memory for every CUDA
        # stream (work queue)".
        self._free: Dict[int, Dict[int, List[Block]]] = {}
        self.stats = AllocatorStats()
        self._next_alloc_id = 0
        # Streams whose frees must synchronize before reuse on another
        # stream (recorded by Stream.record_event / tensor.record_stream).
        self._cross_stream_pending: List[Block] = []

    # ------------------------------------------------------------------
    def allocate(self, nbytes: int, stream: int = 0) -> Block:
        size = round_size(nbytes)
        with self._lock:
            pool = self._free.setdefault(stream, {})
            bucket = pool.get(size)
            if bucket:
                block = bucket.pop()
                block.live = True
                block.requested = nbytes
                self.stats.num_cache_hits += 1
            else:
                block = self._system_alloc(size, stream)
                block.requested = nbytes
                self.stats.num_cache_misses += 1
            block.alloc_id = self._next_alloc_id
            self._next_alloc_id += 1
            self.stats.bytes_active += size
            self.stats.peak_bytes_active = max(
                self.stats.peak_bytes_active, self.stats.bytes_active
            )
            return block

    def free(self, block: Block, stream: Optional[int] = None) -> None:
        """Return a block to its stream pool (immediately reusable on the
        same stream — §5.3's run-ahead argument).  Freeing on a *different*
        stream than the allocation requires an event sync; we model that by
        placing the block on a pending list drained at ``synchronize``.
        """
        with self._lock:
            if not block.live:
                return
            block.live = False
            self.stats.bytes_active -= block.size
            if stream is not None and stream != block.stream:
                # cross-stream free: defer reuse until synchronization
                self._cross_stream_pending.append(block)
                return
            self._free.setdefault(block.stream, {}).setdefault(
                block.size, []
            ).append(block)

    def synchronize(self) -> None:
        """Drain cross-stream frees (called by Stream.synchronize)."""
        with self._lock:
            for block in self._cross_stream_pending:
                self._free.setdefault(block.stream, {}).setdefault(
                    block.size, []
                ).append(block)
            self._cross_stream_pending.clear()

    def empty_cache(self) -> int:
        """Release cached blocks back to the system; returns bytes freed."""
        with self._lock:
            freed = 0
            for pool in self._free.values():
                for bucket in pool.values():
                    for block in bucket:
                        freed += block.size
                        block.buffer = None
                        self.stats.num_system_frees += 1
                    bucket.clear()
            self.stats.bytes_reserved -= freed
            return freed

    def memory_stats(self) -> Dict[str, int]:
        with self._lock:
            return self.stats.as_dict()

    def reset_peak_stats(self) -> None:
        with self._lock:
            self.stats.peak_bytes_active = self.stats.bytes_active
            self.stats.peak_bytes_reserved = self.stats.bytes_reserved

    # ------------------------------------------------------------------
    def _system_alloc(self, size: int, stream: int) -> Block:
        # The expensive path ("cudaMalloc"): on the host arena this is a
        # real numpy allocation; for device accounting it is bookkeeping.
        buffer = np.empty(size, dtype=np.uint8) if self.backed else None
        self.stats.num_system_allocs += 1
        self.stats.bytes_reserved += size
        self.stats.peak_bytes_reserved = max(
            self.stats.peak_bytes_reserved, self.stats.bytes_reserved
        )
        return Block(size=size, stream=stream, buffer=buffer, live=True)


# Global allocators -----------------------------------------------------
_device_allocator = CachingAllocator(backed=False, name="device")
_host_allocator = CachingAllocator(backed=True, name="host")


def device_allocator() -> CachingAllocator:
    return _device_allocator


def host_allocator() -> CachingAllocator:
    return _host_allocator


def memory_stats() -> Dict[str, int]:
    return _device_allocator.memory_stats()


def empty_cache() -> int:
    return _device_allocator.empty_cache() + _host_allocator.empty_cache()
