"""Define-by-run reverse-mode automatic differentiation (paper §4.3).

PyTorch builds the backward graph *as the forward executes* via operator
overloading.  We reproduce that exactly: every eager op records a
:class:`Node` holding a vector-Jacobian product closure, obtained from
``jax.vjp`` so each op's derivative is exact by construction.  The engine
then walks the recorded graph in reverse topological order.

Fidelity points reproduced from the paper:

* **Operator overloading, not source transform** — the graph is rebuilt on
  every invocation, so arbitrary Python control flow works (§4.3 ¶1).
* **Tensor versioning for mutation** — in-place ops bump a version counter
  shared across views; saved-for-backward tensors snapshot the version and
  the engine errors if it changed (§4.3 ¶2), instead of silently using
  stale data or paying copy-on-write.
* **Immediate graph release** — unless ``retain_graph=True``, node closures
  (and therefore saved activations) are dropped as soon as they are
  consumed, so refcounting (§5.5) frees memory at the earliest point.
* **Eager/compiled split** — under a ``jax.jit`` trace the tape is *not*
  recorded (inputs are tracers); compiled code differentiates through XLA
  instead, mirroring eager-vs-TorchScript in the paper.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# Grad mode (torch.no_grad / enable_grad)
# ----------------------------------------------------------------------

_tls = threading.local()


def is_grad_enabled() -> bool:
    """Whether ops currently record autograd tape nodes (thread-local)."""
    return getattr(_tls, "grad_enabled", True)


class _GradMode:
    def __init__(self, enabled: bool):
        self._enabled = enabled
        self._prev: Optional[bool] = None

    def __enter__(self):
        self._prev = is_grad_enabled()
        _tls.grad_enabled = self._enabled
        return self

    def __exit__(self, *exc):
        _tls.grad_enabled = self._prev

    def __call__(self, fn):
        enabled = self._enabled

        def wrapped(*args, **kwargs):
            with _GradMode(enabled):
                return fn(*args, **kwargs)

        return wrapped


class no_grad(_GradMode):
    """Context manager / decorator disabling tape recording:
    ``with repro.no_grad(): ...`` — inference runs allocate no graph."""

    def __init__(self):
        super().__init__(False)


class enable_grad(_GradMode):
    """Context manager / decorator re-enabling tape recording inside an
    outer ``no_grad`` scope."""

    def __init__(self):
        super().__init__(True)


# ----------------------------------------------------------------------
# Graph nodes
# ----------------------------------------------------------------------

class Node:
    """One recorded operation in the dynamic autograd graph."""

    __slots__ = (
        "name",
        "vjp_fn",
        "inputs",           # list[Optional[Tensor]] (leaves or intermediates)
        "saved_versions",   # list[(version_counter, snapshot)]
        "num_outputs",
        "output_grads",     # accumulated cotangents per output
        "pending",          # outputs not yet seen during backward
        "metadata",
    )

    def __init__(self, name: str, vjp_fn: Callable, inputs: Sequence[Any],
                 num_outputs: int = 1):
        self.name = name
        self.vjp_fn = vjp_fn
        self.inputs = list(inputs)
        self.saved_versions: List[Tuple[Any, int]] = []
        self.num_outputs = num_outputs
        self.output_grads: List[Optional[jnp.ndarray]] = [None] * num_outputs
        self.pending = 0
        self.metadata: Dict[str, Any] = {}

    def save_version(self, tensor) -> None:
        self.saved_versions.append((tensor._version, tensor._version.value))

    def check_versions(self) -> None:
        for counter, snapshot in self.saved_versions:
            if counter.value != snapshot:
                raise RuntimeError(
                    f"one of the variables needed for gradient computation "
                    f"has been modified by an inplace operation (op "
                    f"{self.name}: saved version {snapshot}, current "
                    f"{counter.value})."
                )

    def release(self) -> None:
        """Drop the closure so saved activations are freed immediately."""
        self.vjp_fn = None  # type: ignore[assignment]
        self.inputs = []
        self.output_grads = [None] * self.num_outputs

    def __repr__(self):
        return f"<Node {self.name}>"


class VersionCounter:
    """Shared mutation counter (one per storage, shared by views)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def bump(self) -> None:
        self.value += 1


# ----------------------------------------------------------------------
# Backward engine
# ----------------------------------------------------------------------

def _accumulate(existing, update):
    if existing is None:
        return update
    return existing + update


def backward(tensors, grads=None, retain_graph: bool = False) -> None:
    """Run reverse-mode AD from ``tensors`` back to all leaves.

    Multi-source capable (``autograd.backward([l1, l2])``), matching
    ``torch.autograd.backward``.
    """
    from .tensor import Tensor  # circular-safe

    if isinstance(tensors, Tensor):
        tensors = [tensors]
    if grads is None:
        grads = [None] * len(tensors)
    elif isinstance(grads, Tensor) or grads is Ellipsis:
        grads = [grads]

    # backward is a materialization point: flush pending fusion chains so
    # every root has its grad_fn recorded before the graph walk
    for t in tensors:
        t._data  # noqa: B018  (property read flushes)

    # Seed cotangents
    roots: List[Tuple[Node, int, jnp.ndarray]] = []
    for t, g in zip(tensors, grads):
        if g is None:
            if t.shape != ():
                raise RuntimeError(
                    "grad can be implicitly created only for scalar outputs"
                )
            g_data = jnp.ones((), dtype=t.dtype)
        else:
            g_data = g.data if isinstance(g, Tensor) else jnp.asarray(g)
        if t.grad_fn is None:
            if t.requires_grad:
                t._accumulate_grad(g_data)
            continue
        roots.append((t.grad_fn, t._output_index, g_data))

    if not roots:
        return

    # 1) Count in-graph dependencies of every node (how many cotangent
    #    contributions it will receive) with a forward pass over the graph.
    dependencies: Dict[Node, int] = {}
    seen = set()
    stack = [node for node, _, _ in roots]
    topo_nodes: List[Node] = []
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        topo_nodes.append(node)
        for inp in node.inputs:
            if inp is not None and inp.grad_fn is not None:
                dependencies[inp.grad_fn] = dependencies.get(inp.grad_fn, 0) + 1
                stack.append(inp.grad_fn)

    # 2) Ready-queue execution: a node runs once all its consumers have
    #    delivered cotangents (Kahn's algorithm over the reversed graph).
    ready: deque[Node] = deque()
    outstanding: Dict[Node, int] = dict(dependencies)

    for node, idx, g in roots:
        node.output_grads[idx] = _accumulate(node.output_grads[idx], g)
        if outstanding.get(node, 0) == 0 and not node.metadata.get("_queued"):
            node.metadata["_queued"] = True
            ready.append(node)

    executed = set()
    while ready:
        node = ready.popleft()
        if id(node) in executed:
            continue
        executed.add(id(node))
        node.metadata.pop("_queued", None)

        node.check_versions()
        if node.vjp_fn is None:
            raise RuntimeError(
                f"Trying to backward through the graph a second time (node "
                f"{node.name}); specify retain_graph=True if you need to."
            )

        out_grads = [
            g if g is not None else None for g in node.output_grads
        ]
        # Fill missing output cotangents with zeros of the right shape:
        # jax.vjp requires full cotangents.
        cotangent = (
            out_grads[0]
            if node.num_outputs == 1
            else tuple(
                g if g is not None else jnp.zeros(shape, dtype)
                for g, (shape, dtype) in zip(
                    out_grads, node.metadata["out_avals"]
                )
            )
        )
        if node.num_outputs == 1 and cotangent is None:
            shape, dtype = node.metadata["out_avals"][0]
            cotangent = jnp.zeros(shape, dtype)

        input_grads = node.vjp_fn(cotangent)
        if not isinstance(input_grads, (tuple, list)):
            input_grads = (input_grads,)
        # cotangents are consumed: reset so a retained graph starts clean
        node.output_grads = [None] * node.num_outputs

        for inp, g in zip(node.inputs, input_grads):
            if inp is None or g is None:
                continue
            if inp.grad_fn is not None:
                producer = inp.grad_fn
                idx = inp._output_index
                producer.output_grads[idx] = _accumulate(
                    producer.output_grads[idx], g
                )
                outstanding[producer] = outstanding.get(producer, 1) - 1
                if outstanding[producer] <= 0 and not producer.metadata.get(
                    "_queued"
                ):
                    producer.metadata["_queued"] = True
                    ready.append(producer)
            elif inp.requires_grad:
                inp._accumulate_grad(g)

        if not retain_graph:
            node.release()

    # Nodes never reached (e.g. zero-fanout branches) still release.
    if not retain_graph:
        for node in topo_nodes:
            if id(node) not in executed:
                node.release()


def grad(outputs, inputs, grad_outputs=None, retain_graph: bool = False,
         allow_unused: bool = False):
    """``torch.autograd.grad`` analogue: returns grads w.r.t. ``inputs``
    without mutating ``.grad`` on other leaves."""
    from .tensor import Tensor

    single = isinstance(inputs, Tensor)
    if single:
        inputs = [inputs]
    stash = [(t, t.grad) for t in inputs]
    for t in inputs:
        t.grad = None
    backward(outputs, grad_outputs, retain_graph=retain_graph)
    results = []
    for t, old in stash:
        g = t.grad
        if g is None and not allow_unused:
            raise RuntimeError(
                "One of the differentiated Tensors appears to not have been "
                "used in the graph. Set allow_unused=True if this is the "
                "desired behavior."
            )
        results.append(g)
        t.grad = old
    return results[0] if single else tuple(results)


# ----------------------------------------------------------------------
# torch.autograd.Function analogue (paper §4.2 extensibility)
# ----------------------------------------------------------------------

class FunctionCtx:
    def __init__(self):
        self.saved_tensors: Tuple[Any, ...] = ()
        self._saved_versions: List[Tuple[Any, int]] = []
        self._extras: Dict[str, Any] = {}

    def save_for_backward(self, *tensors) -> None:
        self.saved_tensors = tensors
        self._saved_versions = [
            (t._version, t._version.value)
            for t in tensors
            if hasattr(t, "_version")
        ]

    def __setattr__(self, key, value):
        object.__setattr__(self, key, value)


class Function:
    """Subclass with ``forward(ctx, ...)`` and ``backward(ctx, *grads)`` to
    define a custom differentiable op, exactly as in torch.
    """

    @staticmethod
    def forward(ctx: FunctionCtx, *args, **kwargs):
        raise NotImplementedError

    @staticmethod
    def backward(ctx: FunctionCtx, *grad_outputs):
        raise NotImplementedError

    @classmethod
    def apply(cls, *args, **kwargs):
        from .tensor import Tensor, _wrap_outputs

        ctx = FunctionCtx()
        with no_grad():
            raw = cls.forward(ctx, *args, **kwargs)

        tensor_inputs = [a if isinstance(a, Tensor) else None for a in args]
        needs_grad = is_grad_enabled() and any(
            t is not None and (t.requires_grad or t.grad_fn is not None)
            for t in tensor_inputs
        )
        outputs = raw if isinstance(raw, tuple) else (raw,)

        if not needs_grad:
            return raw

        def vjp_fn(cotangent):
            for counter, snap in ctx._saved_versions:
                if counter.value != snap:
                    raise RuntimeError(
                        f"saved tensor modified by an inplace operation in "
                        f"custom Function {cls.__name__}"
                    )
            cots = cotangent if isinstance(cotangent, tuple) else (cotangent,)
            cots = tuple(
                c.data if isinstance(c, Tensor) else c for c in cots
            )
            with no_grad():
                grads = cls.backward(ctx, *[
                    Tensor(c) if c is not None else None for c in cots
                ])
            if not isinstance(grads, tuple):
                grads = (grads,)
            return tuple(
                g.data if isinstance(g, Tensor) else g for g in grads
            )

        node = Node(cls.__name__, vjp_fn, tensor_inputs,
                    num_outputs=len(outputs))
        node.metadata["out_avals"] = [
            (o.shape, o.dtype) for o in outputs
        ]
        return _wrap_outputs(raw, node)
