"""Fault-tolerant checkpointing.

Design for the 1000+-node regime (documented here, exercised at
container scale):

  * atomic checkpoints — write to ``step_N.tmp/``, fsync, rename; a crash
    mid-save can never corrupt the latest restorable state,
  * async save — the host thread snapshots device arrays (device_get) and
    a background thread does the I/O, keeping the step loop running,
  * elastic restore — arrays are stored unsharded (per-leaf .npy inside an
    .npz) plus a manifest; restore ``device_put``s into WHATEVER mesh the
    new job has, so a restart may change the data-parallel width
    (elastic scaling).  At 400B scale each host would write only its
    addressable shards with the same manifest format (noted in DESIGN.md),
  * preemption hook — ``install_preemption_handler`` saves on SIGTERM,
  * retention — keep the newest ``keep_n`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "|"


def _flatten(state) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    out = {}
    for kp, leaf in flat:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._save_count = 0

    # -- write ----------------------------------------------------------
    def save(self, state, step: int) -> str:
        host_state = {k: np.asarray(jax.device_get(v))
                      for k, v in _flatten(state).items()}
        return self._write(host_state, step)

    def save_async(self, state, step: int) -> None:
        """Snapshot synchronously (cheap device_get), write in background."""
        self.wait()
        host_state = {k: np.asarray(jax.device_get(v))
                      for k, v in _flatten(state).items()}
        self._thread = threading.Thread(
            target=self._write, args=(host_state, step), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state: Dict[str, np.ndarray], step: int) -> str:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host_state)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "keys": sorted(host_state)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        self._save_count += 1
        return final

    def _gc(self) -> None:
        ckpts = self.all_steps()
        for s in ckpts[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- read ------------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def restore(self, step: int, like_state, mesh=None):
        """Restore into the structure/shardings of ``like_state`` —
        resharding onto the current mesh (elastic restart)."""
        path = os.path.join(self.dir, f"step_{step}", "arrays.npz")
        data = np.load(path)
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like_state)
        leaves = []
        for kp, leaf in flat_like:
            key = SEP.join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
            arr = data[key]
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and mesh is not None:
                leaves.append(jax.device_put(arr, sharding))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like_state, mesh=None):
        steps = self.all_steps()
        if not steps:
            return None
        return self.restore(steps[-1], like_state, mesh)


def install_preemption_handler(manager: CheckpointManager, get_state,
                               get_step) -> None:
    """Save a final checkpoint on SIGTERM/SIGINT (cluster preemption)."""

    def _handler(signum, frame):
        manager.save(get_state(), int(get_step()))
        raise SystemExit(128 + signum)

    signal.signal(signal.SIGTERM, _handler)
