"""Functional optimizer cores: pure ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)`` pairs over pytrees.

These are the single source of truth for the update math.  The eager
``repro.optim.Optimizer`` classes call them per-parameter; the distributed
train step ``pjit``s them over the whole sharded param pytree (optimizer
state inherits the parameter sharding → ZeRO-style state partitioning for
free).

``state_dtype`` lets the giant-MoE configs (arctic-480b, jamba-398b) hold
moments in bf16; ``factored=True`` switches the second moment to Adafactor
row/column factorization — both standard large-scale memory tricks.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# ----------------------------------------------------------------------
# SGD
# ----------------------------------------------------------------------

def sgd_init(params, momentum: float = 0.0, **_):
    if momentum == 0.0:
        return {}
    return {"momentum": tree_map(jnp.zeros_like, params)}


def sgd_update(grads, state, params, *, lr: float, momentum: float = 0.0,
               weight_decay: float = 0.0, nesterov: bool = False,
               dampening: float = 0.0, **_):
    if weight_decay:
        grads = tree_map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum:
        buf = tree_map(
            lambda m, g: momentum * m + (1 - dampening) * g,
            state["momentum"], grads)
        if nesterov:
            grads = tree_map(lambda g, m: g + momentum * m, grads, buf)
        else:
            grads = buf
        state = {"momentum": buf}
    updates = tree_map(lambda g: -lr * g, grads)
    return updates, state


# ----------------------------------------------------------------------
# Adam / AdamW
# ----------------------------------------------------------------------

def adam_init(params, state_dtype=None, **_):
    def z(p):
        dt = state_dtype or p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "m": tree_map(z, params),
        "v": tree_map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adam_update(grads, state, params, *, lr: float, betas=(0.9, 0.999),
                eps: float = 1e-8, weight_decay: float = 0.0,
                decoupled: bool = True, state_dtype=None, **_):
    b1, b2 = betas
    step = state["step"] + 1
    stepf = step.astype(jnp.float32)

    if weight_decay and not decoupled:  # classic Adam (L2 into grad)
        grads = tree_map(lambda g, p: g + weight_decay * p, grads, params)

    def upd_m(m, g):
        return (b1 * m.astype(g.dtype) + (1 - b1) * g).astype(m.dtype)

    def upd_v(v, g):
        g32 = g.astype(jnp.float32)
        return (b2 * v.astype(jnp.float32)
                + (1 - b2) * jnp.square(g32)).astype(v.dtype)

    m = tree_map(upd_m, state["m"], grads)
    v = tree_map(upd_v, state["v"], grads)
    bc1 = 1 - b1 ** stepf
    bc2 = 1 - b2 ** stepf

    def upd(p, mm, vv):
        mhat = mm.astype(jnp.float32) / bc1
        vhat = vv.astype(jnp.float32) / bc2
        u = -lr * mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and decoupled:  # AdamW
            u = u - lr * weight_decay * p.astype(jnp.float32)
        return u.astype(p.dtype)

    updates = tree_map(upd, params, m, v)
    return updates, {"m": m, "v": v, "step": step}


# ----------------------------------------------------------------------
# Adafactor (factored second moment — fits 480B optimizer state)
# ----------------------------------------------------------------------

def adafactor_init(params, **_):
    def fac(p):
        if p.ndim >= 2:
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "fac": tree_map(fac, params,
                        ),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, *, lr: float,
                     decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0,
                     weight_decay: float = 0.0, **_):
    step = state["step"] + 1
    stepf = step.astype(jnp.float32)
    beta2 = 1.0 - stepf ** (-decay)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_f = treedef.flatten_up_to(state["fac"])

    new_fac, updates = [], []
    for g, p, f in zip(flat_g, flat_p, flat_f):
        g32 = g.astype(jnp.float32)
        sq = jnp.square(g32) + eps
        if g.ndim >= 2:
            row = beta2 * f["row"] + (1 - beta2) * sq.mean(axis=-1)
            col = beta2 * f["col"] + (1 - beta2) * sq.mean(axis=-2)
            row_mean = row.mean(axis=-1, keepdims=True)
            vhat = (row[..., :, None] / jnp.maximum(row_mean[..., None], eps)
                    ) * col[..., None, :]
            new_fac.append({"row": row, "col": col})
        else:
            v = beta2 * f["v"] + (1 - beta2) * sq
            vhat = v
            new_fac.append({"v": v})
        u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
        # update clipping (Adafactor's RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        u = -lr * u
        if weight_decay:
            u = u - lr * weight_decay * p.astype(jnp.float32)
        updates.append(u.astype(p.dtype))

    return (jax.tree_util.tree_unflatten(treedef, updates),
            {"fac": jax.tree_util.tree_unflatten(treedef, new_fac),
             "step": step})


# ----------------------------------------------------------------------
# registry + helpers
# ----------------------------------------------------------------------

OPTIMIZERS: Dict[str, Tuple[Callable, Callable]] = {
    "sgd": (sgd_init, sgd_update),
    "adam": (adam_init, adam_update),
    "adamw": (adam_init, adam_update),
    "adafactor": (adafactor_init, adafactor_update),
}


def make_optimizer(name: str, **hparams):
    """Returns (init_fn(params)->state, update_fn(grads, state, params)
    -> (new_params, new_state)) with hyperparameters bound."""
    init, update = OPTIMIZERS[name]
    if name == "adamw":
        hparams.setdefault("decoupled", True)
        hparams.setdefault("weight_decay", 0.01)
    if name == "adam":
        hparams.setdefault("decoupled", False)

    def init_fn(params):
        return init(params, **hparams)

    def update_fn(grads, state, params):
        updates, new_state = update(grads, state, params, **hparams)
        new_params = tree_map(lambda p, u: p + u, params, updates)
        return new_params, new_state

    return init_fn, update_fn


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return tree_map(lambda g: g * scale, tree), norm
