"""Functional optimizer cores: pure ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)`` pairs over pytrees.

These are the single source of truth for the update math.  The eager
``repro.optim.Optimizer`` classes call them per-parameter; the distributed
train step ``pjit``s them over the whole sharded param pytree (optimizer
state inherits the parameter sharding → ZeRO-style state partitioning for
free).

``state_dtype`` lets the giant-MoE configs (arctic-480b, jamba-398b) hold
moments in bf16; ``factored=True`` switches the second moment to Adafactor
row/column factorization — both standard large-scale memory tricks.

**Foreach ("fused multi-tensor") variants**: ``sgd_update_foreach`` /
``adam_update_foreach`` flatten the param pytree once, bucket leaves by
dtype, and apply the update math to *concatenated raveled buffers* — one
fused kernel per bucket instead of ~10 dispatches per leaf (torch's
``foreach=True`` / ``_multi_tensor`` path).  The math is elementwise, so
concatenation is exact: results are bitwise-identical to the per-leaf
reference.  State pytree *structure is preserved* (per-leaf moments), so
checkpointing and sharding specs are unaffected; select with
``make_optimizer(name, foreach=True)``.  Note for the distributed path:
concatenation forces gathers across shards, so keep ``foreach=False``
under pjit with sharded params (the default) — the eager ``Optimizer``
classes, which pay per-leaf *Python* dispatch, are where foreach wins.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# ----------------------------------------------------------------------
# SGD
# ----------------------------------------------------------------------

def sgd_init(params, momentum: float = 0.0, **_):
    if momentum == 0.0:
        return {}
    return {"momentum": tree_map(jnp.zeros_like, params)}


def sgd_update(grads, state, params, *, lr: float, momentum: float = 0.0,
               weight_decay: float = 0.0, nesterov: bool = False,
               dampening: float = 0.0, **_):
    if weight_decay:
        grads = tree_map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum:
        buf = tree_map(
            lambda m, g: momentum * m + (1 - dampening) * g,
            state["momentum"], grads)
        if nesterov:
            grads = tree_map(lambda g, m: g + momentum * m, grads, buf)
        else:
            grads = buf
        state = {"momentum": buf}
    updates = tree_map(lambda g: -lr * g, grads)
    return updates, state


# ----------------------------------------------------------------------
# Adam / AdamW
# ----------------------------------------------------------------------

def adam_init(params, state_dtype=None, **_):
    def z(p):
        dt = state_dtype or p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "m": tree_map(z, params),
        "v": tree_map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adam_update(grads, state, params, *, lr: float, betas=(0.9, 0.999),
                eps: float = 1e-8, weight_decay: float = 0.0,
                decoupled: bool = True, state_dtype=None, **_):
    b1, b2 = betas
    step = state["step"] + 1
    stepf = step.astype(jnp.float32)

    if weight_decay and not decoupled:  # classic Adam (L2 into grad)
        grads = tree_map(lambda g, p: g + weight_decay * p, grads, params)

    def upd_m(m, g):
        return (b1 * m.astype(g.dtype) + (1 - b1) * g).astype(m.dtype)

    def upd_v(v, g):
        g32 = g.astype(jnp.float32)
        return (b2 * v.astype(jnp.float32)
                + (1 - b2) * jnp.square(g32)).astype(v.dtype)

    m = tree_map(upd_m, state["m"], grads)
    v = tree_map(upd_v, state["v"], grads)
    bc1 = 1 - b1 ** stepf
    bc2 = 1 - b2 ** stepf

    def upd(p, mm, vv):
        mhat = mm.astype(jnp.float32) / bc1
        vhat = vv.astype(jnp.float32) / bc2
        u = -lr * mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and decoupled:  # AdamW
            u = u - lr * weight_decay * p.astype(jnp.float32)
        return u.astype(p.dtype)

    updates = tree_map(upd, params, m, v)
    return updates, {"m": m, "v": v, "step": step}


# ----------------------------------------------------------------------
# Adafactor (factored second moment — fits 480B optimizer state)
# ----------------------------------------------------------------------

def adafactor_init(params, **_):
    def fac(p):
        if p.ndim >= 2:
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "fac": tree_map(fac, params,
                        ),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, *, lr: float,
                     decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0,
                     weight_decay: float = 0.0, **_):
    step = state["step"] + 1
    stepf = step.astype(jnp.float32)
    beta2 = 1.0 - stepf ** (-decay)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_f = treedef.flatten_up_to(state["fac"])

    new_fac, updates = [], []
    for g, p, f in zip(flat_g, flat_p, flat_f):
        g32 = g.astype(jnp.float32)
        sq = jnp.square(g32) + eps
        if g.ndim >= 2:
            row = beta2 * f["row"] + (1 - beta2) * sq.mean(axis=-1)
            col = beta2 * f["col"] + (1 - beta2) * sq.mean(axis=-2)
            row_mean = row.mean(axis=-1, keepdims=True)
            vhat = (row[..., :, None] / jnp.maximum(row_mean[..., None], eps)
                    ) * col[..., None, :]
            new_fac.append({"row": row, "col": col})
        else:
            v = beta2 * f["v"] + (1 - beta2) * sq
            vhat = v
            new_fac.append({"v": v})
        u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
        # update clipping (Adafactor's RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        u = -lr * u
        if weight_decay:
            u = u - lr * weight_decay * p.astype(jnp.float32)
        updates.append(u.astype(p.dtype))

    return (jax.tree_util.tree_unflatten(treedef, updates),
            {"fac": jax.tree_util.tree_unflatten(treedef, new_fac),
             "step": step})


# ----------------------------------------------------------------------
# fused multi-tensor ("foreach") updates
# ----------------------------------------------------------------------

def _bucket_by_dtype(*leaf_lists) -> List[List[int]]:
    """Group leaf indices whose participating arrays share dtypes (shape
    class is uniform: everything ravels to 1-D before concatenation)."""
    buckets: Dict[Tuple, List[int]] = {}
    n = len(leaf_lists[0])
    for i in range(n):
        key = tuple(str(ll[i].dtype) for ll in leaf_lists)
        buckets.setdefault(key, []).append(i)
    return list(buckets.values())


def _concat(leaves, idxs):
    if len(idxs) == 1:
        return leaves[idxs[0]].ravel()
    return jnp.concatenate([leaves[i].ravel() for i in idxs])


def _scatter_back(buf, like_leaves, idxs, out: list) -> None:
    off = 0
    for i in idxs:
        n = like_leaves[i].size
        out[i] = buf[off:off + n].reshape(like_leaves[i].shape)
        off += n


def sgd_update_foreach(grads, state, params, *, lr: float,
                       momentum: float = 0.0, weight_decay: float = 0.0,
                       nesterov: bool = False, dampening: float = 0.0,
                       **_):
    """Bucketed-concat SGD: exactly :func:`sgd_update`'s math applied to
    one fused buffer per dtype bucket."""
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["momentum"]) if momentum else None

    n = len(flat_p)
    updates: List = [None] * n
    new_m: List = [None] * n
    lists = (flat_p, flat_g) + ((flat_m,) if momentum else ())
    for idxs in _bucket_by_dtype(*lists):
        p = _concat(flat_p, idxs)
        g = _concat(flat_g, idxs)
        if weight_decay:
            g = g + weight_decay * p
        if momentum:
            m = _concat(flat_m, idxs)
            buf = momentum * m + (1 - dampening) * g
            g = g + momentum * buf if nesterov else buf
            _scatter_back(buf, flat_p, idxs, new_m)
        _scatter_back(-lr * g, flat_p, idxs, updates)

    unflatten = jax.tree_util.tree_unflatten
    new_state = ({"momentum": unflatten(treedef, new_m)}
                 if momentum else {})
    return unflatten(treedef, updates), new_state


def adam_update_foreach(grads, state, params, *, lr: float,
                        betas=(0.9, 0.999), eps: float = 1e-8,
                        weight_decay: float = 0.0, decoupled: bool = True,
                        state_dtype=None, **_):
    """Bucketed-concat Adam/AdamW: exactly :func:`adam_update`'s math per
    fused dtype bucket, preserving the per-leaf state structure."""
    b1, b2 = betas
    step = state["step"] + 1
    stepf = step.astype(jnp.float32)
    bc1 = 1 - b1 ** stepf
    bc2 = 1 - b2 ** stepf

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    n = len(flat_p)
    updates: List = [None] * n
    new_m: List = [None] * n
    new_v: List = [None] * n
    for idxs in _bucket_by_dtype(flat_p, flat_g, flat_m, flat_v):
        p = _concat(flat_p, idxs)
        g = _concat(flat_g, idxs)
        m = _concat(flat_m, idxs)
        v = _concat(flat_v, idxs)
        if weight_decay and not decoupled:  # classic Adam (L2 into grad)
            g = g + weight_decay * p
        g32 = g.astype(jnp.float32)
        m_new = (b1 * m.astype(g.dtype) + (1 - b1) * g).astype(m.dtype)
        v_new = (b2 * v.astype(jnp.float32)
                 + (1 - b2) * jnp.square(g32)).astype(v.dtype)
        mhat = m_new.astype(jnp.float32) / bc1
        vhat = v_new.astype(jnp.float32) / bc2
        u = -lr * mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and decoupled:  # AdamW
            u = u - lr * weight_decay * p.astype(jnp.float32)
        _scatter_back(m_new, flat_p, idxs, new_m)
        _scatter_back(v_new, flat_p, idxs, new_v)
        _scatter_back(u.astype(p.dtype), flat_p, idxs, updates)

    unflatten = jax.tree_util.tree_unflatten
    return (unflatten(treedef, updates),
            {"m": unflatten(treedef, new_m),
             "v": unflatten(treedef, new_v),
             "step": step})


# Adafactor's factored second moment is not elementwise over a concat
# buffer; its "foreach" win is running the whole per-leaf loop inside ONE
# jitted executable, which the update already supports unchanged.
FOREACH_UPDATES: Dict[str, Callable] = {
    "sgd": sgd_update_foreach,
    "adam": adam_update_foreach,
    "adamw": adam_update_foreach,
    "adafactor": adafactor_update,
}

_FOREACH_STEP_JIT: Dict[Tuple, Callable] = {}


def foreach_hparams_key(algo: str, hparams: Dict) -> Optional[Tuple]:
    """Hashable cache key for a jitted foreach step, or ``None`` when the
    hyperparameters cannot key a cache entry (unhashable values — caller
    falls back to the per-leaf path)."""
    items = []
    for k, v in hparams.items():
        if k == "lr":
            continue  # lr is passed dynamically (schedules mutate it)
        if isinstance(v, list):
            v = tuple(v)
        items.append((k, v))
    key = (algo, tuple(sorted(items, key=lambda kv: kv[0])))
    try:
        hash(key)
    except TypeError:
        return None
    return key


def foreach_step_fn(algo: str, key: Tuple, hparams: Dict) -> Callable:
    """Jitted ``(grads, state, params, lr) -> (new_params, new_state)``
    fused over the whole pytree; cached per (algo, hyperparams)."""
    fn = _FOREACH_STEP_JIT.get(key)
    if fn is None:
        update = FOREACH_UPDATES[algo]
        hp = {k: v for k, v in hparams.items() if k != "lr"}

        def step(gs, st, ps, lr):
            updates, new_st = update(gs, st, ps, lr=lr, **hp)
            new_ps = tree_map(lambda p, u: p + u, ps, updates)
            return new_ps, new_st

        fn = jax.jit(step)
        _FOREACH_STEP_JIT[key] = fn
    return fn


# ----------------------------------------------------------------------
# registry + helpers
# ----------------------------------------------------------------------

OPTIMIZERS: Dict[str, Tuple[Callable, Callable]] = {
    "sgd": (sgd_init, sgd_update),
    "adam": (adam_init, adam_update),
    "adamw": (adam_init, adam_update),
    "adafactor": (adafactor_init, adafactor_update),
}


def make_optimizer(name: str, foreach: bool = False, **hparams):
    """Returns (init_fn(params)->state, update_fn(grads, state, params)
    -> (new_params, new_state)) with hyperparameters bound.

    ``foreach=True`` selects the fused multi-tensor update (single
    bucketed-concat kernel instead of per-leaf tree_map dispatch) —
    identical math and state structure; avoid under pjit with sharded
    params (concat would gather across shards)."""
    init, _ = OPTIMIZERS[name]
    update = FOREACH_UPDATES[name] if foreach else OPTIMIZERS[name][1]
    if name == "adamw":
        hparams.setdefault("decoupled", True)
        hparams.setdefault("weight_decay", 0.01)
    if name == "adam":
        hparams.setdefault("decoupled", False)

    def init_fn(params):
        return init(params, **hparams)

    def update_fn(grads, state, params):
        updates, new_state = update(grads, state, params, **hparams)
        new_params = tree_map(lambda p, u: p + u, params, updates)
        return new_params, new_state

    return init_fn, update_fn


def global_norm(tree) -> jnp.ndarray:
    """L2 norm over every leaf of a gradient pytree (f32 accumulate)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    """Scale the whole pytree so its global norm is <= ``max_norm``;
    returns (clipped tree, pre-clip norm)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return tree_map(lambda g: g * scale, tree), norm
