"""repro.optim — torch.optim-shaped optimizers.

"Running optimizers [is] expressed using the familiar concepts developed
for general purpose programming" (paper §4.1): an Optimizer is a plain
object holding references to parameters; ``step()`` mutates them in place
under ``no_grad``.  The math lives in ``repro.optim.functional`` and is
shared with the compiled/distributed train step.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from ..core.autograd import no_grad
from ..core.tensor import Tensor
from . import functional as OF
from .functional import (
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)


class Optimizer:
    """Base optimizer with param groups, mirroring torch.optim.Optimizer."""

    def __init__(self, params, defaults: Dict[str, Any], algo: str):
        self.defaults = defaults
        self.algo = algo
        params = list(params)
        if not params:
            raise ValueError("optimizer got an empty parameter list")
        if isinstance(params[0], dict):
            self.param_groups = [dict(defaults, **g) for g in params]
        else:
            self.param_groups = [dict(defaults, params=params)]
        self.state: Dict[int, Dict[str, Any]] = {}
        init, self._update = OF.OPTIMIZERS[algo]
        self._init = init

    def zero_grad(self, set_to_none: bool = True) -> None:
        for group in self.param_groups:
            for p in group["params"]:
                p.grad = None

    @no_grad()
    def step(self) -> None:
        for group in self.param_groups:
            hp = {k: v for k, v in group.items() if k != "params"}
            for p in group["params"]:
                if p.grad is None:
                    continue
                st = self.state.get(id(p))
                if st is None:
                    st = self._init(p.data, **hp)
                g = p.grad.data
                updates, new_state = self._update(g, st, p.data, **hp)
                self.state[id(p)] = new_state
                p._data = p.data + updates
                p._version.bump()

    def state_dict(self) -> Dict[str, Any]:
        # index params positionally across groups for serialization
        packed = []
        idx = 0
        for group in self.param_groups:
            for p in group["params"]:
                st = self.state.get(id(p))
                packed.append(jax.tree_util.tree_map(
                    lambda x: x, st) if st is not None else None)
                idx += 1
        return {"state": packed,
                "param_groups": [
                    {k: v for k, v in g.items() if k != "params"}
                    for g in self.param_groups]}

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        packed = sd["state"]
        idx = 0
        for group in self.param_groups:
            for p in group["params"]:
                if idx < len(packed) and packed[idx] is not None:
                    self.state[id(p)] = packed[idx]
                idx += 1


class SGD(Optimizer):
    def __init__(self, params, lr: float = 1e-3, momentum: float = 0.0,
                 weight_decay: float = 0.0, nesterov: bool = False,
                 dampening: float = 0.0):
        super().__init__(params, dict(lr=lr, momentum=momentum,
                                      weight_decay=weight_decay,
                                      nesterov=nesterov,
                                      dampening=dampening), "sgd")


class Adam(Optimizer):
    def __init__(self, params, lr: float = 1e-3, betas=(0.9, 0.999),
                 eps: float = 1e-8, weight_decay: float = 0.0):
        super().__init__(params, dict(lr=lr, betas=betas, eps=eps,
                                      weight_decay=weight_decay,
                                      decoupled=False), "adam")


class AdamW(Optimizer):
    def __init__(self, params, lr: float = 1e-3, betas=(0.9, 0.999),
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 state_dtype=None):
        super().__init__(params, dict(lr=lr, betas=betas, eps=eps,
                                      weight_decay=weight_decay,
                                      decoupled=True,
                                      state_dtype=state_dtype), "adamw")


class Adafactor(Optimizer):
    def __init__(self, params, lr: float = 1e-2, decay: float = 0.8,
                 clip_threshold: float = 1.0, weight_decay: float = 0.0):
        super().__init__(params, dict(lr=lr, decay=decay,
                                      clip_threshold=clip_threshold,
                                      weight_decay=weight_decay),
                         "adafactor")


# -- LR schedules (functional, used by launch.train) ---------------------

def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable[[Any], Any]:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        progress = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        progress = jnp.clip(progress, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return f
