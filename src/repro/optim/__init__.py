"""repro.optim — torch.optim-shaped optimizers.

"Running optimizers [is] expressed using the familiar concepts developed
for general purpose programming" (paper §4.1): an Optimizer is a plain
object holding references to parameters; ``step()`` mutates them in place
under ``no_grad``.  The math lives in ``repro.optim.functional`` and is
shared with the compiled/distributed train step.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from ..core.autograd import no_grad
from ..core.tensor import Tensor
from . import functional as OF
from .functional import (
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)


class Optimizer:
    """Base optimizer with param groups, mirroring torch.optim.Optimizer.

    ``foreach=True`` (the default, torch's multi-tensor path) replaces the
    per-parameter update loop with ONE cached jitted fused step per param
    group: leaves are bucketed by dtype, concatenated, updated in a single
    kernel, and split back — identical math and state layout, but the
    Python/dispatch cost per step drops from O(params) to O(1).
    Unhashable hyperparameters fall back to the per-leaf reference path
    with a warning counter instead of raising.
    """

    def __init__(self, params, defaults: Dict[str, Any], algo: str,
                 foreach: bool = True):
        self.defaults = defaults
        self.algo = algo
        self.foreach = foreach
        params = list(params)
        if not params:
            raise ValueError("optimizer got an empty parameter list")
        if isinstance(params[0], dict):
            self.param_groups = [dict(defaults, **g) for g in params]
        else:
            self.param_groups = [dict(defaults, params=params)]
        self.state: Dict[int, Dict[str, Any]] = {}
        # host-side per-param step counts: lets the foreach path group
        # params by step (staggered grads) without device syncs per step
        self._foreach_steps: Dict[int, int] = {}
        init, self._update = OF.OPTIMIZERS[algo]
        self._init = init

    def zero_grad(self, set_to_none: bool = True) -> None:
        for group in self.param_groups:
            for p in group["params"]:
                p.grad = None

    @no_grad()
    def step(self) -> None:
        for group in self.param_groups:
            hp = {k: v for k, v in group.items() if k != "params"}
            ps = [p for p in group["params"] if p.grad is not None]
            if not ps:
                continue
            if self.foreach and self._step_foreach(ps, hp):
                continue
            for p in ps:
                st = self.state.get(id(p))
                if st is None:
                    st = self._init(p.data, **hp)
                g = p.grad.data
                updates, new_state = self._update(g, st, p.data, **hp)
                self.state[id(p)] = new_state
                if id(p) in self._foreach_steps:
                    self._foreach_steps[id(p)] += 1
                p._data = p.data + updates
                p._version.bump()

    # -- fused multi-tensor step ----------------------------------------
    def _step_foreach(self, ps: List[Any], hp: Dict[str, Any]) -> bool:
        """One jitted fused update per step-group.  Params are grouped
        by their per-leaf step count (staggered grads — e.g. a param
        frozen for a while — must keep the bias correction the per-leaf
        reference would use).  Returns False (caller takes the per-leaf
        path) when the hyperparameters can't key the jit cache."""
        key = OF.foreach_hparams_key(self.algo, hp)
        if key is None:
            from ..core import dispatch as _dispatch
            _dispatch.dispatch_cache().stats.num_fallback_unhashable += 1
            return False

        states = []
        for p in ps:
            st = self.state.get(id(p))
            if st is None:
                st = self._init(p.data, **hp)
                self.state[id(p)] = st
            states.append(st)

        stepped = states[0] is not None and "step" in (states[0] or {})
        if stepped:
            groups: Dict[int, List[int]] = {}
            for i, (p, st) in enumerate(zip(ps, states)):
                c = self._foreach_steps.get(id(p))
                if c is None:
                    c = self._foreach_steps[id(p)] = int(st["step"])
                groups.setdefault(c, []).append(i)
        else:
            groups = {0: list(range(len(ps)))}

        step_fn = OF.foreach_step_fn(self.algo, key, hp)
        for idxs in groups.values():
            g_ps = [ps[i] for i in idxs]
            g_states = [states[i] for i in idxs]
            # per-param state dicts <-> one list-structured tree
            # (structure round-trips exactly: state_dict stays per-param)
            combined: Dict[str, Any] = {}
            if g_states[0]:
                for k in g_states[0]:
                    combined[k] = (g_states[0][k] if k == "step"
                                   else [s[k] for s in g_states])
            new_ps, new_st = step_fn(
                [p.grad.data for p in g_ps], combined,
                [p.data for p in g_ps], hp.get("lr", 1e-3))
            for i, p in enumerate(g_ps):
                st = {k: (v if k == "step" else v[i])
                      for k, v in new_st.items()}
                self.state[id(p)] = st
                if stepped:
                    self._foreach_steps[id(p)] += 1
                p._data = new_ps[i]
                p._version.bump()
        return True

    def state_dict(self) -> Dict[str, Any]:
        # index params positionally across groups for serialization
        packed = []
        idx = 0
        for group in self.param_groups:
            for p in group["params"]:
                st = self.state.get(id(p))
                packed.append(jax.tree_util.tree_map(
                    lambda x: x, st) if st is not None else None)
                idx += 1
        return {"state": packed,
                "param_groups": [
                    {k: v for k, v in g.items() if k != "params"}
                    for g in self.param_groups]}

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        self._foreach_steps.clear()  # resync from restored state
        packed = sd["state"]
        idx = 0
        for group in self.param_groups:
            for p in group["params"]:
                if idx < len(packed) and packed[idx] is not None:
                    self.state[id(p)] = packed[idx]
                idx += 1


class SGD(Optimizer):
    """SGD with momentum/Nesterov/weight decay (torch.optim.SGD);
    ``foreach=True`` (default) runs one fused update over dtype-bucketed
    concatenated leaves instead of a per-parameter loop."""

    def __init__(self, params, lr: float = 1e-3, momentum: float = 0.0,
                 weight_decay: float = 0.0, nesterov: bool = False,
                 dampening: float = 0.0, foreach: bool = True):
        super().__init__(params, dict(lr=lr, momentum=momentum,
                                      weight_decay=weight_decay,
                                      nesterov=nesterov,
                                      dampening=dampening), "sgd",
                         foreach=foreach)


class Adam(Optimizer):
    """Adam with COUPLED (L2) weight decay (torch.optim.Adam);
    ``foreach=True`` fuses the update across parameters."""

    def __init__(self, params, lr: float = 1e-3, betas=(0.9, 0.999),
                 eps: float = 1e-8, weight_decay: float = 0.0,
                 foreach: bool = True):
        super().__init__(params, dict(lr=lr, betas=betas, eps=eps,
                                      weight_decay=weight_decay,
                                      decoupled=False), "adam",
                         foreach=foreach)


class AdamW(Optimizer):
    """Adam with DECOUPLED weight decay (torch.optim.AdamW);
    ``state_dtype`` stores moments in a reduced precision."""

    def __init__(self, params, lr: float = 1e-3, betas=(0.9, 0.999),
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 state_dtype=None, foreach: bool = True):
        super().__init__(params, dict(lr=lr, betas=betas, eps=eps,
                                      weight_decay=weight_decay,
                                      decoupled=True,
                                      state_dtype=state_dtype), "adamw",
                         foreach=foreach)


class Adafactor(Optimizer):
    """Memory-factored Adam variant: second moments stored as row/col
    factors for 2-D parameters (sublinear optimizer state)."""

    def __init__(self, params, lr: float = 1e-2, decay: float = 0.8,
                 clip_threshold: float = 1.0, weight_decay: float = 0.0,
                 foreach: bool = True):
        super().__init__(params, dict(lr=lr, decay=decay,
                                      clip_threshold=clip_threshold,
                                      weight_decay=weight_decay),
                         "adafactor", foreach=foreach)


# -- LR schedules (functional, used by launch.train) ---------------------

def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable[[Any], Any]:
    """Linear warmup then cosine decay to ``min_ratio * base_lr``;
    returns a jit-safe ``step -> lr`` function."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        progress = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        progress = jnp.clip(progress, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return f
