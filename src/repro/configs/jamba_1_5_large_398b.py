"""jamba-1.5-large-398b — AI21 Jamba 1.5 Large  [arXiv:2403.19887].

72L d_model=8192; Mamba:attention 7:1 interleave (1 attention layer per
8-layer Jamba block, at position 4); MoE (16 experts, top-2,
d_ff=24576) every other layer, dense FFN (24576) otherwise.
Attention: 64H GQA kv=8.  Mamba: d_state=16, d_conv=4, expand=2.
Hybrid → long_500k decode runs (attention KV only on 9 layers).
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

_PATTERN = tuple(
    BlockSpec(mixer=("attn" if i == 4 else "mamba"),
              ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

CONFIG = LMConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    pattern=_PATTERN,
    n_experts=16, top_k=2,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_theta=None,   # Jamba uses no positional encoding in attention
    act="silu", tie_embeddings=False, param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="jamba-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=128,
    pattern=tuple(
        BlockSpec(mixer=("attn" if i == 4 else "mamba"),
                  ffn=("moe" if i % 2 == 1 else "dense"))
        for i in range(8)),
    n_experts=4, top_k=2, rope_theta=None,
    tie_embeddings=False, param_dtype=jnp.float32, remat="none",
    attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=True)
