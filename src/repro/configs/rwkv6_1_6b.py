"""rwkv6-1.6b — RWKV-6 "Finch" 1.6B  [arXiv:2404.05892].

24L d_model=2048, attention-free (WKV6 data-dependent-decay recurrence),
channel-mix FFN 3.5×d = 7168, vocab=65536, head_dim=64 (32 heads).
Constant-size state → long_500k decode runs (state is O(1) in seq).
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="rwkv6-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    pattern=(BlockSpec("rwkv", "none"),),   # channel-mix lives in the block
    rwkv_head_dim=64, rope_theta=None,
    tie_embeddings=False, param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="rwkv6-smoke",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=224, vocab_size=128,
    pattern=(BlockSpec("rwkv", "none"),),
    rwkv_head_dim=32, rope_theta=None, tie_embeddings=False,
    param_dtype=jnp.float32, remat="none", attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=True)
