"""minicpm3-4b  [hf:openbmb/MiniCPM3-4B] — MLA (multi-head latent attn).

62L d_model=2560, 40 heads, MLA: q_lora_rank=768, kv_lora_rank=256,
qk_nope=64, qk_rope=32, v_dim=64; SwiGLU d_ff=6400, vocab=73448.
The decode cache stores only (c_kv 256 + k_rope 32) per token — the MLA
memory win.  MiniCPM's depth/emb scaling factors are folded away (noted
in DESIGN.md §Arch-applicability).
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="minicpm3-4b",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    pattern=(BlockSpec("mla", "dense"),),
    q_lora_rank=768, kv_lora_rank=256,
    mla_nope_dim=64, mla_rope_dim=32, mla_v_dim=64,
    rope_theta=1e4, act="silu", tie_embeddings=True,
    param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="minicpm3-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=128,
    pattern=(BlockSpec("mla", "dense"),),
    q_lora_rank=32, kv_lora_rank=16, mla_nope_dim=16, mla_rope_dim=8,
    mla_v_dim=16, tie_embeddings=True, param_dtype=jnp.float32,
    remat="none", attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=False)
