"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) vocab=151936; MoE: 60 routed experts top-4
(d_ff_expert=1408) + shared expert (5632 = 4×1408, "4 shared").
EP note: 60 experts don't divide the 16-way model axis — expert slots are
PADDED to 64 (dead slots with zero routing probability; semantics
unchanged) so the expert axis shards 64/16 = 4-way (§Perf iteration 3).
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    pattern=(BlockSpec("attn", "moe"),),
    n_experts=60, n_experts_padded=64, top_k=4,
    n_shared_experts=4, d_ff_shared=5632,
    qkv_bias=True, rope_theta=1e6, act="silu",
    tie_embeddings=False, param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="qwen2-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab_size=128,
    pattern=(BlockSpec("attn", "moe"),),
    n_experts=4, top_k=2, n_shared_experts=1, d_ff_shared=64,
    qkv_bias=True, tie_embeddings=False,
    param_dtype=jnp.float32, remat="none", attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=False)
