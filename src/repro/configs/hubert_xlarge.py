"""hubert-xlarge  [arXiv:2106.07447] — encoder-only audio transformer.

48L d_model=1280, 16H bidirectional attention, plain-GELU d_ff=5120,
LayerNorm, 504-class frame prediction head (cluster targets).
The conv waveform frontend is a STUB per spec: ``input_specs`` provides
precomputed frame embeddings (B, S, 1280).  No decode shapes
(encoder-only) and no rope (frontend carries positions).
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="hubert-xlarge",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    pattern=(BlockSpec("attn", "dense"),),
    causal=False, rope_theta=None,
    act="gelu", gated_mlp=False, norm="layer",
    lm_head=False, n_classes=504, tie_embeddings=False,
    input_mode="embeddings", param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="hubert-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=64,
    pattern=(BlockSpec("attn", "dense"),),
    causal=False, rope_theta=None, act="gelu", gated_mlp=False,
    norm="layer", lm_head=False, n_classes=64, tie_embeddings=False,
    input_mode="embeddings", param_dtype=jnp.float32, remat="none",
    attn_backend="ref",
)

SHAPES = lm_shapes(
    long_ok=False, decode_ok=False,
    long_reason="encoder-only: no autoregressive decode",
)
