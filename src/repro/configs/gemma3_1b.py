"""gemma3-1b  [hf:google/gemma-3-1b-pt].

26L d_model=1152, 4H GQA kv=1, head_dim=256, GeGLU d_ff=6912,
vocab=262144.  5:1 local:global attention (sliding window 512 on local
layers, rope theta 10k local / 1M global), QK-norm, (1+w) RMSNorm, tied
scaled embeddings.  26 = 4×(5+1) + 2-layer sliding tail.
long_500k: local layers keep a 512-slot ring buffer; only the 4 global
layers hold full 524288-token KV → runs (noted in DESIGN.md).
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

_PATTERN = tuple([BlockSpec("sliding", "dense")] * 5
                 + [BlockSpec("attn", "dense")])

CONFIG = LMConfig(
    name="gemma3-1b",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    pattern=_PATTERN, window=512,
    rope_theta=1e6, rope_theta_local=1e4, qk_norm=True,
    act="gelu", norm_offset=1.0, embed_scale=True, tie_embeddings=True,
    param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="gemma3-smoke",
    n_layers=8, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=256,
    pattern=tuple([BlockSpec("sliding", "dense")] * 5
                  + [BlockSpec("attn", "dense")]),
    window=8, rope_theta=1e6, rope_theta_local=1e4, qk_norm=True,
    act="gelu", norm_offset=1.0, embed_scale=True, tie_embeddings=True,
    param_dtype=jnp.float32, remat="none", attn_backend="ref",
)

SHAPES = lm_shapes(
    long_ok=True,
    long_reason="5:1 sliding:global — rings bound local KV; global KV "
                "(4 layers) fits sharded")
