"""Architecture registry: ``get_config("--arch id")`` plus shape specs.

Ten assigned architectures from the public pool, each with its exact
published configuration, a reduced smoke config, and the four input
shapes (train_4k / prefill_32k / decode_32k / long_500k) with documented
skips where a shape is inapplicable to the family.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.lm import LMConfig
from .common import ShapeSpec, SkipSpec, input_specs  # noqa: F401

ARCH_MODULES: Dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "arctic-480b": "arctic_480b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma-2b": "gemma_2b",
    "gemma3-1b": "gemma3_1b",
    "yi-34b": "yi_34b",
    "minicpm3-4b": "minicpm3_4b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCHS: List[str] = list(ARCH_MODULES)


def _module(arch: str):
    if arch not in ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")


def get_config(arch: str) -> LMConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> LMConfig:
    return _module(arch).SMOKE


def get_shapes(arch: str) -> Dict[str, object]:
    return _module(arch).SHAPES


def iter_cells():
    """Yield every (arch, shape_name, ShapeSpec|SkipSpec) — 40 cells."""
    for arch in ARCHS:
        for shape_name, spec in get_shapes(arch).items():
            yield arch, shape_name, spec
