"""llava-next-mistral-7b  [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d_model=4096, 32H GQA kv=8, SwiGLU d_ff=14336,
vocab=32000, rope theta 1e6.  The anyres vision tower is a STUB per spec:
``input_specs`` provides precomputed patch+text embeddings (B, S, 4096)
for train/prefill; decode runs on text tokens.
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="llava-next-mistral-7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    pattern=(BlockSpec("attn", "dense"),),
    rope_theta=1e6, act="silu", tie_embeddings=False,
    input_mode="embeddings", param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="llava-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=128,
    pattern=(BlockSpec("attn", "dense"),),
    tie_embeddings=False, input_mode="embeddings",
    param_dtype=jnp.float32, remat="none", attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=False)
