"""yi-34b  [arXiv:2403.04652] — llama-architecture GQA.

60L d_model=7168, 56H GQA kv=8 (head_dim=128), SwiGLU d_ff=20480,
vocab=64000.  56 heads don't divide TP=16 → context-parallel attention.
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    pattern=(BlockSpec("attn", "dense"),),
    rope_theta=5e6, act="silu", tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="yi-smoke",
    n_layers=2, d_model=64, n_heads=7, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=128,
    pattern=(BlockSpec("attn", "dense"),),
    tie_embeddings=False, param_dtype=jnp.float32, remat="none",
    attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=False)
