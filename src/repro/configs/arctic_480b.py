"""arctic-480b — Snowflake Arctic base  [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8, head_dim=128) vocab=32000.
Dense-MoE hybrid: every layer has a dense residual FFN (7168) IN PARALLEL
with a 128-expert top-2 MoE (d_ff_expert=4864)  → ≈480B total params.
56 heads don't divide TP=16 → attention runs context-parallel (see
distributed.sharding).  Experts shard 128/16 = 8 per chip (EP).
Training uses Adafactor + bf16 params so optimizer state fits the pod.
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    pattern=(BlockSpec("attn", "moe"),),
    n_experts=128, top_k=2,
    moe_dense_residual=True, d_ff_dense_residual=7168,
    rope_theta=1e4, act="silu", tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="arctic-smoke",
    n_layers=2, d_model=64, n_heads=7, n_kv_heads=1, head_dim=16,
    d_ff=48, vocab_size=128,
    pattern=(BlockSpec("attn", "moe"),),
    n_experts=8, top_k=2, moe_dense_residual=True, d_ff_dense_residual=64,
    tie_embeddings=False, param_dtype=jnp.float32, remat="none",
    attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=False)
