"""gemma-2b  [arXiv:2403.08295].

18L d_model=2048, MQA (8 query heads, 1 KV head, head_dim=256),
GeGLU d_ff=16384, vocab=256000, tied embeddings scaled by sqrt(d_model),
RMSNorm with (1+w) convention.
"""
import jax.numpy as jnp
from ..models.lm import BlockSpec, LMConfig
from .common import lm_shapes

CONFIG = LMConfig(
    name="gemma-2b",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    pattern=(BlockSpec("attn", "dense"),),
    act="gelu", norm_offset=1.0, embed_scale=True, tie_embeddings=True,
    rope_theta=1e4, param_dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    name="gemma-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256,
    pattern=(BlockSpec("attn", "dense"),),
    act="gelu", norm_offset=1.0, embed_scale=True, tie_embeddings=True,
    param_dtype=jnp.float32, remat="none", attn_backend="ref",
)

SHAPES = lm_shapes(long_ok=False)
