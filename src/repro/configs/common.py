"""Shared machinery for architecture configs.

Every arch module defines:
  CONFIG  — the exact published configuration (LMConfig)
  SMOKE   — a reduced same-family config for CPU smoke tests
  SHAPES  — {shape_name: ShapeSpec | SkipSpec}

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins (weak-type
correct, shardable, zero allocation) for the dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.lm import LMConfig

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


@dataclass(frozen=True)
class SkipSpec:
    reason: str


TRAIN_4K = ShapeSpec("train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode", 32768, 128)
LONG_500K = ShapeSpec("decode", 524288, 1)


def lm_shapes(*, long_ok: bool, long_reason: str = "",
              decode_ok: bool = True,
              decode_reason: str = "") -> Dict[str, object]:
    shapes: Dict[str, object] = {
        "train_4k": TRAIN_4K,
        "prefill_32k": PREFILL_32K,
    }
    shapes["decode_32k"] = DECODE_32K if decode_ok else SkipSpec(
        decode_reason or "encoder-only architecture has no decode step")
    if long_ok:
        shapes["long_500k"] = LONG_500K
    else:
        shapes["long_500k"] = SkipSpec(
            long_reason or "pure full-attention arch: 500k decode KV is "
                           "quadratic-prefill territory; skipped per spec")
    return shapes


def input_specs(cfg: LMConfig, spec: ShapeSpec) -> Dict[str, object]:
    """ShapeDtypeStructs for one (arch × shape) cell.

    train/prefill: the full-sequence batch.  decode: one-token batch (the
    cache is a separate argument produced by ``abstract_cache``).
    """
    b, s = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        if cfg.input_mode == "embeddings":
            return {
                "embeds": SDS((b, s, cfg.d_model), jnp.bfloat16),
                "labels": SDS((b, s), jnp.int32),
            }
        return {
            "tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32),
        }
    if spec.kind == "prefill":
        if cfg.input_mode == "embeddings":
            return {"embeds": SDS((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": SDS((b, s), jnp.int32)}
    if spec.kind == "decode":
        return {
            "tokens": SDS((b, 1), jnp.int32),
            "pos": SDS((), jnp.int32),
        }
    raise ValueError(spec.kind)
