"""Roofline term extraction from compiled dry-run artifacts.

Per (arch × shape × mesh) we derive, for TPU v5e targets:

  compute term    = FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_bw_per_chip

``compiled.cost_analysis()`` reports the per-device (SPMD module) FLOPs
and bytes.  Collective bytes are not in cost_analysis: we parse the
compiled HLO and sum the result-buffer sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

MODEL_FLOPS uses 6·N·D (dense) or 6·N_active·D (MoE) per trained token,
3× less for forward-only (prefill/decode counts 2·N·D per token).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~3 links usable: use 1-link
                             # figure per the spec: ~50 GB/s/link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "bf16[2,1024,128]{2,1,0} all-gather(" possibly inside tuples
_SHAPE_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*?=\s*\(?[\w\s,\[\]{}()]*?(" +
    "|".join(_COLLECTIVES) + r")\(")
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?\S+\s*=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) +
    r")(?:-start|-done)?\(", re.M)
_ONE_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    if not dims:
        return nbytes
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-buffer bytes per collective kind from HLO text."""
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_LINE_RE.finditer(hlo_text):
        result_type, kind = m.group(1), m.group(2)
        if kind.endswith("-done"):
            continue
        size = sum(_shape_bytes(dt, dims)
                   for dt, dims in _ONE_SHAPE.findall(result_type))
        totals[kind] += size
        counts[kind] += 1
    totals["_counts"] = counts  # type: ignore[assignment]
    return totals


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs × devices)
    bytes_per_device_peak: Optional[float] = None   # from memory_analysis
    note: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return asdict(self)


def model_flops(cfg, spec, kind: str) -> float:
    """6·N_active·D for train, 2·N_active·D per forward token."""
    import jax
    from ..models.lm import abstract_params

    # parameter count excluding embeddings (standard convention keeps
    # embed out of the 6ND matmul estimate; logits matmul added back)
    ap = abstract_params(cfg)
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(ap))
    embed = cfg.vocab_size * cfg.d_model
    n_embed_mats = sum(
        1 for k in ("embed",) ) + (0 if cfg.tie_embeddings else 1)
    body = total - embed * (1 if cfg.tie_embeddings else 2)

    # MoE: only top_k of n_experts expert FFNs run per token
    if cfg.n_experts:
        moe_layers = sum(1 for s in cfg.pattern if s.ffn == "moe") \
            * cfg.n_groups + sum(1 for s in cfg.tail if s.ffn == "moe")
        per_layer_expert = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
        inactive = per_layer_expert * (1 - cfg.top_k / cfg.n_experts)
        body -= moe_layers * inactive

    n_active = body + cfg.vocab_size * cfg.d_model  # logits matmul
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode"
                                  else 1)
    per_token = 6 * n_active if kind == "train" else 2 * n_active
    return float(per_token) * tokens


def analyze(arch: str, shape: str, mesh_name: str, n_devices: int,
            cfg, spec, kind: str, cost: Dict[str, float],
            hlo_text: str, mem: Optional[Dict] = None) -> Roofline:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    counts = coll.pop("_counts")
    coll_total = float(sum(coll.values()))

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, spec, kind)
    hlo_total = flops_dev * n_devices
    useful = mf / hlo_total if hlo_total else 0.0

    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_total,
        collective_breakdown={**coll, **{f"n_{k}": v
                                         for k, v in counts.items()}},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, useful_ratio=useful,
        bytes_per_device_peak=(mem or {}).get("bytes"),
    )
