"""Serving entry point for the scheduler/executor engine.

    PYTHONPATH=src python -m repro.launch.serve [--preset tiny|small]
        [--requests 32] [--max-new 8] [--chunk 16] [--json PATH]
        [--timeout-ms T] [--ttft-deadline-ms T] [--max-queue-depth N]
        [--faults SPEC] [--fault-seed S]

Builds a synthetic mixed-length workload (long prompts interleaved with
short ones), serves it through the paged continuous-batching engine, and
prints the metrics that make a throughput regression attributable:
decode tokens/s, mean TTFT, prefill chunks, preemptions, bucket
compiles vs the bucket budget, and the page high-water mark — plus the
fault-tolerance ledger (cancellations, timeouts, failed requests,
watchdog trips).

Failure handling is per-request, not per-process: a rejected submit
(typed ``AdmissionRejected``) is reported and skipped, a timed-out or
quarantined request is listed with its error, and Ctrl-C drains the
engine and prints partial outputs instead of dying mid-decode.  Fault
injection (``--faults "nan_logits@6;pool_exhaustion@4:pages=16"``, or
env ``REPRO_FAULTS``) exercises those paths deterministically.

The big configs under ``repro.configs`` serve through the same engine on
real accelerators; the presets here keep the entry point runnable on a
laptop CPU (the paper's §2 "everyone's workflow must work locally").
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..models.lm import LMConfig, init_params
from ..serving.engine import ServingEngine
from ..serving.errors import ServingError
from ..serving.faults import FaultInjector

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                 d_ff=128, vocab_size=97),
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  d_ff=512, vocab_size=1024),
}


def synthetic_workload(n_requests: int, vocab: int):
    prompts = []
    for i in range(n_requests):
        n = 48 if i % 4 == 0 else 8          # 1 long : 3 short
        prompts.append([(7 + 13 * i + j) % (vocab - 1) + 1
                        for j in range(n)])
    return prompts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--kv-dtype", choices=["fp32", "int8", "fp8_e4m3"],
                    default=None,
                    help="KV page-pool storage; int8/fp8_e4m3 store "
                         "quantized codes + per-token scales and "
                         "dequantize in the attention kernel "
                         "(~4x/~3.5x more concurrent sequences per "
                         "KV byte; see docs/kernels.md)")
    ap.add_argument("--timeout-ms", type=float, default=None,
                    help="per-request total deadline")
    ap.add_argument("--ttft-deadline-ms", type=float, default=None,
                    help="per-request first-token deadline")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="bounded admission (AdmissionRejected beyond)")
    ap.add_argument("--faults", default=None,
                    help='fault spec, e.g. "nan_logits@6;'
                         'executor_crash@9" (see serving.faults)')
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--dp", type=int, default=1,
                    help="data replicas (slot space becomes dp*max_batch;"
                         " dp*tp devices must exist for dp*tp > 1)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over the model axis")
    ap.add_argument("--json", default=None,
                    help="also dump metrics JSON to this path")
    args = ap.parse_args()

    cfg = LMConfig(name=f"serve-{args.preset}", **PRESETS[args.preset],
                   param_dtype=jnp.float32, remat="none",
                   attn_backend="ref")
    params = init_params(cfg, jax.random.key(0))
    faults = FaultInjector.parse(args.faults, seed=args.fault_seed) \
        if args.faults else None
    mesh = None
    if args.dp * args.tp > 1:
        from .mesh import mesh_for_serving
        mesh = mesh_for_serving(args.dp * args.tp, tp=args.tp)
    eng = ServingEngine(cfg, params, page_size=args.page_size,
                        num_pages=args.num_pages,
                        max_batch=args.max_batch,
                        chunk_size=args.chunk,
                        max_queue_depth=args.max_queue_depth,
                        kv_dtype=args.kv_dtype,
                        faults=faults, mesh=mesh)

    prompts = synthetic_workload(args.requests, cfg.vocab_size)
    t0 = time.perf_counter()
    rejected = 0
    for i, p in enumerate(prompts):
        try:
            eng.submit(p, max_new_tokens=args.max_new,
                       ttft_deadline_ms=args.ttft_deadline_ms,
                       timeout_ms=args.timeout_ms)
        except ServingError as e:
            # typed per-request rejection — report it, keep serving
            rejected += 1
            print(f"[rejected] request {i}: "
                  f"{type(e).__name__}: {e}")
    interrupted = False
    try:
        done = eng.run()
    except KeyboardInterrupt:
        # drain: cancel everything, keep the partial outputs
        interrupted = True
        done = []
        partial = eng.drain()
        print(f"\n[interrupt] drained {len(partial)} in-flight "
              f"request(s); partial outputs:")
        for r in partial:
            print(f"  req {r.req_id}: {len(r.out_tokens)} token(s) "
                  f"{r.out_tokens}")
    wall = time.perf_counter() - t0

    for r in eng.aborted:
        if r.state.value != "cancelled":
            print(f"[{r.state.value}] request {r.req_id}: {r.error} "
                  f"({len(r.out_tokens)} partial token(s))")

    m = eng.stats()
    ttfts = [r.first_token_at - r.submitted_at for r in done]
    report = {
        "served": len(done),
        "rejected_submits": rejected,
        "aborted": len(eng.aborted),
        "interrupted": interrupted,
        "wall_s": round(wall, 3),
        "decode_tokens_per_s": round(m["decoded_tokens"] / wall, 1),
        "ttft_mean_s": round(sum(ttfts) / max(len(ttfts), 1), 4),
        "bucket_compiles": m["bucket_compiles"],
        "bucket_budget": eng.bucket_count,
        "n_replicas": m["n_replicas"],
        **{k: m[k] for k in ("steps", "prefills", "prefill_chunks",
                             "preemptions", "zero_decode_steps",
                             "decoded_tokens", "page_hwm",
                             "page_hwm_per_replica", "kv_bytes",
                             "kv_bytes_per_seq", "kv_dtype",
                             "table_upload_rows", "prefix_hit_rate",
                             "cancellations", "timeouts",
                             "ttft_deadline_misses",
                             "failed_requests", "watchdog_trips",
                             "aged_admissions", "executor_failures",
                             "steps_exhausted")},
    }
    for k, v in report.items():
        print(f"{k:>22}: {v}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[json] {args.json}")


if __name__ == "__main__":
    main()
