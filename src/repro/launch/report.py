"""Render the §Dry-run / §Roofline tables for EXPERIMENTS.md from the
dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""

import glob
import json
import os
import sys

from repro.configs import ARCHS, SkipSpec, get_shapes

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath, mesh):
    recs = {}
    for f in glob.glob(os.path.join(dirpath, f"*__{mesh}.json")):
        with open(f) as fh:
            r = json.load(fh)
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def roofline_table(recs):
    lines = [
        "| arch | shape | FLOPs/dev | bytes/dev | coll/dev | compute_s |"
        " memory_s | collective_s | dominant | MODEL_FLOPS | useful |"
        " HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            spec = get_shapes(arch).get(shape)
            if isinstance(spec, SkipSpec):
                lines.append(
                    f"| {arch} | {shape} | — | — | — | — | — | — | "
                    f"SKIP: {spec.reason[:60]} | — | — | — |")
                continue
            r = recs.get((arch, shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING |"
                             + " |" * 10)
                continue
            rl = r["roofline"]
            mem_gb = r["memory"].get("bytes", 0) / 1e9
            lines.append(
                f"| {arch} | {shape} "
                f"| {rl['flops_per_device']:.2e} "
                f"| {rl['bytes_per_device']:.2e} "
                f"| {rl['collective_bytes_per_device']:.2e} "
                f"| {rl['compute_s']*1e3:.1f}ms "
                f"| {rl['memory_s']*1e3:.1f}ms "
                f"| {rl['collective_s']*1e3:.1f}ms "
                f"| **{rl['dominant']}** "
                f"| {rl['model_flops']:.2e} "
                f"| {rl['useful_ratio']:.2f} "
                f"| {mem_gb:.1f} |")
    return "\n".join(lines)


def dryrun_table(recs_s, recs_m):
    lines = [
        "| arch | shape | single-pod (256) | multi-pod (512) | "
        "bytes/dev single | bytes/dev multi | compile s/m |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            spec = get_shapes(arch).get(shape)
            if isinstance(spec, SkipSpec):
                lines.append(f"| {arch} | {shape} | SKIP | SKIP | — | — "
                             f"| — |")
                continue
            rs = recs_s.get((arch, shape))
            rm = recs_m.get((arch, shape))

            def stat(r):
                if r is None:
                    return "MISSING", "—", "—"
                return ("OK", fmt_bytes(r["memory"].get("bytes", 0)),
                        str(r.get("compile_s", "—")))
            s_ok, s_b, s_c = stat(rs)
            m_ok, m_b, m_c = stat(rm)
            lines.append(f"| {arch} | {shape} | {s_ok} | {m_ok} "
                         f"| {s_b} GB | {m_b} GB | {s_c}/{m_c} |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs_s = load(d, "single")
    recs_m = load(d, "multi")
    print("## Dry-run matrix\n")
    print(dryrun_table(recs_s, recs_m))
    print("\n## Roofline (single-pod, 256 × v5e)\n")
    print(roofline_table(recs_s))


if __name__ == "__main__":
    main()
