"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import and only then calls ``make_production_mesh``.

Mesh shapes:
  single pod:  (data=16, model=16)          — 256 chips (one v5e pod)
  multi-pod:   (pod=2, data=16, model=16)   — 512 chips across DCN

Axis roles:
  pod   — pure data parallelism across pods (DCN-crossing collectives are
          gradient all-reduces only; optionally the pipeline axis)
  data  — data parallel + FSDP (weights shard their contracting dim here)
  model — tensor/expert/context parallel within a pod (ICI)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]
              ) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests/examples (e.g. (1,1) on one CPU)."""
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist, as a (data, model) mesh with model=1."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axis_names(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes over which the batch is sharded (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_info(mesh: jax.sharding.Mesh) -> dict:
    return {
        "axis_names": mesh.axis_names,
        "shape": dict(mesh.shape),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
