"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import and only then calls ``make_production_mesh``.

Mesh shapes:
  single pod:  (data=16, model=16)          — 256 chips (one v5e pod)
  multi-pod:   (pod=2, data=16, model=16)   — 512 chips across DCN

Axis roles:
  pod   — pure data parallelism across pods (DCN-crossing collectives are
          gradient all-reduces only; optionally the pipeline axis)
  data  — data parallel + FSDP (weights shard their contracting dim here)
  model — tensor/expert/context parallel within a pod (ICI)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]
              ) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests/examples (e.g. (1,1) on one CPU)."""
    return jax.make_mesh(shape, axes)


def make_local_mesh(tp: Optional[int] = None) -> jax.sharding.Mesh:
    """Whatever devices exist, as a (data, model) mesh.

    ``tp`` sets the ``model`` axis extent (default 1 — pure data
    parallel, the historical behavior); it must divide the local device
    count.  ``make_local_mesh(tp=2)`` on a 8-device host is the local
    TP testing mesh the hardcoded ``(n, 1)`` used to make impossible."""
    n = len(jax.devices())
    tp = tp or 1
    if tp < 1 or n % tp != 0:
        from ..serving.errors import MeshConfigError
        raise MeshConfigError(
            f"tp={tp} must be >= 1 and divide the local device "
            f"count ({n})")
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def mesh_for_serving(n_devices: Optional[int] = None, tp: int = 1
                     ) -> jax.sharding.Mesh:
    """A validated (data, model) serving mesh over ``n_devices``
    (default: all local devices) with tensor-parallel degree ``tp``.

    Raises :class:`repro.serving.errors.MeshConfigError` — never a bare
    ``ValueError`` — when the shape can't be built: ``tp`` not dividing
    ``n_devices``, or more devices requested than exist.  The serving
    engine takes the result directly: ``ServingEngine(..., mesh=...)``
    runs ``data`` replicas of the slot space and shards heads/MLP width
    over ``model``."""
    from ..serving.errors import MeshConfigError
    avail = len(jax.devices())
    n = n_devices if n_devices is not None else avail
    if n < 1 or n > avail:
        raise MeshConfigError(
            f"n_devices={n} out of range: {avail} device(s) available")
    if tp < 1 or n % tp != 0:
        raise MeshConfigError(
            f"tp={tp} must be >= 1 and divide n_devices={n}")
    devices = np.asarray(jax.devices()[:n]).reshape(n // tp, tp)
    return jax.sharding.Mesh(devices, ("data", "model"))


def data_axis_names(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes over which the batch is sharded (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_info(mesh: jax.sharding.Mesh) -> dict:
    return {
        "axis_names": mesh.axis_names,
        "shape": dict(mesh.shape),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
