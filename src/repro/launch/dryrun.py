import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, prove memory/sharding coherence, and dump roofline
inputs.

MUST be the process entry point (jax locks device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun

Per cell it prints/records:
  * compiled.memory_analysis()  — per-device bytes (fits/doesn't fit)
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline
  * collective schedule summary — parsed from the compiled HLO
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from dataclasses import replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SkipSpec, get_config, get_shapes,
                           input_specs)
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.train import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import lm as LM


def _mem_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        out = {
            "bytes": float(getattr(ma, "temp_size_in_bytes", 0)
                           + getattr(ma, "argument_size_in_bytes", 0)
                           + getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes",
                                            0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
        }
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


ACCUM_STEPS = {
    # giant models: microbatch so per-device activations fit 16GB HBM
    "arctic-480b": 8,
    "jamba-1.5-large-398b": 8,
    "yi-34b": 2,
}


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: Optional[str] = None,
             optimizer: Optional[str] = None,
             accum_steps: Optional[int] = None,
             skip_cost: bool = False) -> Dict:
    cfg = get_config(arch)
    # dry-run lowers the pure-jnp reference path (Pallas kernels target
    # real TPUs; interpret-mode kernels don't belong in an HLO dry-run)
    cfg = replace(cfg, attn_backend="ref")
    spec = get_shapes(arch)[shape_name]
    if isinstance(spec, SkipSpec):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": spec.reason}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = len(mesh.devices.reshape(-1))
    if optimizer is None:
        # giant MoEs train with factored second moments to fit HBM
        optimizer = "adafactor" if arch in ("arctic-480b",
                                            "jamba-1.5-large-398b") \
            else "adamw"

    def lower_cell(cfg_l, accum):
        if spec.kind == "train":
            batch_abs = input_specs(cfg_l, spec)
            step, _s, state_abs, _ = make_train_step(
                cfg_l, mesh, optimizer=optimizer, batch_abs=batch_abs,
                accum_steps=accum)
            return step.lower(state_abs, batch_abs)
        if spec.kind == "prefill":
            step, _p, params_abs = make_prefill_step(cfg_l, mesh)
            return step.lower(params_abs, input_specs(cfg_l, spec))
        step, _p, params_abs, _c, cache_abs = make_serve_step(
            cfg_l, mesh, batch=spec.global_batch, max_seq=spec.seq_len)
        io = input_specs(cfg_l, spec)
        return step.lower(params_abs, cache_abs, io["tokens"], io["pos"])

    if accum_steps is None:
        accum_steps = ACCUM_STEPS.get(arch, 1) if spec.kind == "train" \
            else 1

    t0 = time.time()
    with mesh:
        # pass 1 — production form (scan-over-groups, grad accumulation):
        # proves sharding/memory coherence; memory_analysis is taken here.
        lowered = lower_cell(cfg, accum_steps)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = _mem_summary(compiled)

        # pass 2 — cost form (groups unrolled, accum=1): XLA counts a
        # while-loop body ONCE in cost_analysis, so the exact FLOPs /
        # bytes / collective schedule come from the unrolled lowering.
        # For deep pattern-len-1 stacks (n_groups > 12, no tail) we use
        # an AFFINE TWO-POINT method instead of unrolling all L layers:
        # lower 1-group and 2-group unrolled models; the per-group cost
        # is their difference (cost is affine in group count), so
        # total = c1 + (n_groups-1)·(c2-c1).  Validated against the full
        # unroll on gemma-2b (<1% error, see EXPERIMENTS §Roofline).
        t0 = time.time()
        cost = {}
        hlo = ""
        extrapolated = False
        if skip_cost:
            cost = dict(compiled.cost_analysis() or {})
            hlo = compiled.as_text()
        elif cfg.n_groups > 12 and not cfg.tail:
            extrapolated = True
            plen = len(cfg.pattern)
            metrics = []
            for g in (1, 2):
                c = lower_cell(replace(cfg, n_layers=g * plen,
                                       unroll_groups=True), 1).compile()
                ca = dict(c.cost_analysis() or {})
                coll = RL.collective_bytes(c.as_text())
                coll.pop("_counts", None)
                metrics.append((float(ca.get("flops", 0.0)),
                                float(ca.get("bytes accessed", 0.0)),
                                {k: float(v) for k, v in coll.items()}))
            n = cfg.n_groups
            f1, b1, co1 = metrics[0]
            f2, b2, co2 = metrics[1]
            cost = {"flops": f1 + (n - 1) * (f2 - f1),
                    "bytes accessed": b1 + (n - 1) * (b2 - b1)}
            # synthesize an HLO-free collective total via the same affine
            # rule; stash for RL.analyze through a fake hlo-less path
            coll_total = {k: co1.get(k, 0) + (n - 1)
                          * (co2.get(k, 0) - co1.get(k, 0))
                          for k in co1}
            hlo = None
            _coll_override = coll_total
        else:
            cost_cfg = replace(cfg, unroll_groups=True)
            compiled_cost = lower_cell(cost_cfg, 1).compile()
            cost = dict(compiled_cost.cost_analysis() or {})
            hlo = compiled_cost.as_text()
        t_cost = time.time() - t0
    # train cost pass ran accum=1 over the full batch: same total tokens
    if hlo is None:
        rl = RL.analyze(arch, shape_name, mesh_name, n_dev, cfg, spec,
                        spec.kind, cost, "", mem)
        rl.collective_breakdown = {k: int(v)
                                   for k, v in _coll_override.items()}
        coll_total_bytes = float(sum(_coll_override.values()))
        rl.collective_bytes_per_device = coll_total_bytes
        rl.collective_s = coll_total_bytes / RL.ICI_BW
        terms = {"compute": rl.compute_s, "memory": rl.memory_s,
                 "collective": rl.collective_s}
        rl.dominant = max(terms, key=terms.get)
        rl.note = "cost via affine 2-point extrapolation over groups"
    else:
        rl = RL.analyze(arch, shape_name, mesh_name, n_dev, cfg, spec,
                        spec.kind, cost, hlo, mem)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "n_devices": n_dev, "optimizer": optimizer,
        "accum_steps": accum_steps,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_pass_s": round(t_cost, 2),
        "memory": mem,
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "roofline": rl.as_dict(),
    }
    print(f"[{arch} × {shape_name} × {mesh_name}] "
          f"dev={n_dev} bytes/dev={mem.get('bytes', 0)/1e9:.2f}GB "
          f"flops/dev={rl.flops_per_device/1e9:.1f}G "
          f"coll/dev={rl.collective_bytes_per_device/1e6:.1f}MB "
          f"dominant={rl.dominant} "
          f"(compile {t_compile:.1f}s)")
    print("  memory_analysis:", json.dumps(mem))
    print("  cost_analysis: flops=%.3e bytes=%.3e" % (
        rl.flops_per_device, rl.bytes_per_device))

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}.json".replace("/", "_")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--skip-cost", action="store_true",
                    help="skip the unrolled cost pass (multi-pod validity "
                         "runs)")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape_name in get_shapes(arch):
                for m in meshes:
                    cells.append((arch, shape_name, m))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = []
    for arch, shape_name, m in cells:
        try:
            run_cell(arch, shape_name, m, out_dir=args.out,
                     optimizer=args.optimizer,
                     skip_cost=(args.skip_cost or m == "multi"))
        except Exception as e:
            failures.append((arch, shape_name, m, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"\nFAILED {len(failures)} cells:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"\nall {len(cells)} cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
