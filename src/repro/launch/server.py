"""Streaming HTTP/SSE serving entry point.

    PYTHONPATH=src python -m repro.launch.server [--preset tiny|small]
        [--host 127.0.0.1] [--port 8008] [--num-pages N]
        [--hwm-frac F] [--max-stream-tokens N] [--selftest N]

A dependency-free asyncio HTTP server (``asyncio.start_server`` — no
aiohttp in the container) over :class:`~repro.serving.AsyncFrontend`.
One event-loop task drives the engine (``frontend.run``); each client
connection is a coroutine consuming an async token stream.

Routes::

    POST /generate   JSON {"prompt": [ints], "max_new_tokens": 16,
                           "priority": 0, "tenant": "default",
                           "ttft_deadline_ms": null, "timeout_ms": null}
                     -> text/event-stream, one SSE event per token:
                          event: token
                          data: {"token": 17, "index": 0}
                        ending with exactly one terminal event
                        (event: finished | cancelled | timed_out |
                         failed).  Backpressure shed -> 503 with a
                        Retry-After header; other admission rejections
                        -> 429; bad JSON -> 400.
    GET  /metrics    engine + frontend counters as JSON
    GET  /healthz    200 "ok"

Disconnect semantics: if the client drops mid-stream the write fails,
the handler abandons the async generator, and its ``finally`` cancels
the request — KV pages free on the same scheduler tick.  ``--selftest
N`` starts the server on an ephemeral port, streams N requests through
a real socket with :func:`sse_client`, prints the metrics, and exits
nonzero on any failure (the CI smoke for this module).
"""

from __future__ import annotations

import argparse
import asyncio
import json
from typing import AsyncIterator, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.lm import LMConfig, init_params
from ..serving.engine import ServingEngine
from ..serving.errors import AdmissionRejected, BackpressureRejected
from ..serving.frontend import AsyncFrontend
from .serve import PRESETS

__all__ = ["HttpFrontendServer", "sse_client", "main"]


def _response(status: str, headers: Dict[str, str], body: bytes) -> bytes:
    head = [f"HTTP/1.1 {status}"]
    head += [f"{k}: {v}" for k, v in headers.items()]
    head += [f"Content-Length: {len(body)}", "Connection: close", "", ""]
    return "\r\n".join(head).encode() + body


def _sse(event: str, data: dict) -> bytes:
    return (f"event: {event}\ndata: {json.dumps(data)}\n\n").encode()


class HttpFrontendServer:
    """Raw-asyncio HTTP/SSE wrapper around an :class:`AsyncFrontend`.

    ``start`` binds the socket and spawns the engine-pump task;
    ``stop`` drains both.  The server object exposes ``port`` after
    ``start`` so tests can bind port 0."""

    def __init__(self, frontend: AsyncFrontend, host: str = "127.0.0.1",
                 port: int = 8008):
        self.frontend = frontend
        self.host, self.port = host, port
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None

    async def start(self) -> None:
        """Bind the listening socket and start the engine-pump task."""
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._pump_task = asyncio.ensure_future(self.frontend.run())

    async def stop(self) -> None:
        """Close the socket, stop the pump, cancel open streams."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.frontend.close()
        if self._pump_task is not None:
            await self._pump_task

    # -- request handling ---------------------------------------------------
    async def _read_request(self, reader: asyncio.StreamReader
                            ) -> Tuple[str, str, bytes]:
        line = await reader.readline()
        if not line:
            return "", "", b""
        method, path, _ = line.decode().split(" ", 2)
        clen = 0
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, val = h.decode().partition(":")
            if name.strip().lower() == "content-length":
                clen = int(val.strip())
        body = await reader.readexactly(clen) if clen else b""
        return method, path, body

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, body = await self._read_request(reader)
            if not method:
                return
            if method == "GET" and path == "/healthz":
                writer.write(_response(
                    "200 OK", {"Content-Type": "text/plain"}, b"ok"))
            elif method == "GET" and path == "/metrics":
                payload = json.dumps(self.frontend.stats(),
                                     default=str).encode()
                writer.write(_response(
                    "200 OK", {"Content-Type": "application/json"},
                    payload))
            elif method == "POST" and path == "/generate":
                await self._generate(writer, body)
            else:
                writer.write(_response(
                    "404 Not Found", {"Content-Type": "text/plain"},
                    b"not found"))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            pass                      # client went away; nothing to do
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = [int(t) for t in spec["prompt"]]
        except (ValueError, KeyError, TypeError) as e:
            writer.write(_response(
                "400 Bad Request", {"Content-Type": "text/plain"},
                f"bad request body: {e}".encode()))
            return
        try:
            stream = self.frontend.stream(
                prompt,
                int(spec.get("max_new_tokens", 16)),
                priority=int(spec.get("priority", 0)),
                tenant=str(spec.get("tenant", "default")),
                ttft_deadline_ms=spec.get("ttft_deadline_ms"),
                timeout_ms=spec.get("timeout_ms"))
            first = await stream.__anext__()   # admission errors surface here
        except BackpressureRejected as e:
            writer.write(_response(
                "503 Service Unavailable",
                {"Content-Type": "text/plain",
                 "Retry-After": f"{e.retry_after_s:g}"},
                str(e).encode()))
            return
        except AdmissionRejected as e:
            writer.write(_response(
                "429 Too Many Requests", {"Content-Type": "text/plain"},
                str(e).encode()))
            return
        writer.write(("HTTP/1.1 200 OK\r\n"
                      "Content-Type: text/event-stream\r\n"
                      "Cache-Control: no-cache\r\n"
                      "Connection: close\r\n\r\n").encode())
        try:
            ev = first
            while True:
                if ev.terminal:
                    writer.write(_sse(ev.kind, {
                        "req_id": ev.req_id, "error": ev.error}))
                    await writer.drain()
                    return
                writer.write(_sse("token", {
                    "token": ev.token, "index": ev.index}))
                await writer.drain()   # raises when the client is gone
                ev = await stream.__anext__()
        finally:
            # disconnect or server shutdown: abandoning the generator
            # runs its finally -> engine.cancel -> pages free now
            await stream.aclose()


async def sse_client(host: str, port: int, spec: dict,
                     max_events: Optional[int] = None
                     ) -> AsyncIterator[Tuple[str, dict]]:
    """Minimal SSE client: POST ``spec`` to ``/generate`` and yield
    ``(event, data)`` pairs.  Stops after the terminal event, after
    ``max_events`` events (simulating a client that walks away
    mid-stream), or on a non-200 status (yielding one synthetic
    ``("http_error", {"status": ..., "retry_after": ...})`` pair)."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(spec).encode()
    writer.write((f"POST /generate HTTP/1.1\r\n"
                  f"Host: {host}\r\nContent-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    try:
        status_line = (await reader.readline()).decode()
        status = int(status_line.split(" ", 2)[1])
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode().partition(":")
            headers[k.strip().lower()] = v.strip()
        if status != 200:
            yield "http_error", {
                "status": status,
                "retry_after": headers.get("retry-after")}
            return
        seen = 0
        event, data = "message", {}
        while True:
            line = await reader.readline()
            if not line:
                return
            text = line.decode().rstrip("\n").rstrip("\r")
            if text.startswith("event:"):
                event = text[6:].strip()
            elif text.startswith("data:"):
                data = json.loads(text[5:].strip())
            elif text == "":
                yield event, data
                seen += 1
                if event != "token":
                    return
                if max_events is not None and seen >= max_events:
                    return            # walk away mid-stream
                event, data = "message", {}
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


def build_engine(preset: str, *, num_pages: int, page_size: int,
                 max_batch: int, chunk: int) -> ServingEngine:
    """Construct the preset engine the server fronts (same presets as
    ``launch.serve`` so the two entry points stay comparable)."""
    cfg = LMConfig(name=f"server-{preset}", **PRESETS[preset],
                   param_dtype=jnp.float32, remat="none",
                   attn_backend="ref")
    params = init_params(cfg, jax.random.key(0))
    return ServingEngine(cfg, params, page_size=page_size,
                         num_pages=num_pages, max_batch=max_batch,
                         chunk_size=chunk)


async def _selftest(server: HttpFrontendServer, n: int,
                    vocab: int) -> int:
    """Drive ``n`` streams through a real socket; return the number
    that reached a terminal ``finished`` event with >= 1 token."""
    ok = 0
    for i in range(n):
        prompt = [(3 + 5 * i + j) % (vocab - 1) + 1 for j in range(6)]
        toks: List[int] = []
        terminal = None
        async for ev, data in sse_client(
                server.host, server.port,
                {"prompt": prompt, "max_new_tokens": 4}):
            if ev == "token":
                toks.append(data["token"])
            else:
                terminal = ev
        if terminal == "finished" and toks:
            ok += 1
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8008)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--hwm-frac", type=float, default=0.95,
                    help="page watermark for high-priority admission")
    ap.add_argument("--max-stream-tokens", type=int, default=256,
                    help="hard cap on any one request's token budget")
    ap.add_argument("--max-queue-depth", type=int, default=64)
    ap.add_argument("--selftest", type=int, default=None, metavar="N",
                    help="serve N requests through a real socket on an "
                         "ephemeral port, print metrics, and exit")
    args = ap.parse_args()

    eng = build_engine(args.preset, num_pages=args.num_pages,
                       page_size=args.page_size,
                       max_batch=args.max_batch, chunk=args.chunk)
    fe = AsyncFrontend(eng, hwm_frac=args.hwm_frac,
                       max_queue_depth=args.max_queue_depth,
                       max_stream_tokens=args.max_stream_tokens)
    port = 0 if args.selftest else args.port
    server = HttpFrontendServer(fe, args.host, port)

    async def serve() -> int:
        await server.start()
        print(f"[server] listening on http://{server.host}:{server.port}"
              f"  (preset={args.preset})")
        if args.selftest is not None:
            vocab = PRESETS[args.preset]["vocab_size"]
            ok = await _selftest(server, args.selftest, vocab)
            await server.stop()
            print(json.dumps(server.frontend.stats(), default=str,
                             indent=2))
            print(f"[selftest] {ok}/{args.selftest} streams finished")
            return 0 if ok == args.selftest else 1
        try:
            await asyncio.Event().wait()      # serve until Ctrl-C
        except (KeyboardInterrupt, asyncio.CancelledError):
            pass
        await server.stop()
        return 0

    raise SystemExit(asyncio.run(serve()))


if __name__ == "__main__":
    main()
