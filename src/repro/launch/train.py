"""Distributed step builders: train / prefill / decode under pjit.

``make_train_step`` assembles loss → grad → clip → optimizer into one
pjit-ed function with full sharding annotations (params per
``distributed.sharding``, optimizer state inheriting param specs =
ZeRO-sharded, batch over ('pod','data')).  Buffer donation on the state
makes the update in-place at the XLA level.

Also the CLI trainer used by the examples: synthetic/real DataLoader,
checkpoint/restart (preemption-safe), straggler-aware step timing.
"""

from __future__ import annotations

import functools
import time
from dataclasses import replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import act_sharding as AS
from ..distributed import sharding as S
from ..models import lm as LM
from ..optim.functional import clip_by_global_norm, make_optimizer

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# spec derivation for optimizer state
# ----------------------------------------------------------------------

def opt_state_specs(opt_state_abs, param_spec_tree):
    """Optimizer-state PartitionSpecs: moment tensors inherit the param
    spec; Adafactor row/col drop the reduced dim's entry; scalars
    replicate."""

    def like(sub_abs, sub_specs):
        return jax.tree_util.tree_map(
            lambda leaf, spec: spec, sub_abs, sub_specs)

    specs = {}
    for key, sub in opt_state_abs.items():
        if key in ("m", "v", "momentum"):
            specs[key] = like(sub, param_spec_tree)
        elif key == "fac":
            def fac_spec(p_spec, fac_leaf_dict):
                out = {}
                for k2, leaf in fac_leaf_dict.items():
                    if k2 == "row":      # param shape minus last dim
                        out[k2] = P(*tuple(p_spec)[:-1]) \
                            if len(tuple(p_spec)) else P()
                    elif k2 == "col":    # minus second-to-last
                        t = tuple(p_spec)
                        out[k2] = P(*(t[:-2] + t[-1:])) if len(t) >= 2 \
                            else P()
                    else:                # "v" for 1-d params
                        out[k2] = P(*tuple(p_spec))
                return out

            specs[key] = jax.tree_util.tree_map(
                fac_spec, param_spec_tree, sub,
                is_leaf=lambda x: isinstance(x, dict)
                and ("row" in x or "v" in x))
        else:
            specs[key] = jax.tree_util.tree_map(lambda _: P(), sub)
    return specs


def shard_tree(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# step builders
# ----------------------------------------------------------------------

def make_train_step(cfg: LM.LMConfig, mesh: Mesh, *,
                    optimizer: str = "adamw", lr: float = 3e-4,
                    grad_clip: float = 1.0, donate: bool = True,
                    batch_abs: Optional[Dict] = None,
                    accum_steps: int = 1,
                    foreach: bool = False,
                    opt_kwargs: Optional[Dict] = None):
    """Returns (train_step_jit, state_shardings, abstract_state,
    batch_shardings_fn).  Pass ``batch_abs`` (ShapeDtypeStructs) so the
    batch input shardings are pinned at jit time (required for the
    dry-run's .lower()).

    ``foreach=True`` selects the fused multi-tensor optimizer update
    (bucketed concat, one kernel per dtype bucket) — fewer HLO ops and
    faster compiles on single-device/replicated meshes, but keep it off
    when params are sharded (concat gathers across shards)."""
    opt_kwargs = dict(opt_kwargs or {})
    if optimizer == "adafactor":
        opt_kwargs.setdefault("lr", lr)
    else:
        opt_kwargs.setdefault("lr", lr)
    init_opt, update_opt = make_optimizer(optimizer, foreach=foreach,
                                          **opt_kwargs)

    params_abs = LM.abstract_params(cfg)
    opt_abs = jax.eval_shape(init_opt, params_abs)
    p_specs = S.param_specs(cfg, params_abs, mesh)
    o_specs = opt_state_specs(opt_abs, p_specs)
    state_specs = {"params": p_specs, "opt": o_specs, "step": P()}
    state_shardings = shard_tree(mesh, state_specs)
    state_abs = {"params": params_abs, "opt": opt_abs,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def train_step(state, batch):
        def loss_fn(p, b):
            with AS.scope(mesh):
                return LM.lm_loss(cfg, p, b)

        if accum_steps <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"],
                                                      batch)
        else:
            # gradient accumulation: scan over microbatches; activation
            # memory scales with batch/accum_steps instead of batch
            def micro(i):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape(
                        (accum_steps, x.shape[0] // accum_steps)
                        + x.shape[1:])[i] if hasattr(x, 'shape') and
                    x.ndim > 0 else x, batch)

            def body(carry, i):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state["params"],
                                                   micro(i))
                return (loss_acc + l,
                        jax.tree_util.tree_map(jnp.add, grad_acc, g)), None

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32),
                state["params"])
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_grads),
                jnp.arange(accum_steps))
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps,
                                           grads)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = update_opt(grads, state["opt"],
                                         state["params"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_state, metrics

    def batch_shardings(b_abs):
        return {k: NamedSharding(mesh, s)
                for k, s in S.batch_specs(cfg, b_abs, mesh).items()}

    jit_step = jax.jit(
        train_step,
        in_shardings=(state_shardings,
                      batch_shardings(batch_abs) if batch_abs else None),
        out_shardings=(state_shardings,
                       {"loss": S.replicated(mesh),
                        "grad_norm": S.replicated(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return jit_step, state_shardings, state_abs, batch_shardings


def make_prefill_step(cfg: LM.LMConfig, mesh: Mesh):
    params_abs = LM.abstract_params(cfg)
    p_shardings = shard_tree(mesh, S.param_specs(cfg, params_abs, mesh))

    def prefill(params, batch):
        with AS.scope(mesh):
            logits, _ = LM.forward(cfg, params, tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"))
        return logits

    jit_step = jax.jit(prefill, in_shardings=(p_shardings, None))
    return jit_step, p_shardings, params_abs


def make_serve_step(cfg: LM.LMConfig, mesh: Mesh, *, batch: int,
                    max_seq: int, cache_dtype=jnp.bfloat16,
                    donate_cache: bool = True):
    """Single-token decode step, cache donated (in-place update)."""
    params_abs = LM.abstract_params(cfg)
    p_shardings = shard_tree(mesh, S.param_specs(cfg, params_abs, mesh))
    cache_abs = LM.abstract_cache(cfg, batch, max_seq, cache_dtype)
    c_shardings = shard_tree(mesh, S.cache_specs(cfg, cache_abs, mesh))

    def serve_step(params, cache, tokens, pos):
        with AS.scope(mesh):
            logits, new_cache = LM.decode_step(cfg, params, cache, tokens,
                                               pos)
        return logits, new_cache

    jit_step = jax.jit(
        serve_step,
        in_shardings=(p_shardings, c_shardings, None, None),
        out_shardings=(None, c_shardings),
        donate_argnums=(1,) if donate_cache else (),
    )
    return jit_step, p_shardings, params_abs, c_shardings, cache_abs


# ----------------------------------------------------------------------
# the runnable trainer (examples/end-to-end driver calls this)
# ----------------------------------------------------------------------

def train_loop(cfg: LM.LMConfig, *, steps: int, batch_size: int,
               seq_len: int, mesh: Optional[Mesh] = None,
               optimizer: str = "adamw", lr: float = 3e-4,
               foreach: bool = False,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 100,
               log_every: int = 10, seed: int = 0,
               straggler_threshold: float = 3.0) -> Dict[str, Any]:
    """Real training on synthetic LM data.  Restores from checkpoint_dir
    if present (fault-tolerant restart); saves asynchronously."""
    from ..checkpoint import CheckpointManager
    from ..data import DataLoader, SyntheticLMDataset

    if mesh is None:
        from .mesh import make_local_mesh
        mesh = make_local_mesh()

    step_fn, state_shardings, state_abs, batch_sharding_fn = \
        make_train_step(cfg, mesh, optimizer=optimizer, lr=lr,
                        foreach=foreach)

    with mesh:
        params = jax.jit(
            functools.partial(LM.init_params, cfg),
            out_shardings=state_shardings["params"],
        )(jax.random.key(seed))
        init_opt, _ = make_optimizer(optimizer, lr=lr)
        opt = jax.jit(init_opt,
                      out_shardings=state_shardings["opt"])(params)
        state = {"params": params, "opt": opt,
                 "step": jnp.zeros((), jnp.int32)}

        ckpt = None
        start_step = 0
        if checkpoint_dir:
            ckpt = CheckpointManager(checkpoint_dir)
            restored = ckpt.restore_latest(state, mesh)
            if restored is not None:
                state = restored
                start_step = int(jax.device_get(state["step"]))

        ds = SyntheticLMDataset(cfg.vocab_size, seq_len, size=1 << 20,
                                seed=seed)
        loader = DataLoader(ds, batch_size=batch_size, shuffle=True,
                            num_workers=2, seed=seed, drop_last=True)

        history = []
        step_times = []
        it = iter(loader)
        t_loop = time.perf_counter()
        for step in range(start_step, steps):
            try:
                tokens, labels = next(it)
            except StopIteration:
                it = iter(loader)
                tokens, labels = next(it)
            batch = {"tokens": jnp.asarray(tokens.data),
                     "labels": jnp.asarray(labels.data)}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            step_times.append(dt)
            # straggler watchdog: flag steps >> median
            if len(step_times) > 10:
                med = float(np.median(step_times[-50:]))
                if dt > straggler_threshold * med:
                    print(f"[straggler] step {step}: {dt:.3f}s "
                          f"(median {med:.3f}s)")
            history.append(loss)
            if step % log_every == 0:
                tok_s = batch_size * seq_len / dt
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"{dt*1e3:6.1f} ms/step  {tok_s:,.0f} tok/s")
            if ckpt and step > 0 and step % checkpoint_every == 0:
                ckpt.save_async(state, step)
        if ckpt:
            ckpt.save(state, steps)
            ckpt.wait()
        wall = time.perf_counter() - t_loop
        return {"losses": history, "steps": steps - start_step,
                "wall_time_s": wall, "final_loss": history[-1]
                if history else None}
