"""nn.functional — stateless ops over Tensors.

Every op here is implemented as a *jnp-level* function and routed through
the eager dispatcher as a single tape node (its backward is the exact
``jax.vjp`` of the fused computation).  This mirrors how PyTorch backs
``F.*`` with single fused ATen kernels rather than building them out of
primitive tape nodes — and it keeps eager dispatch overhead at one node per
layer-level op.

Dispatch-cache contract (see ``core.dispatch``): every op passes a
``static=`` tuple naming **every** kwarg its closure captures besides the
tensor operands (``dim``, ``approximate``, ``eps``, strides, reduction
mode, ...).  Repeated layer calls then replay cached jitted executables
instead of re-tracing ``jax.vjp`` — and a forgotten capture would replay a
stale closure with silently wrong results, which is exactly what
``tests/test_functional_conformance.py`` and ``tests/test_gradcheck.py``
exist to catch.  Array-valued values an op depends on (indices, targets,
masks, running stats) are passed as *operands*, never closed over: a
closed-over array would be baked stale into the cached executable.

Op names are shared with the ``Tensor`` method surface where semantics
coincide (``tanh``, ``sigmoid``, ``relu``, ``softmax``, ``log_softmax``)
so both spellings hit one cache entry.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tensor import Tensor, _apply_op, _coerce, _is_tracer, _raw

# ----------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------

def relu(x: Tensor) -> Tensor:
    return _apply_op("relu", jax.nn.relu, _coerce(x), static=())


def relu6(x: Tensor) -> Tensor:
    return _apply_op("relu6", jax.nn.relu6, _coerce(x), static=())


def gelu(x: Tensor, approximate: str = "tanh") -> Tensor:
    return _apply_op(
        "gelu",
        lambda v: jax.nn.gelu(v, approximate=(approximate == "tanh")),
        _coerce(x), static=(approximate,))


def silu(x: Tensor) -> Tensor:
    return _apply_op("silu", jax.nn.silu, _coerce(x), static=())


def sigmoid(x: Tensor) -> Tensor:
    return _apply_op("sigmoid", jax.nn.sigmoid, _coerce(x), static=())


def tanh(x: Tensor) -> Tensor:
    return _apply_op("tanh", jnp.tanh, _coerce(x), static=())


def softmax(x: Tensor, dim: int = -1) -> Tensor:
    return _apply_op("softmax", lambda v: jax.nn.softmax(v, axis=dim),
                     _coerce(x), static=(dim,))


def log_softmax(x: Tensor, dim: int = -1) -> Tensor:
    return _apply_op("log_softmax",
                     lambda v: jax.nn.log_softmax(v, axis=dim), _coerce(x),
                     static=(dim,))


def leaky_relu(x: Tensor, negative_slope: float = 0.01) -> Tensor:
    return _apply_op(
        "leaky_relu",
        lambda v: jax.nn.leaky_relu(v, negative_slope), _coerce(x),
        static=(negative_slope,))


def elu(x: Tensor, alpha: float = 1.0) -> Tensor:
    return _apply_op("elu", lambda v: jax.nn.elu(v, alpha), _coerce(x),
                     static=(alpha,))


def softplus(x: Tensor) -> Tensor:
    return _apply_op("softplus", jax.nn.softplus, _coerce(x), static=())


def hardswish(x: Tensor) -> Tensor:
    return _apply_op("hardswish", jax.nn.hard_swish, _coerce(x), static=())


# ----------------------------------------------------------------------
# linear / embedding
# ----------------------------------------------------------------------

def linear(x: Tensor, weight: Tensor, bias: Optional[Tensor] = None) -> Tensor:
    """y = x @ W^T + b  (torch layout: weight is (out, in))."""
    x, weight = _coerce(x), _coerce(weight)
    if bias is None:
        return _apply_op("linear", lambda v, w: v @ w.T, x, weight,
                         static=())
    return _apply_op("linear",
                     lambda v, w, b: v @ w.T + b, x, weight, _coerce(bias),
                     static=())


def embedding(indices: Tensor, weight: Tensor) -> Tensor:
    # indices ride as an integer *operand* (non-diffable position), not a
    # closure capture: new index values replay the same cached entry
    return _apply_op("embedding",
                     lambda w, i: jnp.take(w, i, axis=0),
                     _coerce(weight), _coerce(indices), static=())


# ----------------------------------------------------------------------
# normalization
# ----------------------------------------------------------------------

def layer_norm(x: Tensor, normalized_shape: Sequence[int],
               weight: Optional[Tensor] = None,
               bias: Optional[Tensor] = None, eps: float = 1e-5) -> Tensor:
    axes = tuple(range(-len(tuple(normalized_shape)), 0))

    def _ln(v, *wb):
        mean = jnp.mean(v, axis=axes, keepdims=True)
        var = jnp.var(v, axis=axes, keepdims=True)
        out = (v - mean) * jax.lax.rsqrt(var + eps)
        if wb:
            out = out * wb[0]
            if len(wb) > 1:
                out = out + wb[1]
        return out

    args = [_coerce(x)]
    if weight is not None:
        args.append(_coerce(weight))
        if bias is not None:
            args.append(_coerce(bias))
    return _apply_op("layer_norm", _ln, *args, static=(axes, eps))


def rms_norm(x: Tensor, weight: Optional[Tensor] = None,
             eps: float = 1e-6, offset: float = 0.0) -> Tensor:
    """RMSNorm; ``offset=1.0`` gives the Gemma convention (1+w scaling)."""

    def _rms(v, *w):
        var = jnp.mean(jnp.square(v.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        out = v * jax.lax.rsqrt(var + eps).astype(v.dtype)
        if w:
            out = out * (offset + w[0])
        return out

    args = [_coerce(x)]
    if weight is not None:
        args.append(_coerce(weight))
    return _apply_op("rms_norm", _rms, *args, static=(eps, offset))


def batch_norm(x: Tensor, running_mean, running_var,
               weight: Optional[Tensor] = None,
               bias: Optional[Tensor] = None, training: bool = False,
               momentum: float = 0.1, eps: float = 1e-5) -> Tensor:
    """2d batch norm over NCHW.  In training mode, running stats are
    updated in place on the buffer tensors (imperative semantics)."""
    x = _coerce(x)
    reduce_axes = (0,) + tuple(range(2, x.ndim))
    shape = (1, x.shape[1]) + (1,) * (x.ndim - 2)

    if training:
        if running_mean is not None and not _is_tracer(x.data):
            batch_mean = jnp.mean(x.data, axis=reduce_axes)
            batch_var = jnp.var(x.data, axis=reduce_axes)
            running_mean._data = ((1 - momentum) * running_mean.data
                                  + momentum * batch_mean)
            running_var._data = ((1 - momentum) * running_var.data
                                 + momentum * batch_var)
            running_mean._version.bump()
            running_var._version.bump()

        def _bn(v, *wb):
            m = jnp.mean(v, axis=reduce_axes).reshape(shape)
            var = jnp.var(v, axis=reduce_axes).reshape(shape)
            out = (v - m) * jax.lax.rsqrt(var + eps)
            if wb:
                out = out * wb[0].reshape(shape)
                if len(wb) > 1:
                    out = out + wb[1].reshape(shape)
            return out

        args = [x]
    else:
        # eval mode: running stats are *operands* (they mutate across
        # train steps — closing over them would cache stale values)
        def _bn(v, m, var, *wb):
            m = m.reshape(shape)
            var = var.reshape(shape)
            out = (v - m) * jax.lax.rsqrt(var + eps)
            if wb:
                out = out * wb[0].reshape(shape)
                if len(wb) > 1:
                    out = out + wb[1].reshape(shape)
            return out

        args = [x, _coerce(running_mean), _coerce(running_var)]

    if weight is not None:
        args.append(_coerce(weight))
        if bias is not None:
            args.append(_coerce(bias))
    return _apply_op("batch_norm", _bn, *args, static=(training, eps))


# ----------------------------------------------------------------------
# convolution / pooling (NCHW, torch layout)
# ----------------------------------------------------------------------

def _pair(v) -> Tuple[int, int]:
    return (v, v) if isinstance(v, int) else tuple(v)


def conv2d(x: Tensor, weight: Tensor, bias: Optional[Tensor] = None,
           stride: Union[int, Tuple[int, int]] = 1,
           padding: Union[int, Tuple[int, int], str] = 0,
           dilation: Union[int, Tuple[int, int]] = 1,
           groups: int = 1) -> Tensor:
    stride = _pair(stride)
    dilation = _pair(dilation)
    if isinstance(padding, str):
        pad = padding.upper()
    else:
        p = _pair(padding)
        pad = ((p[0], p[0]), (p[1], p[1]))

    def _conv(v, w, *b):
        out = jax.lax.conv_general_dilated(
            v, w, window_strides=stride, padding=pad,
            rhs_dilation=dilation, feature_group_count=groups,
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if b:
            out = out + b[0].reshape(1, -1, 1, 1)
        return out

    args = [_coerce(x), _coerce(weight)]
    if bias is not None:
        args.append(_coerce(bias))
    return _apply_op("conv2d", _conv, *args,
                     static=(stride, pad, dilation, groups))


def conv1d(x: Tensor, weight: Tensor, bias: Optional[Tensor] = None,
           stride: int = 1, padding: int = 0, dilation: int = 1,
           groups: int = 1) -> Tensor:
    def _conv(v, w, *b):
        out = jax.lax.conv_general_dilated(
            v, w, window_strides=(stride,), padding=((padding, padding),),
            rhs_dilation=(dilation,), feature_group_count=groups,
            dimension_numbers=("NCH", "OIH", "NCH"))
        if b:
            out = out + b[0].reshape(1, -1, 1)
        return out

    args = [_coerce(x), _coerce(weight)]
    if bias is not None:
        args.append(_coerce(bias))
    return _apply_op("conv1d", _conv, *args,
                     static=(stride, padding, dilation, groups))


def max_pool2d(x: Tensor, kernel_size, stride=None, padding=0) -> Tensor:
    k = _pair(kernel_size)
    s = _pair(stride if stride is not None else kernel_size)
    p = _pair(padding)

    def _pool(v):
        return jax.lax.reduce_window(
            v, -jnp.inf, jax.lax.max,
            window_dimensions=(1, 1) + k,
            window_strides=(1, 1) + s,
            padding=((0, 0), (0, 0), (p[0], p[0]), (p[1], p[1])))

    return _apply_op("max_pool2d", _pool, _coerce(x), static=(k, s, p))


def avg_pool2d(x: Tensor, kernel_size, stride=None, padding=0) -> Tensor:
    k = _pair(kernel_size)
    s = _pair(stride if stride is not None else kernel_size)
    p = _pair(padding)

    def _pool(v):
        summed = jax.lax.reduce_window(
            v, 0.0, jax.lax.add,
            window_dimensions=(1, 1) + k,
            window_strides=(1, 1) + s,
            padding=((0, 0), (0, 0), (p[0], p[0]), (p[1], p[1])))
        return summed / (k[0] * k[1])

    return _apply_op("avg_pool2d", _pool, _coerce(x), static=(k, s, p))


def adaptive_avg_pool2d(x: Tensor, output_size) -> Tensor:
    out = _pair(output_size)

    def _pool(v):
        n, c, h, w = v.shape
        if h >= out[0] and w >= out[1] and h % out[0] == 0 \
                and w % out[1] == 0:
            kh, kw = h // out[0], w // out[1]
            v = v.reshape(n, c, out[0], kh, out[1], kw)
            return v.mean(axis=(3, 5))
        # non-divisible / upscale fallback: interpolate (benchmark-size
        # flexibility; torch uses overlapping windows here)
        return jax.image.resize(v, (n, c, out[0], out[1]), method="linear")

    return _apply_op("adaptive_avg_pool2d", _pool, _coerce(x),
                     static=(out,))


# ----------------------------------------------------------------------
# dropout
# ----------------------------------------------------------------------

_dropout_seed = np.random.default_rng(1234)


def dropout(x: Tensor, p: float = 0.5, training: bool = True,
            rng: Optional[jax.Array] = None) -> Tensor:
    if not training or p == 0.0:
        return _coerce(x)
    x = _coerce(x)
    if rng is None:
        if x._pending is None and _is_tracer(x._d):
            raise RuntimeError(
                "dropout under jit requires an explicit `rng` key "
                "(pass rng=jax.random.key(...)); eager mode draws from the "
                "global generator.")
        mask = jnp.asarray(
            _dropout_seed.random(x.shape) >= p, dtype=x.dtype)
    else:
        mask = jax.random.bernoulli(rng, 1.0 - p, x.shape).astype(x.dtype)
    scale = 1.0 / (1.0 - p)
    return _apply_op("dropout", lambda v, m: v * m * scale, x, Tensor(mask),
                     static=(p,))


# ----------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------

def cross_entropy(logits: Tensor, target: Tensor,
                  ignore_index: int = -100,
                  label_smoothing: float = 0.0,
                  reduction: str = "mean") -> Tensor:
    """Softmax cross-entropy with integer targets (torch semantics)."""

    def _ce(lg, tgt):
        lg32 = lg.astype(jnp.float32)
        logp = jax.nn.log_softmax(lg32, axis=-1)
        n_cls = lg.shape[-1]
        flat_logp = logp.reshape(-1, n_cls)
        flat_tgt = tgt.reshape(-1)
        valid = flat_tgt != ignore_index
        safe_tgt = jnp.where(valid, flat_tgt, 0)
        picked = jnp.take_along_axis(
            flat_logp, safe_tgt[:, None], axis=-1)[:, 0]
        if label_smoothing > 0.0:
            smooth = jnp.mean(flat_logp, axis=-1)
            picked = (1 - label_smoothing) * picked + label_smoothing * smooth
        loss = -jnp.where(valid, picked, 0.0)
        if reduction == "mean":
            return loss.sum() / jnp.maximum(valid.sum(), 1)
        if reduction == "sum":
            return loss.sum()
        return loss.reshape(tgt.shape)

    return _apply_op("cross_entropy", _ce, _coerce(logits), _coerce(target),
                     static=(ignore_index, label_smoothing, reduction))


def nll_loss(log_probs: Tensor, target: Tensor,
             reduction: str = "mean") -> Tensor:
    def _nll(lp, tgt):
        picked = jnp.take_along_axis(
            lp.reshape(-1, lp.shape[-1]),
            tgt.reshape(-1)[:, None], axis=-1)[:, 0]
        loss = -picked
        if reduction == "mean":
            return loss.mean()
        if reduction == "sum":
            return loss.sum()
        return loss.reshape(tgt.shape)

    return _apply_op("nll_loss", _nll, _coerce(log_probs), _coerce(target),
                     static=(reduction,))


def mse_loss(input: Tensor, target: Tensor, reduction: str = "mean") -> Tensor:
    def _mse(a, b):
        d = jnp.square(a - b)
        if reduction == "mean":
            return d.mean()
        if reduction == "sum":
            return d.sum()
        return d

    return _apply_op("mse_loss", _mse, _coerce(input), _coerce(target),
                     static=(reduction,))


def binary_cross_entropy_with_logits(input: Tensor, target: Tensor,
                                     reduction: str = "mean") -> Tensor:
    def _bce(lg, t):
        loss = jnp.maximum(lg, 0) - lg * t + jnp.log1p(jnp.exp(-jnp.abs(lg)))
        if reduction == "mean":
            return loss.mean()
        if reduction == "sum":
            return loss.sum()
        return loss

    return _apply_op("bce_logits", _bce, _coerce(input), _coerce(target),
                     static=(reduction,))


# ----------------------------------------------------------------------
# attention (reference path; the Pallas flash kernel plugs in via
# repro.kernels and is selected by backend="pallas")
# ----------------------------------------------------------------------

def scaled_dot_product_attention(q: Tensor, k: Tensor, v: Tensor,
                                 attn_mask: Optional[Tensor] = None,
                                 is_causal: bool = False,
                                 scale: Optional[float] = None,
                                 window: Optional[int] = None,
                                 backend: str = "auto") -> Tensor:
    """(B, H, S, D) attention with GQA broadcast, causal & sliding-window
    masking.  ``backend='pallas'`` routes to the flash kernel."""
    from ..models import attention as _attn

    static = (is_causal, scale, window, backend)
    if attn_mask is None:
        fn = lambda qd, kd, vd: _attn.sdpa(  # noqa: E731
            qd, kd, vd, is_causal=is_causal, scale=scale, window=window,
            mask=None, backend=backend)
        return _apply_op("sdpa", fn, _coerce(q), _coerce(k), _coerce(v),
                         static=static)
    # the mask is an operand, not a closure capture: attention masks
    # change per batch while shapes stay fixed
    fn = lambda qd, kd, vd, md: _attn.sdpa(  # noqa: E731
        qd, kd, vd, is_causal=is_causal, scale=scale, window=window,
        mask=md, backend=backend)
    return _apply_op("sdpa", fn, _coerce(q), _coerce(k), _coerce(v),
                     _coerce(attn_mask), static=static)


# handy aliases matching torch.nn.functional
def pad(x: Tensor, padding: Sequence[int], value: float = 0.0) -> Tensor:
    """torch-style pad: last-dim-first pairs."""
    x = _coerce(x)
    pads = [(0, 0)] * x.ndim
    for i in range(len(padding) // 2):
        dim = x.ndim - 1 - i
        pads[dim] = (padding[2 * i], padding[2 * i + 1])
    pads = tuple(pads)
    return _apply_op("pad",
                     lambda v: jnp.pad(v, pads, constant_values=value), x,
                     static=(pads, value))


def one_hot(x: Tensor, num_classes: int) -> Tensor:
    return Tensor(jax.nn.one_hot(_raw(x), num_classes))


def normalize(x: Tensor, p: float = 2.0, dim: int = -1,
              eps: float = 1e-12) -> Tensor:
    def _norm(v):
        n = jnp.linalg.norm(v, ord=p, axis=dim, keepdims=True)
        return v / jnp.maximum(n, eps)

    return _apply_op("normalize", _norm, _coerce(x), static=(p, dim, eps))
