"""Recurrent layers (LSTM/GRU) — needed for the paper's GNMTv2 benchmark.

The recurrence runs as a single ``jax.lax.scan`` inside one tape node, so
eager dispatch cost is O(1) per layer per step-batch rather than O(seq).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import tensor_mod as T
from ..core.tensor import Tensor, _apply_op, _coerce
from .module import Module, Parameter


def _lstm_cell(x_t, h, c, w_ih, w_hh, b):
    gates = x_t @ w_ih.T + h @ w_hh.T + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, c


class LSTM(Module):
    """Multi-layer LSTM over (B, S, D) batches (batch_first semantics)."""

    def __init__(self, input_size: int, hidden_size: int,
                 num_layers: int = 1, bias: bool = True,
                 bidirectional: bool = False, dtype=jnp.float32):
        super().__init__()
        self.input_size = input_size
        self.hidden_size = hidden_size
        self.num_layers = num_layers
        self.bidirectional = bidirectional
        dirs = 2 if bidirectional else 1
        k = 1.0 / math.sqrt(hidden_size)
        for layer in range(num_layers):
            for d in range(dirs):
                in_sz = input_size if layer == 0 else hidden_size * dirs
                sfx = f"_l{layer}" + ("_reverse" if d else "")
                setattr(self, f"weight_ih{sfx}", Parameter(
                    T.uniform(-k, k, (4 * hidden_size, in_sz), dtype=dtype)))
                setattr(self, f"weight_hh{sfx}", Parameter(
                    T.uniform(-k, k, (4 * hidden_size, hidden_size),
                              dtype=dtype)))
                setattr(self, f"bias{sfx}", Parameter(
                    T.uniform(-k, k, (4 * hidden_size,), dtype=dtype)))

    def _run_direction(self, x: Tensor, w_ih: Tensor, w_hh: Tensor,
                       b: Tensor, reverse: bool,
                       h0c0=None) -> Tuple[Tensor, Tensor, Tensor]:
        hidden = self.hidden_size

        def _scan(xd, wi, wh, bb, *hc):
            bsz = xd.shape[0]
            if hc:
                h0, c0 = hc
            else:
                h0 = jnp.zeros((bsz, hidden), xd.dtype)
                c0 = jnp.zeros((bsz, hidden), xd.dtype)
            seq = jnp.swapaxes(xd, 0, 1)  # (S, B, D)
            if reverse:
                seq = seq[::-1]

            def step(carry, x_t):
                h, c = carry
                h, c = _lstm_cell(x_t, h, c, wi, wh, bb)
                return (h, c), h

            (h_n, c_n), outs = jax.lax.scan(step, (h0, c0), seq)
            if reverse:
                outs = outs[::-1]
            return jnp.swapaxes(outs, 0, 1), h_n, c_n

        args = [x, w_ih, w_hh, b]
        if h0c0 is not None:
            args += [h0c0[0], h0c0[1]]
        # closure captures: hidden size + direction (presence of an
        # initial state changes the operand count, so the signature
        # already distinguishes it)
        return _apply_op("lstm", _scan, *[_coerce(a) for a in args],
                         num_outputs=3, static=(hidden, reverse))

    def forward(self, x: Tensor, state=None):
        h_states, c_states = [], []
        out = x
        for layer in range(self.num_layers):
            sfx = f"_l{layer}"
            h0c0 = None
            if state is not None:
                h0c0 = (state[0][layer], state[1][layer])
            fwd, h_n, c_n = self._run_direction(
                out, getattr(self, f"weight_ih{sfx}"),
                getattr(self, f"weight_hh{sfx}"),
                getattr(self, f"bias{sfx}"), reverse=False, h0c0=h0c0)
            if self.bidirectional:
                bwd, hb, cb = self._run_direction(
                    out, getattr(self, f"weight_ih{sfx}_reverse"),
                    getattr(self, f"weight_hh{sfx}_reverse"),
                    getattr(self, f"bias{sfx}_reverse"), reverse=True)
                out = T.cat([fwd, bwd], dim=-1)
                h_states += [h_n, hb]
                c_states += [c_n, cb]
            else:
                out = fwd
                h_states.append(h_n)
                c_states.append(c_n)
        h = T.stack(h_states, dim=0)
        c = T.stack(c_states, dim=0)
        return out, (h, c)


class LSTMCell(Module):
    def __init__(self, input_size: int, hidden_size: int, dtype=jnp.float32):
        super().__init__()
        self.hidden_size = hidden_size
        k = 1.0 / math.sqrt(hidden_size)
        self.weight_ih = Parameter(
            T.uniform(-k, k, (4 * hidden_size, input_size), dtype=dtype))
        self.weight_hh = Parameter(
            T.uniform(-k, k, (4 * hidden_size, hidden_size), dtype=dtype))
        self.bias = Parameter(T.uniform(-k, k, (4 * hidden_size,),
                                        dtype=dtype))

    def forward(self, x: Tensor, state=None):
        if state is None:
            z = T.zeros(x.shape[0], self.hidden_size, dtype=x.dtype)
            state = (z, z)
        h, c = state
        out = _apply_op(
            "lstm_cell",
            lambda xd, hd, cd, wi, wh, b: _lstm_cell(xd, hd, cd, wi, wh, b),
            _coerce(x), _coerce(h), _coerce(c),
            self.weight_ih, self.weight_hh, self.bias, num_outputs=2,
            static=())
        return out
