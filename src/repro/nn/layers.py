"""Built-in layers (paper Listing 1: constructors create parameters,
``forward`` processes activations)."""

from __future__ import annotations

import math
from typing import Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core import tensor_mod as T
from ..core.tensor import Tensor
from . import functional as F
from .module import Module, Parameter


def _kaiming_uniform(shape, fan_in, dtype=jnp.float32) -> Tensor:
    bound = math.sqrt(1.0 / fan_in) if fan_in > 0 else 0.0
    return T.uniform(-bound, bound, shape, dtype=dtype)


class Linear(Module):
    def __init__(self, in_features: int, out_features: int,
                 bias: bool = True, dtype=jnp.float32):
        super().__init__()
        self.in_features = in_features
        self.out_features = out_features
        self.weight = Parameter(
            _kaiming_uniform((out_features, in_features), in_features, dtype))
        if bias:
            self.bias = Parameter(
                _kaiming_uniform((out_features,), in_features, dtype))
        else:
            self.register_parameter("bias", None)

    def forward(self, x: Tensor) -> Tensor:
        return F.linear(x, self.weight, self._parameters.get("bias"))

    def __repr__(self):
        return (f"Linear(in={self.in_features}, out={self.out_features}, "
                f"bias={self._parameters.get('bias') is not None})")


class Embedding(Module):
    def __init__(self, num_embeddings: int, embedding_dim: int,
                 dtype=jnp.float32):
        super().__init__()
        self.num_embeddings = num_embeddings
        self.embedding_dim = embedding_dim
        self.weight = Parameter(
            T.normal(0.0, 1.0, (num_embeddings, embedding_dim), dtype=dtype))

    def forward(self, idx: Tensor) -> Tensor:
        return F.embedding(idx, self.weight)

    def __repr__(self):
        return f"Embedding({self.num_embeddings}, {self.embedding_dim})"


class LayerNorm(Module):
    def __init__(self, normalized_shape: Union[int, Tuple[int, ...]],
                 eps: float = 1e-5, elementwise_affine: bool = True,
                 bias: bool = True, dtype=jnp.float32):
        super().__init__()
        if isinstance(normalized_shape, int):
            normalized_shape = (normalized_shape,)
        self.normalized_shape = tuple(normalized_shape)
        self.eps = eps
        if elementwise_affine:
            self.weight = Parameter(T.ones(*self.normalized_shape,
                                           dtype=dtype))
            if bias:
                self.bias = Parameter(T.zeros(*self.normalized_shape,
                                              dtype=dtype))
            else:
                self.register_parameter("bias", None)
        else:
            self.register_parameter("weight", None)
            self.register_parameter("bias", None)

    def forward(self, x: Tensor) -> Tensor:
        return F.layer_norm(x, self.normalized_shape,
                            self._parameters.get("weight"),
                            self._parameters.get("bias"), self.eps)


class RMSNorm(Module):
    """offset=1.0 gives the Gemma (1+w) convention."""

    def __init__(self, dim: int, eps: float = 1e-6, offset: float = 0.0,
                 dtype=jnp.float32):
        super().__init__()
        self.eps = eps
        self.offset = offset
        init = T.zeros(dim, dtype=dtype) if offset else T.ones(dim,
                                                               dtype=dtype)
        self.weight = Parameter(init)

    def forward(self, x: Tensor) -> Tensor:
        return F.rms_norm(x, self.weight, self.eps, self.offset)


class BatchNorm2d(Module):
    def __init__(self, num_features: int, eps: float = 1e-5,
                 momentum: float = 0.1, affine: bool = True):
        super().__init__()
        self.eps = eps
        self.momentum = momentum
        if affine:
            self.weight = Parameter(T.ones(num_features))
            self.bias = Parameter(T.zeros(num_features))
        else:
            self.register_parameter("weight", None)
            self.register_parameter("bias", None)
        self.register_buffer("running_mean", T.zeros(num_features))
        self.register_buffer("running_var", T.ones(num_features))

    def forward(self, x: Tensor) -> Tensor:
        return F.batch_norm(
            x, self._buffers["running_mean"], self._buffers["running_var"],
            self._parameters.get("weight"), self._parameters.get("bias"),
            training=self.training, momentum=self.momentum, eps=self.eps)


class Conv2d(Module):
    def __init__(self, in_channels: int, out_channels: int,
                 kernel_size: Union[int, Tuple[int, int]],
                 stride: Union[int, Tuple[int, int]] = 1,
                 padding: Union[int, Tuple[int, int], str] = 0,
                 dilation: int = 1, groups: int = 1, bias: bool = True,
                 dtype=jnp.float32):
        super().__init__()
        k = (kernel_size, kernel_size) if isinstance(kernel_size, int) \
            else tuple(kernel_size)
        # canonicalize at construction: every forward then passes
        # identical static descriptors (one dispatch-cache key per layer
        # config, whether the user wrote `stride=1` or `stride=(1, 1)`)
        self.stride = F._pair(stride)
        self.padding = padding if isinstance(padding, str) \
            else F._pair(padding)
        self.dilation, self.groups = F._pair(dilation), groups
        fan_in = in_channels // groups * k[0] * k[1]
        self.weight = Parameter(_kaiming_uniform(
            (out_channels, in_channels // groups, k[0], k[1]), fan_in, dtype))
        if bias:
            self.bias = Parameter(_kaiming_uniform(
                (out_channels,), fan_in, dtype))
        else:
            self.register_parameter("bias", None)

    def forward(self, x: Tensor) -> Tensor:
        return F.conv2d(x, self.weight, self._parameters.get("bias"),
                        self.stride, self.padding, self.dilation, self.groups)


class Conv1d(Module):
    def __init__(self, in_channels: int, out_channels: int, kernel_size: int,
                 stride: int = 1, padding: int = 0, dilation: int = 1,
                 groups: int = 1, bias: bool = True, dtype=jnp.float32):
        super().__init__()
        self.stride, self.padding = stride, padding
        self.dilation, self.groups = dilation, groups
        fan_in = in_channels // groups * kernel_size
        self.weight = Parameter(_kaiming_uniform(
            (out_channels, in_channels // groups, kernel_size), fan_in,
            dtype))
        if bias:
            self.bias = Parameter(_kaiming_uniform((out_channels,), fan_in,
                                                   dtype))
        else:
            self.register_parameter("bias", None)

    def forward(self, x: Tensor) -> Tensor:
        return F.conv1d(x, self.weight, self._parameters.get("bias"),
                        self.stride, self.padding, self.dilation, self.groups)


class MaxPool2d(Module):
    def __init__(self, kernel_size, stride=None, padding=0):
        super().__init__()
        self.kernel_size, self.stride, self.padding = \
            kernel_size, stride, padding

    def forward(self, x: Tensor) -> Tensor:
        return F.max_pool2d(x, self.kernel_size, self.stride, self.padding)


class AvgPool2d(Module):
    def __init__(self, kernel_size, stride=None, padding=0):
        super().__init__()
        self.kernel_size, self.stride, self.padding = \
            kernel_size, stride, padding

    def forward(self, x: Tensor) -> Tensor:
        return F.avg_pool2d(x, self.kernel_size, self.stride, self.padding)


class AdaptiveAvgPool2d(Module):
    def __init__(self, output_size):
        super().__init__()
        self.output_size = output_size

    def forward(self, x: Tensor) -> Tensor:
        return F.adaptive_avg_pool2d(x, self.output_size)


class Dropout(Module):
    def __init__(self, p: float = 0.5):
        super().__init__()
        self.p = p

    def forward(self, x: Tensor, rng=None) -> Tensor:
        return F.dropout(x, self.p, training=self.training, rng=rng)


class Flatten(Module):
    def __init__(self, start_dim: int = 1, end_dim: int = -1):
        super().__init__()
        self.start_dim, self.end_dim = start_dim, end_dim

    def forward(self, x: Tensor) -> Tensor:
        return x.flatten(self.start_dim, self.end_dim)


class Identity(Module):
    def forward(self, x: Tensor) -> Tensor:
        return x


class ReLU(Module):
    def forward(self, x):
        return F.relu(x)


class ReLU6(Module):
    def forward(self, x):
        return F.relu6(x)


class GELU(Module):
    def __init__(self, approximate: str = "tanh"):
        super().__init__()
        self.approximate = approximate

    def forward(self, x):
        return F.gelu(x, self.approximate)


class SiLU(Module):
    def forward(self, x):
        return F.silu(x)


class Sigmoid(Module):
    def forward(self, x):
        return F.sigmoid(x)


class Tanh(Module):
    def forward(self, x):
        return F.tanh(x)


class Softmax(Module):
    def __init__(self, dim: int = -1):
        super().__init__()
        self.dim = dim

    def forward(self, x):
        return F.softmax(x, self.dim)


class Hardswish(Module):
    def forward(self, x):
        return F.hardswish(x)
