"""repro.nn — torch.nn-shaped neural network API."""

from . import functional
from .layers import (
    GELU,
    SiLU,
    AdaptiveAvgPool2d,
    AvgPool2d,
    BatchNorm2d,
    Conv1d,
    Conv2d,
    Dropout,
    Embedding,
    Flatten,
    Hardswish,
    Identity,
    LayerNorm,
    Linear,
    MaxPool2d,
    ReLU,
    ReLU6,
    RMSNorm,
    Sigmoid,
    Softmax,
    Tanh,
)
from .module import (
    Module,
    ModuleDict,
    ModuleList,
    Parameter,
    Sequential,
    functional_call,
    param_dict,
)
from .rnn import LSTM, LSTMCell
