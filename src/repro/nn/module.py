"""torch.nn.Module analogue (paper §4.1: models are just Python programs).

Layers are Python classes whose constructors create parameters and whose
``forward`` methods process activations.  Nothing forces users into this
structure — any callable over Tensors works — but Module provides the
bookkeeping: named parameters/buffers, train/eval mode, state_dict.

The crucial addition for the TPU path is :func:`functional_call`: it runs a
module's ``forward`` with an explicit parameter dict swapped in, turning the
imperative module into a *pure function* ``f(params, inputs)`` that can be
``jax.jit``-ed, ``pjit``-ed across a pod mesh, or differentiated by JAX AD.
One model definition serves both the eager tape and the compiled/
distributed world.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple, Union

import jax.numpy as jnp

from ..core.tensor import Tensor
from ..core.autograd import no_grad


class Parameter(Tensor):
    """A Tensor that is a module parameter (requires grad by default)."""

    def __init__(self, data: Any, requires_grad: bool = True):
        if isinstance(data, Tensor):
            super().__init__(data.data, requires_grad=requires_grad)
        else:
            super().__init__(data, requires_grad=requires_grad)

    def __repr__(self):
        return "Parameter containing:\n" + super().__repr__()


class Module:
    def __init__(self):
        object.__setattr__(self, "_parameters", OrderedDict())
        object.__setattr__(self, "_buffers", OrderedDict())
        object.__setattr__(self, "_modules", OrderedDict())
        object.__setattr__(self, "training", True)

    # -- attribute interception -----------------------------------------
    def __setattr__(self, name: str, value: Any) -> None:
        params = self.__dict__.get("_parameters")
        if params is None:
            raise RuntimeError(
                "cannot assign attributes before Module.__init__() call"
            )
        for d in (self._parameters, self._buffers, self._modules):
            d.pop(name, None)
        if isinstance(value, Parameter):
            self._parameters[name] = value
        elif isinstance(value, Module):
            self._modules[name] = value
        else:
            object.__setattr__(self, name, value)

    def __getattr__(self, name: str):
        for d in ("_parameters", "_buffers", "_modules"):
            sub = self.__dict__.get(d)
            if sub is not None and name in sub:
                return sub[name]
        raise AttributeError(
            f"'{type(self).__name__}' object has no attribute '{name}'"
        )

    def register_buffer(self, name: str, tensor: Optional[Tensor]) -> None:
        self._buffers[name] = tensor

    def register_parameter(self, name: str, param: Optional[Parameter]) -> None:
        self._parameters[name] = param

    def add_module(self, name: str, module: "Module") -> None:
        self._modules[name] = module

    # -- iteration --------------------------------------------------------
    def named_modules(self, prefix: str = "") -> Iterator[Tuple[str, "Module"]]:
        yield prefix, self
        for name, mod in self._modules.items():
            if mod is None:
                continue
            sub = f"{prefix}.{name}" if prefix else name
            yield from mod.named_modules(sub)

    def modules(self) -> Iterator["Module"]:
        for _, m in self.named_modules():
            yield m

    def children(self) -> Iterator["Module"]:
        yield from self._modules.values()

    def named_parameters(self, prefix: str = "") -> Iterator[Tuple[str, Parameter]]:
        for mod_name, mod in self.named_modules(prefix):
            for p_name, p in mod._parameters.items():
                if p is not None:
                    full = f"{mod_name}.{p_name}" if mod_name else p_name
                    yield full, p

    def parameters(self) -> Iterator[Parameter]:
        for _, p in self.named_parameters():
            yield p

    def named_buffers(self, prefix: str = "") -> Iterator[Tuple[str, Tensor]]:
        for mod_name, mod in self.named_modules(prefix):
            for b_name, b in mod._buffers.items():
                if b is not None:
                    full = f"{mod_name}.{b_name}" if mod_name else b_name
                    yield full, b

    def buffers(self) -> Iterator[Tensor]:
        for _, b in self.named_buffers():
            yield b

    # -- state dict ---------------------------------------------------------
    def state_dict(self) -> "OrderedDict[str, Tensor]":
        out: "OrderedDict[str, Tensor]" = OrderedDict()
        for name, p in self.named_parameters():
            out[name] = p
        for name, b in self.named_buffers():
            out[name] = b
        return out

    def load_state_dict(self, state: Dict[str, Any], strict: bool = True) -> None:
        own = self.state_dict()
        missing = [k for k in own if k not in state]
        unexpected = [k for k in state if k not in own]
        if strict and (missing or unexpected):
            raise KeyError(
                f"load_state_dict mismatch: missing={missing[:5]}, "
                f"unexpected={unexpected[:5]}"
            )
        with no_grad():
            for k, v in state.items():
                if k in own:
                    data = v.data if isinstance(v, Tensor) else jnp.asarray(v)
                    own[k]._data = data.astype(own[k].dtype)
                    own[k]._version.bump()

    # -- modes ---------------------------------------------------------------
    def train(self, mode: bool = True) -> "Module":
        for m in self.modules():
            object.__setattr__(m, "training", mode)
        return self

    def eval(self) -> "Module":
        return self.train(False)

    def zero_grad(self, set_to_none: bool = True) -> None:
        for p in self.parameters():
            p.grad = None if set_to_none else (
                None if p.grad is None else p.grad.zero_())

    def apply(self, fn: Callable[["Module"], None]) -> "Module":
        for m in self.modules():
            fn(m)
        return self

    def requires_grad_(self, flag: bool = True) -> "Module":
        for p in self.parameters():
            p.requires_grad = flag
        return self

    # -- call ------------------------------------------------------------------
    def forward(self, *args, **kwargs):
        raise NotImplementedError

    def __call__(self, *args, **kwargs):
        return self.forward(*args, **kwargs)

    def __repr__(self):
        lines = [type(self).__name__ + "("]
        for name, mod in self._modules.items():
            mod_repr = repr(mod).replace("\n", "\n  ")
            lines.append(f"  ({name}): {mod_repr}")
        lines.append(")")
        return "\n".join(lines)

    def num_parameters(self) -> int:
        return sum(p.numel() for p in self.parameters())


# ----------------------------------------------------------------------
# functional bridge (module → pure function for jit/pjit/JAX-AD)
# ----------------------------------------------------------------------

def functional_call(module: Module,
                    params_and_buffers: Dict[str, Any],
                    *args, **kwargs):
    """Run ``module.forward`` with parameters/buffers replaced by
    ``params_and_buffers`` (name → Tensor or raw array), restoring the
    originals afterwards.  Inside a jit trace the swapped values are
    tracers, so the whole forward lowers to one XLA computation.
    """
    entries: List[Tuple[Dict[str, Any], str, Any, Any]] = []
    for mod_name, mod in module.named_modules():
        for store in (mod._parameters, mod._buffers):
            for local, current in store.items():
                full = f"{mod_name}.{local}" if mod_name else local
                if full in params_and_buffers:
                    new = params_and_buffers[full]
                    if not isinstance(new, Tensor):
                        new = Tensor(new)
                    entries.append((store, local, current, new))
    try:
        for store, local, _current, new in entries:
            store[local] = new
        return module.forward(*args, **kwargs)
    finally:
        for store, local, current, _new in entries:
            store[local] = current


def param_dict(module: Module, dtype=None) -> Dict[str, Tensor]:
    """Extract {name: Tensor} for all params+buffers (the pytree that the
    compiled/distributed path threads through pjit)."""
    out = {}
    for name, p in module.named_parameters():
        out[name] = p.astype(dtype) if dtype is not None else p
    for name, b in module.named_buffers():
        out[name] = b
    return out


# ----------------------------------------------------------------------
# containers
# ----------------------------------------------------------------------

class Sequential(Module):
    def __init__(self, *mods: Module):
        super().__init__()
        for i, m in enumerate(mods):
            self.add_module(str(i), m)

    def __iter__(self):
        return iter(self._modules.values())

    def __getitem__(self, idx: int) -> Module:
        return list(self._modules.values())[idx]

    def __len__(self):
        return len(self._modules)

    def append(self, mod: Module) -> "Sequential":
        self.add_module(str(len(self._modules)), mod)
        return self

    def forward(self, x):
        for m in self._modules.values():
            x = m(x)
        return x


class ModuleList(Module):
    def __init__(self, mods: Optional[List[Module]] = None):
        super().__init__()
        for i, m in enumerate(mods or []):
            self.add_module(str(i), m)

    def append(self, mod: Module) -> "ModuleList":
        self.add_module(str(len(self._modules)), mod)
        return self

    def __iter__(self):
        return iter(self._modules.values())

    def __getitem__(self, idx: Union[int, slice]):
        mods = list(self._modules.values())
        return mods[idx]

    def __len__(self):
        return len(self._modules)


class ModuleDict(Module):
    def __init__(self, mods: Optional[Dict[str, Module]] = None):
        super().__init__()
        for k, m in (mods or {}).items():
            self.add_module(k, m)

    def __getitem__(self, key: str) -> Module:
        return self._modules[key]

    def __setitem__(self, key: str, mod: Module) -> None:
        self.add_module(key, mod)

    def keys(self):
        return self._modules.keys()

    def items(self):
        return self._modules.items()

    def values(self):
        return self._modules.values()
