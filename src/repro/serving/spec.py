"""Speculative-decoding proposers for the serving scheduler.

Decode throughput is bounded by one ``unified_step`` per token per
sequence.  A proposer breaks that bound: it guesses ``k`` draft tokens
for a decoding sequence from host-side evidence, the scheduler feeds
``pending + drafts`` as ONE multi-token span (the flat token batch
already mixes multi-token and single-token segments — chunked prefill
proved the shape), the executor samples a target token at every draft
position in the same jitted call, and the scheduler commits the longest
prefix where target == draft plus the first correction token.

Exactness is the correctness anchor, not a best-effort approximation:
because the sampler's PRNG key depends only on ``(seed, position)``
(see ``sampling.py``), the token sampled at a position inside a
speculative batch is IDENTICAL to the token a non-speculative step
would sample there — for greedy and for temperature/top-k/top-p alike.
A wrong draft costs wasted compute, never a changed output;
``metrics["accepted_tokens"] / metrics["proposed_tokens"]`` is the
first-class observability signal for how much of the speculative work
paid off.

Proposers are host Python (control plane) behind one interface:

  * :class:`NgramProposer` — prompt-lookup decoding: match the
    sequence's own trailing n-gram against its earlier history and
    propose the continuation.  Free (no model), and strong on
    repeat-heavy text (code, retrieval-augmented prompts, the argmax
    cycles small models fall into);
  * :class:`DraftModelProposer` — a smaller LM proposes greedily
    through the same interface (the classic two-model scheme);
  * :class:`FixedProposer` — deterministic drafts for tests (force
    all-reject / all-accept interleavings).
"""

from __future__ import annotations

from typing import List, Protocol, Sequence, runtime_checkable

__all__ = ["Proposer", "NgramProposer", "DraftModelProposer",
           "FixedProposer"]


@runtime_checkable
class Proposer(Protocol):
    """Anything with ``propose(history, k) -> up to k draft tokens``.

    ``history`` is the request's full token history
    (``prompt + out_tokens``); the return value may be shorter than
    ``k`` (including empty — "no guess", which costs nothing)."""

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Return up to ``k`` draft tokens continuing ``history``."""
        ...


class NgramProposer:
    """Prompt-lookup proposer: find the most recent earlier occurrence
    of the trailing ``n``-gram (longest match first, down to
    ``min_n``) and propose the tokens that followed it."""

    def __init__(self, n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= n:
            raise ValueError(f"need 1 <= min_n <= n, got {min_n}, {n}")
        self.n = n
        self.min_n = min_n

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Scan ``history`` for its own trailing n-gram; on a match at
        ``i`` propose the continuation ``history[i+n:]``, extended
        cyclically to ``k`` tokens.  A match ``q = |h| - n - i`` tokens
        back implies period ``q``, so the predicted token at future
        offset ``m`` is ``h[|h| + m - q]`` — which IS the cyclic
        extension of the matched continuation (without it, a period-1
        loop would yield a single draft per step no matter how large
        ``k`` is).  Deterministic, O(n·|h|) per call, empty when
        nothing matches."""
        h = list(history)
        if k <= 0 or len(h) < self.min_n + 1:
            return []
        for n in range(min(self.n, len(h) - 1), self.min_n - 1, -1):
            tail = h[-n:]
            # most recent earlier occurrence wins (locality: decode
            # loops repeat their own recent past)
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == tail:
                    span = h[i + n:]
                    if span:
                        return [span[m % len(span)] for m in range(k)]
        return []


class DraftModelProposer:
    """Greedy drafts from a (smaller) LM over the history tail.

    Reference implementation of the two-model scheme behind the same
    ``Proposer`` interface: runs ``forward`` over the last ``window``
    tokens and extends greedily ``k`` times.  Host-blocking — meant for
    small draft configs (the acceptance logic upstream is identical for
    any proposer, which is the point of the interface)."""

    def __init__(self, cfg, params, window: int = 64):
        self.cfg = cfg
        self.params = params
        self.window = window

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Autoregressive greedy continuation of ``history`` under the
        draft model; returns ``k`` tokens (or [] for empty history)."""
        import jax.numpy as jnp
        from ..models.lm import forward
        if k <= 0 or not history:
            return []
        toks = list(history)
        out: List[int] = []
        for _ in range(k):
            ctx = toks[-self.window:]
            logits = forward(self.cfg, self.params,
                             jnp.asarray([ctx], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            toks.append(nxt)
        return out


class FixedProposer:
    """Always proposes a fixed draft list (truncated to ``k``) — the
    test hook for forcing accept/reject interleavings."""

    def __init__(self, drafts: Sequence[int]):
        self.drafts = list(drafts)

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Return the configured drafts, clipped to ``k``."""
        return self.drafts[:k]
