"""Pre-refactor monolithic serving engine — kept as the measured
baseline for ``benchmarks/bench_serving.py`` (the scheduler/executor
split must beat this by ≥ 1.5× decode tokens/s).

Characteristic costs the refactor removes (do NOT "fix" these here —
they ARE the baseline): un-jitted per-prompt prefill (eager op-by-op
forward per admission), a decode jit keyed on live batch size (one
recompile per distinct batch size), and per-sequence host-side KV
appends after every step.  The prefill page writes go through the
batched ``write_prompt`` (one scatter per layer) since the old
per-token loop lived at the kv_cache API level, and the
preemption-resume path carries ``out_tokens`` through re-prefill — both
semantic fixes, not data-plane restructuring.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm as LM
from ..models import layers as L
from ..models.attention import decode_attention
from .executor import split_layer_params
from .kv_cache import PagedKVCache
from .scheduler import Request


class LegacyServingEngine:
    """Batched serving with host-interleaved control and compute (the
    pre-scheduler/executor design)."""

    def __init__(self, cfg: LM.LMConfig, params, *, page_size: int = 16,
                 num_pages: int = 512, max_batch: int = 8,
                 greedy: bool = True):
        for spec in cfg.pattern:
            if spec.mixer not in ("attn",):
                raise ValueError(
                    "paged engine serves full-attention models; use the "
                    "dense-cache pjit path for hybrid/ssm archs")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.greedy = greedy
        self.kv = PagedKVCache(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, page_size=page_size, num_pages=num_pages,
            dtype=jnp.float32 if cfg.param_dtype == jnp.float32
            else jnp.bfloat16)
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self._next_id = 0
        self.metrics = {"steps": 0, "prefills": 0, "decoded_tokens": 0,
                        "rejected_admissions": 0}

        self._layer_params = self._split_layer_params()
        self._token_fn = jax.jit(self._token_compute)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: Sequence[int],
               max_new_tokens: int = 16) -> int:
        req = Request(self._next_id, list(prompt), max_new_tokens,
                      submitted_at=time.perf_counter())
        self._next_id += 1
        self.waiting.append(req)
        return req.req_id

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                break
            self._admit()
            finished.extend(self.step())
            self.metrics["steps"] += 1
        return finished

    # -- scheduling -----------------------------------------------------------
    def _admit(self) -> None:
        while (self.waiting and len(self.running) < self.max_batch):
            req = self.waiting[0]
            hist = req.history      # prompt + any pre-preemption tokens
            if not self.kv.can_admit(len(hist) + 1):
                self.metrics["rejected_admissions"] += 1
                break
            self.waiting.pop(0)
            if not self.kv.create(req.req_id, hist):
                self.waiting.insert(0, req)
                break
            self._prefill(req)
            self.running[req.req_id] = req

    def step(self) -> List[Request]:
        """One continuous-batching decode step for all running seqs."""
        if not self.running:
            return []
        seq_ids = sorted(self.running)
        last_tokens = []
        for s in seq_ids:
            r = self.running[s]
            last_tokens.append(r.out_tokens[-1] if r.out_tokens
                               else r.prompt[-1])
        next_tokens, layer_kv = self._decode_batch(seq_ids, last_tokens)

        finished = []
        for i, s in enumerate(seq_ids):
            r = self.running[s]
            ok = self.kv.append(s, [(k[i], v[i]) for k, v in layer_kv])
            if not ok:
                # out of pages mid-flight: preempt (requeue) this request
                self.kv.free_seq(s)
                del self.running[s]
                self.waiting.insert(0, r)
                continue
            tok = int(next_tokens[i])
            r.out_tokens.append(tok)
            if r.first_token_at is None:
                r.first_token_at = time.perf_counter()
            self.metrics["decoded_tokens"] += 1
            if r.done:
                r.finished_at = time.perf_counter()
                self.kv.free_seq(s)
                del self.running[s]
                finished.append(r)
        return finished

    # -- compute -------------------------------------------------------------
    def _split_layer_params(self):
        return split_layer_params(self.cfg, self.params)

    def _prefill(self, req: Request) -> None:
        """Run the whole history through the model eagerly (un-jitted —
        the baseline cost), write K/V past the reused prefix in one
        batched scatter per layer, and emit the first token only for a
        FRESH request (a resumed one already holds its tokens)."""
        hist = req.history
        tokens = jnp.asarray([hist], jnp.int32)
        kvs, logits = self._prefill_fn(tokens)
        # resumed requests keep their last generated token OUT of the
        # cache: the next decode step feeds it (writing it here too would
        # double-append its K/V and derail the continuation)
        n_write = len(hist) - (1 if req.out_tokens else 0)
        layer_kv = [(k[0].transpose(1, 0, 2)[:n_write],
                     v[0].transpose(1, 0, 2)[:n_write]) for k, v in kvs]
        self.kv.write_prompt(req.req_id, layer_kv, n_write)
        self.kv.lengths[req.req_id] = min(self.kv.lengths[req.req_id],
                                          n_write)
        self.metrics["prefills"] += 1
        if not req.out_tokens:
            req.out_tokens.append(int(jnp.argmax(logits[0, -1])))
            req.first_token_at = time.perf_counter()

    def _prefill_fn(self, tokens):
        cfg = self.cfg
        x = jnp.take(self.params["embed"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        kvs = []
        for lp in self._layer_params:
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps, cfg.norm_offset) \
                if cfg.norm == "rms" else L.layer_norm(
                    x, lp["norm1"], lp.get("norm1_b"), cfg.norm_eps)
            b, s, _ = h.shape
            q = (h @ lp["attn"]["wq"]).reshape(
                b, s, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
            k = (h @ lp["attn"]["wk"]).reshape(
                b, s, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            v = (h @ lp["attn"]["wv"]).reshape(
                b, s, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            if cfg.rope_theta is not None:
                pos = jnp.arange(s)
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
            kvs.append((k, v))
            from ..models.attention import sdpa_ref
            o = sdpa_ref(q, k, v, is_causal=cfg.causal,
                         scale=cfg.query_scale or cfg.hd ** -0.5)
            o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
            x = x + o @ lp["attn"]["wo"]
            if "mlp" in lp:
                h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps,
                                cfg.norm_offset) if cfg.norm == "rms" \
                    else L.layer_norm(x, lp["norm2"], lp.get("norm2_b"),
                                      cfg.norm_eps)
                x = x + L.mlp(lp["mlp"], h2, cfg.act)
        x = L.rms_norm(x, self.params["final_norm"], cfg.norm_eps,
                       cfg.norm_offset) if cfg.norm == "rms" else \
            L.layer_norm(x, self.params["final_norm"],
                         self.params.get("final_norm_b"), cfg.norm_eps)
        logits = x @ (self.params["embed"].T if cfg.tie_embeddings
                      else self.params["lm_head"])
        return kvs, logits

    def _token_compute(self, tokens, pos, gathered):
        """One decode step given pre-gathered per-layer K/V."""
        cfg = self.cfg
        x = jnp.take(self.params["embed"], tokens[:, None], axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        new_kv = []
        for li, lp in enumerate(self._layer_params):
            k_cache, v_cache, lens = gathered[li]
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps, cfg.norm_offset) \
                if cfg.norm == "rms" else L.layer_norm(
                    x, lp["norm1"], lp.get("norm1_b"), cfg.norm_eps)
            b = h.shape[0]
            q = (h @ lp["attn"]["wq"]).reshape(
                b, 1, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
            k = (h @ lp["attn"]["wk"]).reshape(
                b, 1, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            v = (h @ lp["attn"]["wv"]).reshape(
                b, 1, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            if cfg.rope_theta is not None:
                q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
                k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
            # attend over gathered cache + the fresh token
            k_full = jnp.concatenate(
                [k_cache, k.astype(k_cache.dtype)], axis=2)
            v_full = jnp.concatenate(
                [v_cache, v.astype(v_cache.dtype)], axis=2)
            o = decode_attention(q, k_full, v_full, cache_len=lens + 1,
                                 scale=cfg.query_scale or cfg.hd ** -0.5,
                                 backend="ref")
            o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
            x = x + o @ lp["attn"]["wo"]
            if "mlp" in lp:
                h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps,
                                cfg.norm_offset) if cfg.norm == "rms" \
                    else L.layer_norm(x, lp["norm2"], lp.get("norm2_b"),
                                      cfg.norm_eps)
                x = x + L.mlp(lp["mlp"], h2, cfg.act)
            new_kv.append((k[:, :, 0], v[:, :, 0]))
        x = L.rms_norm(x, self.params["final_norm"], cfg.norm_eps,
                       cfg.norm_offset) if cfg.norm == "rms" else \
            L.layer_norm(x, self.params["final_norm"],
                         self.params.get("final_norm_b"), cfg.norm_eps)
        logits = x @ (self.params["embed"].T if cfg.tie_embeddings
                      else self.params["lm_head"])
        return jnp.argmax(logits[:, -1], axis=-1), new_kv

    def _decode_batch(self, seq_ids, last_tokens):
        gathered = [self.kv.gather(seq_ids, li)
                    for li in range(self.cfg.n_layers)]
        pos = jnp.asarray([self.kv.lengths[s] for s in seq_ids], jnp.int32)
        tokens = jnp.asarray(last_tokens, jnp.int32)
        next_tokens, new_kv = self._token_fn(tokens, pos, gathered)
        return np.asarray(next_tokens), [
            (np.asarray(k), np.asarray(v)) for k, v in new_kv]

    def stats(self) -> Dict[str, Any]:
        return {**self.metrics, **self.kv.memory_stats()}
