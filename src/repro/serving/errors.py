"""Typed serving errors — the request-lifecycle failure vocabulary.

Every failure the serving stack can hand a caller is a subclass of
:class:`ServingError`, so front ends catch ONE type and report
per-request outcomes instead of dying on a bare ``ValueError``
(``launch/serve.py`` does exactly that).  The admission-shaped errors
also subclass ``ValueError`` for backward compatibility with callers
that predate the hierarchy.

Hierarchy::

    ServingError
    ├── AdmissionRejected (ValueError)   submit-time rejection
    │   ├── PoolExhausted                page-watermark backpressure
    │   └── BackpressureRejected         front-door load shed (carries
    │                                    retry_after_s → 503 Retry-After)
    ├── BucketOverflow (ValueError)      pow2 shape-bucket cap exceeded
    ├── MeshConfigError (ValueError)     invalid serving mesh shape
    ├── DeadlineExceeded                 ttft/timeout/step-cap expiry
    └── RequestFailed                    quarantined by the watchdog /
        └── FaultInjected                executor fault barrier
"""

from __future__ import annotations

from typing import Optional

__all__ = ["ServingError", "AdmissionRejected", "PoolExhausted",
           "BackpressureRejected", "BucketOverflow", "MeshConfigError",
           "DeadlineExceeded", "RequestFailed", "FaultInjected"]


class ServingError(Exception):
    """Base class for every typed serving-stack error."""


class AdmissionRejected(ServingError, ValueError):
    """Request refused at ``submit`` time — over-cap prompt, queue
    depth at ``max_queue_depth``, or pool watermark backpressure.  The
    request holds NO resources; the caller may retry later."""


class PoolExhausted(AdmissionRejected):
    """Admission gate: live pages are at/above the configured watermark
    of the pool — shed load now rather than wedge mid-decode later."""


class BackpressureRejected(AdmissionRejected):
    """Front-door load shed: the page pool (or request queue) is past
    the admission watermark for this request's priority tier.  The
    request holds no resources; ``retry_after_s`` tells the client how
    long to back off (the HTTP layer maps this to a 503 response with a
    ``Retry-After`` header)."""

    def __init__(self, msg: str, retry_after_s: float = 1.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class BucketOverflow(ServingError, ValueError):
    """A size exceeds its pow2 shape-bucket cap (token budget or
    pages-per-sequence) — the shape can never be scheduled."""


class MeshConfigError(ServingError, ValueError):
    """A serving mesh shape cannot be built: tensor-parallel degree not
    dividing the device count, more devices requested than exist, or a
    pool/slot count that does not divide across the ``data`` replicas.
    Raised at construction time — never mid-serve."""


class DeadlineExceeded(ServingError):
    """A per-request deadline (``ttft_deadline_ms``, ``timeout_ms``) or
    the engine's step cap expired; the request was retired TIMED_OUT
    with its pages freed."""


class RequestFailed(ServingError):
    """A request was quarantined (state FAILED): non-finite logits, a
    corrupted block table, a stalled sequence, or an executor fault
    attributed to it.  ``req_id`` names the culprit when known."""

    def __init__(self, msg: str, req_id: Optional[int] = None):
        super().__init__(msg)
        self.req_id = req_id


class FaultInjected(RequestFailed):
    """Raised by the deterministic fault harness (``serving.faults``)
    at the executor boundary — exercises the same recovery path a real
    executor exception takes."""
