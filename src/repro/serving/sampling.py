"""In-jit token sampling for the serving executor.

The sampling contract (``ServingEngine(greedy=...)``, per-request
temperature / top-k / top-p) is honored INSIDE the jitted
``unified_step``: logits never round-trip to host — the only arrays
that cross the device boundary per step are the sampled token ids
(``(S, K+1)`` int32) and the per-slot fault flags.  This is the §5.2
separation applied to the sampling tail of the step: the host decides
*what* to sample (per-request params ride as tiny operand arrays), the
device decides *which token* comes out.

Determinism contract (the replay anchor every test leans on):

  * the PRNG key for a sampled token depends ONLY on
    ``(seed, position)`` — ``fold_in(key(seed), position)`` where
    ``position`` is the token's absolute index in its sequence.  The
    same request replayed on a rebuilt engine, after a preemption, or
    inside a speculative batch therefore draws the SAME token at every
    position, which is what makes speculative decoding exact for any
    temperature (see ``spec.py``), not just for greedy;
  * ``temperature <= 0`` short-circuits to pure argmax — bitwise the
    pre-sampling behavior — so greedy serving pays no PRNG cost in
    semantics (the noise lanes are computed but discarded by a
    ``where``, keeping one fused executable for both modes);
  * filtering is threshold-based: ties at the top-k boundary or at the
    top-p cutoff value are all kept.  Deterministic, and identical
    between the in-jit path and the host reference used by the parity
    tests.

Sharded serving note: under R data replicas the executor flattens the
per-replica sampling operands to one (R·S·(K+1),) batch before calling
``sample_tokens`` — the position-keyed PRNG makes this layout-oblivious
(a slot's token depends on its own (seed, position), never on which
replica row or mesh shape carried it), which is exactly why seeded
outputs are bitwise-identical across mesh shapes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "filter_logits", "sample_tokens",
           "sample_ref"]

_NEG_INF = jnp.finfo(jnp.float32).min
_MIN_TEMP = 1e-6
_MIN_UNIFORM = 1e-20


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    ``temperature <= 0`` means greedy (argmax); ``top_k <= 0`` disables
    the top-k filter; ``top_p >= 1`` disables the nucleus filter.
    ``seed`` roots the request's PRNG stream — two requests with equal
    seeds draw identical noise at equal positions (replay-friendly; use
    distinct seeds for independent randomness)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        """True when this config degenerates to argmax decoding."""
        return self.temperature <= 0.0

    def validate(self) -> "SamplingParams":
        """Raise ``ValueError`` on out-of-range fields (negative top_k,
        top_p outside (0, 1]); returns self for chaining."""
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        return self


def filter_logits(logits: jnp.ndarray, temperature: jnp.ndarray,
                  top_k: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """Temperature-scale one ``(V,)`` logits row and mask everything
    outside the top-k / top-p support to ``-inf``.

    Fixed-shape (jit/vmap-safe): the per-row ``top_k`` is applied as a
    value threshold (the k-th largest scaled logit; ties at the
    boundary are kept), and ``top_p`` keeps the smallest sorted prefix
    whose exclusive cumulative probability is still below ``top_p``
    (so the token that crosses the boundary is included — the standard
    nucleus rule).  ``top_k <= 0`` and ``top_p >= 1`` are no-ops."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    scaled = logits / jnp.maximum(temperature, _MIN_TEMP)

    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    srt = jnp.sort(scaled)[::-1]                       # descending
    kth = srt[k_eff - 1]
    keep = scaled >= kth

    ranks = jnp.arange(v)
    in_k = ranks < k_eff
    srt_k = jnp.where(in_k, srt, _NEG_INF)
    probs = jax.nn.softmax(srt_k)
    cum = jnp.cumsum(probs)
    keep_sorted = ((cum - probs) < top_p) & in_k       # exclusive cumsum
    thr = jnp.min(jnp.where(keep_sorted, srt_k, jnp.inf))
    keep = keep & (scaled >= thr)
    return jnp.where(keep, scaled, _NEG_INF)


def _fold_keys(seeds: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """(R,) seeds x (R,) positions -> (R,) typed PRNG keys, entirely
    on device: ``fold_in(key(seed), position)`` per row."""
    return jax.vmap(
        lambda s, p: jax.random.fold_in(jax.random.key(s), p)
    )(seeds, positions)


def sample_tokens(logits: jnp.ndarray, temperature: jnp.ndarray,
                  top_k: jnp.ndarray, top_p: jnp.ndarray,
                  seeds: jnp.ndarray, positions: jnp.ndarray
                  ) -> jnp.ndarray:
    """Sample one token per ``(R, V)`` logits row, fully in-jit.

    ``temperature``/``top_k``/``top_p``/``seeds``/``positions`` are
    ``(R,)`` per-row arrays (operands, not statics — per-request params
    never trigger a recompile).  Stochastic rows draw via the
    Gumbel-max trick over the filtered support (one fused perturb
    kernel on TPU, see ``kernels.ops.gumbel_perturb``); rows with
    ``temperature <= 0`` return plain ``argmax(logits)``.  Returns
    ``(R,)`` int32 token ids."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    filtered = jax.vmap(filter_logits)(logits, temperature, top_k, top_p)
    keys = _fold_keys(seeds, positions)
    uniform = jax.vmap(
        lambda k: jax.random.uniform(k, (v,), jnp.float32,
                                     minval=_MIN_UNIFORM)
    )(keys)
    from ..kernels import ops as kops
    perturbed = kops.gumbel_perturb(filtered, uniform)
    stochastic = jnp.argmax(perturbed, axis=-1)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(temperature > 0.0, stochastic,
                     greedy).astype(jnp.int32)


def sample_ref(logits: jnp.ndarray, params: SamplingParams,
               position: int,
               seed: Optional[int] = None) -> int:
    """Host-side single-row reference: sample the token the in-jit path
    would produce for one ``(V,)`` logits row at ``position``.  The
    parity tests pin ``sample_tokens`` against this (and against an
    independent numpy filter reference)."""
    seed = params.seed if seed is None else seed
    tok = sample_tokens(
        jnp.asarray(logits, jnp.float32)[None],
        jnp.asarray([params.temperature], jnp.float32),
        jnp.asarray([params.top_k], jnp.int32),
        jnp.asarray([params.top_p], jnp.float32),
        jnp.asarray([seed], jnp.uint32),
        jnp.asarray([position], jnp.int32))
    return int(tok[0])
