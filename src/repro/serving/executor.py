"""Serving data plane — ONE jitted ``unified_step`` per shape bucket.

The executor consumes a ``StepPlan`` (host-built by the Scheduler) and
runs the whole step's compute as a single XLA executable:

  * a padded FLAT token batch (T,) mixing prefill-chunk tokens and decode
    tokens — the §5.2 "all data flow in one compiled program" applied to
    serving,
  * per-layer K/V appends are ONE flat scatter per layer INSIDE the jit
    (``write_idx`` precomputed on host; out-of-bounds rows drop — the
    padding/reused-prefix skip), replacing the O(prompt_len × layers)
    host round-trips of the old ``_prefill``,
  * attention reads the KV pages DIRECTLY through the device block-table
    mirror via ``paged_attention`` (per-token segment ids/positions; on
    TPU the Pallas kernel scalar-prefetches the table and DMAs only live
    pages — no per-slot contiguous cache is ever gathered),
  * the KV page arrays are DONATED: ``unified_step`` consumes them and
    returns the updated pair; while the step runs the host holds no
    alias (``PagedKVCache.take_kv``/``put_kv`` enforce this),
  * SAMPLING runs in the same executable (``serving.sampling``):
    greedy / temperature / top-k / top-p with per-slot params as tiny
    operand arrays and position-keyed PRNG — plus the K speculative
    verify rows per slot — so the (rows, vocab) logits NEVER cross to
    host; the step's only outputs are (S, K+1) token ids and (S,)
    fault flags.

Shapes are bucketed (powers of two: token batch up to ``token_budget``,
pages per sequence up to ``max_pages_per_seq``; slot count fixed at
``max_batch``), so the executable compiles O(log) variants total instead
of one per live batch size — ``compile_count`` must stay ≤
``Scheduler.bucket_count`` (the CI gate).
"""

from __future__ import annotations

import math
import warnings
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import layers as L
from ..models.attention import paged_attention, select_paged_backend
from ..models import lm as LM
from . import quant, sampling
from .kv_cache import PagedKVCache
from .scheduler import StepPlan

# buffer donation is a TPU/GPU optimization; CPU (tests) just warns
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


def split_layer_params(cfg: LM.LMConfig, params) -> list:
    """Flatten the scan-stacked group params (+ unrolled tail) into one
    per-layer list — serving iterates layers in Python, not lax.scan."""
    layers = []
    for gi in range(cfg.n_groups):
        for j in range(len(cfg.pattern)):
            layers.append(jax.tree_util.tree_map(
                lambda a: a[gi], params["groups"][j]))
    for j in range(len(cfg.tail)):
        layers.append(params["tail"][j])
    return layers


class Executor:
    """Owns the jitted step; stateless between calls except the compile
    bookkeeping."""

    def __init__(self, cfg: LM.LMConfig, params, *, mesh=None,
                 n_replicas: int = 1, kv_sharding=None,
                 kv_quant=None, scale_sharding=None):
        self.cfg = cfg
        # quantized KV: the step quantizes k/v per (token, head) right
        # before the flat scatter (codes into the pool, scales into the
        # parallel arrays at the SAME write_idx) and attention
        # dequantizes in-kernel — None keeps the fp32/bf16 trace
        # byte-identical to the unquantized executor
        self._kv_quant = quant.canonical(kv_quant)
        self.mesh = mesh
        if mesh is not None:
            n_replicas = dict(mesh.shape).get("data", 1)
            from ..distributed.sharding import serving_param_shardings
            params = jax.tree_util.tree_map(
                jax.device_put, params,
                serving_param_shardings(cfg, params, mesh))
        self.n_replicas = n_replicas
        self.params = params
        self._layer_params = split_layer_params(cfg, params)
        # a replica axis (vmap) or a mesh pins the jnp ref attention
        # path — the Pallas kernel's scalar-prefetch table lookup is a
        # single-device whole-pool construct (see select_paged_backend)
        self._attn_backend = select_paged_backend(
            cfg.attn_backend, sharded=(mesh is not None or n_replicas > 1))
        # KV pages keep THIS sharding across steps: constrained on the
        # step outputs so donation round-trips never reshard
        self._kv_sharding = kv_sharding
        self._scale_sharding = scale_sharding
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self._plan_sh = {
                2: NamedSharding(mesh, P("data", None)),
                3: NamedSharding(mesh, P("data", None, None)),
            }
        else:
            self._plan_sh = None
        # p_bucket is static: the full-width device table mirror is
        # narrowed to the step's page bucket INSIDE the jit (free), so
        # the host never slices/re-uploads tables per step
        self._step = jax.jit(self._unified_step, static_argnums=(0,),
                             donate_argnums=(1, 2, 3, 4))
        self._compiled: set = set()

    @property
    def compile_count(self) -> int:
        if hasattr(self._step, "_cache_size"):
            return self._step._cache_size()
        return len(self._compiled)

    # -- host entry -------------------------------------------------------
    def execute(self, plan: StepPlan, kv: PagedKVCache
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Run one unified step; returns ((max_batch, K+1) sampled
        tokens — column 0 is the step's next token, columns 1..K the
        target tokens at the speculative draft positions — and a
        (max_batch,) bool non-finite-logits flag array, the fault
        barrier the engine uses to quarantine a poisoned sequence
        without losing the step for everyone else).  Sampling runs
        INSIDE the jit: only these two small arrays ever cross the
        device boundary — the (S·(K+1), V) logits never do."""
        tables = kv.device_tables(plan.slot_seqs, plan.p_bucket)
        ks, vs = kv.take_kv()
        kss, vss = kv.take_scales()      # ([], []) unquantized
        op = self._place
        try:
            next_tokens, bad, ks, vs, kss, vss = self._step(
                plan.p_bucket, ks, vs, kss, vss,
                op(plan.tokens), op(plan.seg_ids),
                op(plan.positions), op(plan.write_idx),
                tables, op(plan.sample_idx),
                op(plan.sample_pos), op(plan.temps),
                op(plan.top_ks), op(plan.top_ps),
                op(plan.seeds))
        finally:
            if ks is not None:
                kv.put_kv(ks, vs)
                kv.put_scales(kss, vss)
        self._compiled.add((plan.t_bucket, plan.p_bucket))
        return np.asarray(next_tokens), np.asarray(bad)

    def _place(self, a) -> jnp.ndarray:
        """Plan operands under a mesh get an explicit replica-axis
        placement (row r → replica r's devices); otherwise asarray —
        stable input shardings keep the jit cache at one entry per
        shape bucket."""
        if self._plan_sh is not None:
            a = np.asarray(a)
            sh = self._plan_sh.get(a.ndim)
            if sh is not None:
                return jax.device_put(a, sh)
        return jnp.asarray(a)

    # -- the jitted data plane -------------------------------------------
    def _unified_step(self, p_bucket: int, k_pages: List[jnp.ndarray],
                      v_pages: List[jnp.ndarray],
                      k_scales: List[jnp.ndarray],
                      v_scales: List[jnp.ndarray],
                      tokens: jnp.ndarray, seg_ids: jnp.ndarray,
                      positions: jnp.ndarray, write_idx: jnp.ndarray,
                      tables: jnp.ndarray, sample_idx: jnp.ndarray,
                      sample_pos: jnp.ndarray, temps: jnp.ndarray,
                      top_ks: jnp.ndarray, top_ps: jnp.ndarray,
                      seeds: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                 List[jnp.ndarray], List[jnp.ndarray],
                                 List[jnp.ndarray], List[jnp.ndarray]]:
        """Single replica: tokens/seg_ids/positions/write_idx (T,),
        tables (S, W>=P), sample_idx (S, K+1), sample_pos/temps/top_ks/
        top_ps/seeds (S,) — all operands, never statics (per-request
        params cannot trigger a recompile).  With R data replicas every
        plan operand grows a leading replica axis ((R, T), (R, S, K+1),
        (R, S)) and the transformer body vmaps over it — replica r runs
        the single-device step against its OWN slice of the page pool
        ((R, N/R, ps, Hkv, hd) view) and its own S-row table block, so
        per-replica bucket shapes (and the compiled-variant count) are
        IDENTICAL to the single-device plan.  Under a mesh GSPMD then
        partitions the vmapped program over ``data``/``model``.
        Returns ((R*S, K+1) sampled int32 tokens, (R*S,) non-finite-
        logits flags, new K/V page arrays)."""
        cfg = self.cfg
        replicated = tokens.ndim == 2
        if not replicated:
            x, new_k, new_v, new_ks, new_vs = self._body(
                k_pages, v_pages, k_scales, v_scales, tokens, seg_ids,
                positions, write_idx, tables[:, :p_bucket])
            s, kp1 = sample_idx.shape
            xs = jnp.take(x, sample_idx.reshape(-1), axis=0)  # (S*(K+1), D)
        else:
            r = tokens.shape[0]
            n_total, ps = k_pages[0].shape[0], k_pages[0].shape[1]
            n_local = n_total // r
            k_r = [a.reshape(r, n_local, *a.shape[1:]) for a in k_pages]
            v_r = [a.reshape(r, n_local, *a.shape[1:]) for a in v_pages]
            ks_r = [a.reshape(r, n_local, *a.shape[1:]) for a in k_scales]
            vs_r = [a.reshape(r, n_local, *a.shape[1:]) for a in v_scales]
            tab_r = tables.reshape(r, tables.shape[0] // r,
                                   tables.shape[1])[:, :, :p_bucket]
            x, new_k, new_v, new_ks, new_vs = jax.vmap(self._body)(
                k_r, v_r, ks_r, vs_r, tokens, seg_ids, positions,
                write_idx, tab_r)
            new_k = [a.reshape(n_total, *a.shape[2:]) for a in new_k]
            new_v = [a.reshape(n_total, *a.shape[2:]) for a in new_v]
            new_ks = [a.reshape(n_total, *a.shape[2:]) for a in new_ks]
            new_vs = [a.reshape(n_total, *a.shape[2:]) for a in new_vs]
            if self._kv_sharding is not None:
                cons = jax.lax.with_sharding_constraint
                new_k = [cons(a, self._kv_sharding) for a in new_k]
                new_v = [cons(a, self._kv_sharding) for a in new_v]
                if self._scale_sharding is not None:
                    new_ks = [cons(a, self._scale_sharding)
                              for a in new_ks]
                    new_vs = [cons(a, self._scale_sharding)
                              for a in new_vs]
            _, s_r, kp1 = sample_idx.shape
            s = r * s_r
            # per-replica row gather out of (R, T, D) hidden states,
            # then flatten: the sampling tail below is replica-oblivious
            xs = jax.vmap(lambda xr, ir: jnp.take(xr, ir, axis=0))(
                x, sample_idx.reshape(r, -1)).reshape(s * kp1, -1)
            sample_pos = sample_pos.reshape(-1)
            temps = temps.reshape(-1)
            top_ks = top_ks.reshape(-1)
            top_ps = top_ps.reshape(-1)
            seeds = seeds.reshape(-1)
        logits = xs @ (self.params["embed"].T if cfg.tie_embeddings
                       else self.params["lm_head"])
        # per-slot fault barrier: a NaN/inf logits row (poisoned KV,
        # overflowed activations) flags JUST that slot — the engine
        # quarantines the one request instead of crashing the step loop
        bad = jnp.any(~jnp.all(jnp.isfinite(logits), axis=-1)
                      .reshape(s, kp1), axis=-1)
        # sample IN-JIT: row i of a slot draws the token at absolute
        # position sample_pos + i under that slot's params — the PRNG
        # key depends only on (seed, position), which is what makes the
        # speculative targets bitwise-equal to a non-speculative replay
        gen_pos = (sample_pos[:, None]
                   + jnp.arange(kp1, dtype=jnp.int32)[None, :])
        toks = sampling.sample_tokens(
            logits, jnp.repeat(temps, kp1), jnp.repeat(top_ks, kp1),
            jnp.repeat(top_ps, kp1), jnp.repeat(seeds, kp1),
            gen_pos.reshape(-1))
        return toks.reshape(s, kp1), bad, new_k, new_v, new_ks, new_vs

    def _body(self, k_pages: List[jnp.ndarray], v_pages: List[jnp.ndarray],
              k_scales: List[jnp.ndarray], v_scales: List[jnp.ndarray],
              tokens: jnp.ndarray, seg_ids: jnp.ndarray,
              positions: jnp.ndarray, write_idx: jnp.ndarray,
              tables: jnp.ndarray
              ) -> Tuple[jnp.ndarray, List[jnp.ndarray], List[jnp.ndarray],
                         List[jnp.ndarray], List[jnp.ndarray]]:
        """One replica's transformer pass over its (n, ps, Hkv, hd) page
        slice: embed → layers (KV scatter + paged attention in place) →
        final norm.  Returns the (T, D) normed hidden states and the
        updated page (and, quantized, scale) arrays; write_idx/tables
        are replica-LOCAL."""
        cfg = self.cfg
        t = tokens.shape[0]
        n_pages, ps = k_pages[0].shape[0], k_pages[0].shape[1]
        scale = cfg.query_scale or cfg.hd ** -0.5

        x = jnp.take(self.params["embed"], tokens, axis=0)     # (T, D)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

        qmode = self._kv_quant
        new_k, new_v, new_ks, new_vs = [], [], [], []
        for li, lp in enumerate(self._layer_params):
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps, cfg.norm_offset) \
                if cfg.norm == "rms" else L.layer_norm(
                    x, lp["norm1"], lp.get("norm1_b"), cfg.norm_eps)
            q = (h @ lp["attn"]["wq"]).reshape(t, cfg.n_heads, cfg.hd)
            k = (h @ lp["attn"]["wk"]).reshape(t, cfg.n_kv_heads, cfg.hd)
            v = (h @ lp["attn"]["wv"]).reshape(t, cfg.n_kv_heads, cfg.hd)
            if cfg.rope_theta is not None:
                # (T, H, 1, hd) + per-token positions (T, 1)
                q = L.apply_rope(q[:, :, None], positions[:, None],
                                 cfg.rope_theta)[:, :, 0]
                k = L.apply_rope(k[:, :, None], positions[:, None],
                                 cfg.rope_theta)[:, :, 0]

            # one segment-indexed scatter per layer (padding + reused-
            # prefix rows carry an OOB index and drop)
            kf = k_pages[li].reshape(n_pages * ps, cfg.n_kv_heads, cfg.hd)
            vf = v_pages[li].reshape(n_pages * ps, cfg.n_kv_heads, cfg.hd)
            ks_p = vs_p = None
            if qmode is None:
                kf = kf.at[write_idx].set(k.astype(kf.dtype), mode="drop")
                vf = vf.at[write_idx].set(v.astype(vf.dtype), mode="drop")
            else:
                # quantize on scatter: int8/fp8 codes into the pool,
                # per-(token, head) scales into the parallel arrays at
                # the SAME flat slots (same drop semantics)
                kq, k_sc = quant.quantize(k, qmode)
                vq, v_sc = quant.quantize(v, qmode)
                kf = kf.at[write_idx].set(kq, mode="drop")
                vf = vf.at[write_idx].set(vq, mode="drop")
                ks_p = k_scales[li].reshape(n_pages * ps, cfg.n_kv_heads) \
                    .at[write_idx].set(k_sc, mode="drop") \
                    .reshape(n_pages, ps, cfg.n_kv_heads)
                vs_p = v_scales[li].reshape(n_pages * ps, cfg.n_kv_heads) \
                    .at[write_idx].set(v_sc, mode="drop") \
                    .reshape(n_pages, ps, cfg.n_kv_heads)
                new_ks.append(ks_p)
                new_vs.append(vs_p)
            kp = kf.reshape(n_pages, ps, cfg.n_kv_heads, cfg.hd)
            vp = vf.reshape(n_pages, ps, cfg.n_kv_heads, cfg.hd)
            new_k.append(kp)
            new_v.append(vp)

            # attend the page pool in place through the block table
            # (includes this step's writes; no per-slot gather) — a
            # quantized pool keeps q in compute dtype and dequantizes
            # the pages in-kernel via the scale operands
            o = paged_attention(q.astype(kp.dtype) if qmode is None
                                else q, kp, vp, tables,
                                seg_ids, positions, scale=scale,
                                k_scale=ks_p, v_scale=vs_p,
                                backend=self._attn_backend)
            x = x + o.reshape(t, -1).astype(x.dtype) @ lp["attn"]["wo"]
            if "mlp" in lp:
                h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps,
                                cfg.norm_offset) if cfg.norm == "rms" \
                    else L.layer_norm(x, lp["norm2"], lp.get("norm2_b"),
                                      cfg.norm_eps)
                x = x + L.mlp(lp["mlp"], h2, cfg.act)

        x = L.rms_norm(x, self.params["final_norm"], cfg.norm_eps,
                       cfg.norm_offset) if cfg.norm == "rms" else \
            L.layer_norm(x, self.params["final_norm"],
                         self.params.get("final_norm_b"), cfg.norm_eps)
        return x, new_k, new_v, new_ks, new_vs
