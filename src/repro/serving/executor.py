"""Serving data plane — ONE jitted ``unified_step`` per shape bucket.

The executor consumes a ``StepPlan`` (host-built by the Scheduler) and
runs the whole step's compute as a single XLA executable:

  * a padded FLAT token batch (T,) mixing prefill-chunk tokens and decode
    tokens — the §5.2 "all data flow in one compiled program" applied to
    serving,
  * per-layer K/V appends are ONE flat scatter per layer INSIDE the jit
    (``write_idx`` precomputed on host; out-of-bounds rows drop — the
    padding/reused-prefix skip), replacing the O(prompt_len × layers)
    host round-trips of the old ``_prefill``,
  * attention reads the KV pages DIRECTLY through the device block-table
    mirror via ``paged_attention`` (per-token segment ids/positions; on
    TPU the Pallas kernel scalar-prefetches the table and DMAs only live
    pages — no per-slot contiguous cache is ever gathered),
  * the KV page arrays are DONATED: ``unified_step`` consumes them and
    returns the updated pair; while the step runs the host holds no
    alias (``PagedKVCache.take_kv``/``put_kv`` enforce this),
  * SAMPLING runs in the same executable (``serving.sampling``):
    greedy / temperature / top-k / top-p with per-slot params as tiny
    operand arrays and position-keyed PRNG — plus the K speculative
    verify rows per slot — so the (rows, vocab) logits NEVER cross to
    host; the step's only outputs are (S, K+1) token ids and (S,)
    fault flags.

Shapes are bucketed (powers of two: token batch up to ``token_budget``,
pages per sequence up to ``max_pages_per_seq``; slot count fixed at
``max_batch``), so the executable compiles O(log) variants total instead
of one per live batch size — ``compile_count`` must stay ≤
``Scheduler.bucket_count`` (the CI gate).
"""

from __future__ import annotations

import math
import warnings
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import layers as L
from ..models.attention import paged_attention
from ..models import lm as LM
from . import sampling
from .kv_cache import PagedKVCache
from .scheduler import StepPlan

# buffer donation is a TPU/GPU optimization; CPU (tests) just warns
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


def split_layer_params(cfg: LM.LMConfig, params) -> list:
    """Flatten the scan-stacked group params (+ unrolled tail) into one
    per-layer list — serving iterates layers in Python, not lax.scan."""
    layers = []
    for gi in range(cfg.n_groups):
        for j in range(len(cfg.pattern)):
            layers.append(jax.tree_util.tree_map(
                lambda a: a[gi], params["groups"][j]))
    for j in range(len(cfg.tail)):
        layers.append(params["tail"][j])
    return layers


class Executor:
    """Owns the jitted step; stateless between calls except the compile
    bookkeeping."""

    def __init__(self, cfg: LM.LMConfig, params):
        self.cfg = cfg
        self.params = params
        self._layer_params = split_layer_params(cfg, params)
        # p_bucket is static: the full-width device table mirror is
        # narrowed to the step's page bucket INSIDE the jit (free), so
        # the host never slices/re-uploads tables per step
        self._step = jax.jit(self._unified_step, static_argnums=(0,),
                             donate_argnums=(1, 2))
        self._compiled: set = set()

    @property
    def compile_count(self) -> int:
        if hasattr(self._step, "_cache_size"):
            return self._step._cache_size()
        return len(self._compiled)

    # -- host entry -------------------------------------------------------
    def execute(self, plan: StepPlan, kv: PagedKVCache
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Run one unified step; returns ((max_batch, K+1) sampled
        tokens — column 0 is the step's next token, columns 1..K the
        target tokens at the speculative draft positions — and a
        (max_batch,) bool non-finite-logits flag array, the fault
        barrier the engine uses to quarantine a poisoned sequence
        without losing the step for everyone else).  Sampling runs
        INSIDE the jit: only these two small arrays ever cross the
        device boundary — the (S·(K+1), V) logits never do."""
        tables = kv.device_tables(plan.slot_seqs, plan.p_bucket)
        ks, vs = kv.take_kv()
        try:
            next_tokens, bad, ks, vs = self._step(
                plan.p_bucket, ks, vs,
                jnp.asarray(plan.tokens), jnp.asarray(plan.seg_ids),
                jnp.asarray(plan.positions), jnp.asarray(plan.write_idx),
                tables, jnp.asarray(plan.sample_idx),
                jnp.asarray(plan.sample_pos), jnp.asarray(plan.temps),
                jnp.asarray(plan.top_ks), jnp.asarray(plan.top_ps),
                jnp.asarray(plan.seeds))
        finally:
            if ks is not None:
                kv.put_kv(ks, vs)
        self._compiled.add((plan.t_bucket, plan.p_bucket))
        return np.asarray(next_tokens), np.asarray(bad)

    # -- the jitted data plane -------------------------------------------
    def _unified_step(self, p_bucket: int, k_pages: List[jnp.ndarray],
                      v_pages: List[jnp.ndarray],
                      tokens: jnp.ndarray, seg_ids: jnp.ndarray,
                      positions: jnp.ndarray, write_idx: jnp.ndarray,
                      tables: jnp.ndarray, sample_idx: jnp.ndarray,
                      sample_pos: jnp.ndarray, temps: jnp.ndarray,
                      top_ks: jnp.ndarray, top_ps: jnp.ndarray,
                      seeds: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                 List[jnp.ndarray], List[jnp.ndarray]]:
        """tokens/seg_ids/positions/write_idx: (T,); tables: (S, W>=P)
        full-width block-table mirror, narrowed here to the static
        ``p_bucket``; sample_idx: (S, K+1) token-batch rows to sample;
        sample_pos/temps/top_ks/top_ps/seeds: (S,) per-slot sampling
        state (operands, never statics — per-request params cannot
        trigger a recompile).  Returns ((S, K+1) sampled int32 tokens,
        (S,) non-finite-logits flags, new K/V page arrays)."""
        cfg = self.cfg
        t = tokens.shape[0]
        n_pages, ps = k_pages[0].shape[0], k_pages[0].shape[1]
        tables = tables[:, :p_bucket]
        scale = cfg.query_scale or cfg.hd ** -0.5

        x = jnp.take(self.params["embed"], tokens, axis=0)     # (T, D)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

        new_k, new_v = [], []
        for li, lp in enumerate(self._layer_params):
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps, cfg.norm_offset) \
                if cfg.norm == "rms" else L.layer_norm(
                    x, lp["norm1"], lp.get("norm1_b"), cfg.norm_eps)
            q = (h @ lp["attn"]["wq"]).reshape(t, cfg.n_heads, cfg.hd)
            k = (h @ lp["attn"]["wk"]).reshape(t, cfg.n_kv_heads, cfg.hd)
            v = (h @ lp["attn"]["wv"]).reshape(t, cfg.n_kv_heads, cfg.hd)
            if cfg.rope_theta is not None:
                # (T, H, 1, hd) + per-token positions (T, 1)
                q = L.apply_rope(q[:, :, None], positions[:, None],
                                 cfg.rope_theta)[:, :, 0]
                k = L.apply_rope(k[:, :, None], positions[:, None],
                                 cfg.rope_theta)[:, :, 0]

            # one segment-indexed scatter per layer (padding + reused-
            # prefix rows carry an OOB index and drop)
            kf = k_pages[li].reshape(n_pages * ps, cfg.n_kv_heads, cfg.hd)
            vf = v_pages[li].reshape(n_pages * ps, cfg.n_kv_heads, cfg.hd)
            kf = kf.at[write_idx].set(k.astype(kf.dtype), mode="drop")
            vf = vf.at[write_idx].set(v.astype(vf.dtype), mode="drop")
            kp = kf.reshape(n_pages, ps, cfg.n_kv_heads, cfg.hd)
            vp = vf.reshape(n_pages, ps, cfg.n_kv_heads, cfg.hd)
            new_k.append(kp)
            new_v.append(vp)

            # attend the page pool in place through the block table
            # (includes this step's writes; no per-slot gather)
            o = paged_attention(q.astype(kp.dtype), kp, vp, tables,
                                seg_ids, positions, scale=scale,
                                backend=cfg.attn_backend)
            x = x + o.reshape(t, -1).astype(x.dtype) @ lp["attn"]["wo"]
            if "mlp" in lp:
                h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps,
                                cfg.norm_offset) if cfg.norm == "rms" \
                    else L.layer_norm(x, lp["norm2"], lp.get("norm2_b"),
                                      cfg.norm_eps)
                x = x + L.mlp(lp["mlp"], h2, cfg.act)

        x = L.rms_norm(x, self.params["final_norm"], cfg.norm_eps,
                       cfg.norm_offset) if cfg.norm == "rms" else \
            L.layer_norm(x, self.params["final_norm"],
                         self.params.get("final_norm_b"), cfg.norm_eps)
        s, kp1 = sample_idx.shape
        xs = jnp.take(x, sample_idx.reshape(-1), axis=0)  # (S*(K+1), D)
        logits = xs @ (self.params["embed"].T if cfg.tie_embeddings
                       else self.params["lm_head"])
        # per-slot fault barrier: a NaN/inf logits row (poisoned KV,
        # overflowed activations) flags JUST that slot — the engine
        # quarantines the one request instead of crashing the step loop
        bad = jnp.any(~jnp.all(jnp.isfinite(logits), axis=-1)
                      .reshape(s, kp1), axis=-1)
        # sample IN-JIT: row i of a slot draws the token at absolute
        # position sample_pos + i under that slot's params — the PRNG
        # key depends only on (seed, position), which is what makes the
        # speculative targets bitwise-equal to a non-speculative replay
        gen_pos = (sample_pos[:, None]
                   + jnp.arange(kp1, dtype=jnp.int32)[None, :])
        toks = sampling.sample_tokens(
            logits, jnp.repeat(temps, kp1), jnp.repeat(top_ks, kp1),
            jnp.repeat(top_ps, kp1), jnp.repeat(seeds, kp1),
            gen_pos.reshape(-1))
        return toks.reshape(s, kp1), bad, new_k, new_v
