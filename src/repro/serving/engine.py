"""Continuous-batching serving engine over the paged KV cache.

Control flow (request admission, scheduling, page tables) runs in Python
on the host; data flow (prefill/decode compute) is jit-compiled XLA — the
paper's §5.2 separation, at serving granularity.

Loop per step:
  1. admit waiting requests while pages remain (admission control = the
     allocator's job, §5.3),
  2. batched single-token decode for all RUNNING sequences: gather paged
     KV per layer → decode attention → append new KV pages,
  3. retire finished sequences → pages refcount-released immediately
     (§5.5) and reusable by the very next admission.

This is a faithful small-scale vLLM-style engine; the dense-cache
``launch.make_serve_step`` path is the pod-scale pjit twin (used by the
decode_32k/long_500k dry-run cells).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm as LM
from ..models import layers as L
from ..models.attention import decode_attention
from .kv_cache import PagedKVCache


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class ServingEngine:
    """Batched serving for pattern-homogeneous attention LMs (the paged
    path supports 'attn' mixers; hybrid archs serve via the dense-cache
    pjit path)."""

    def __init__(self, cfg: LM.LMConfig, params, *, page_size: int = 16,
                 num_pages: int = 512, max_batch: int = 8,
                 greedy: bool = True):
        for spec in cfg.pattern:
            if spec.mixer not in ("attn",):
                raise ValueError(
                    "paged engine serves full-attention models; use the "
                    "dense-cache pjit path for hybrid/ssm archs")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.greedy = greedy
        self.kv = PagedKVCache(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, page_size=page_size, num_pages=num_pages,
            dtype=jnp.float32 if cfg.param_dtype == jnp.float32
            else jnp.bfloat16)
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self._next_id = 0
        self.metrics = {"steps": 0, "prefills": 0, "decoded_tokens": 0,
                        "rejected_admissions": 0}

        self._layer_params = self._split_layer_params()
        self._token_fn = jax.jit(self._token_compute)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: Sequence[int],
               max_new_tokens: int = 16) -> int:
        req = Request(self._next_id, list(prompt), max_new_tokens,
                      submitted_at=time.perf_counter())
        self._next_id += 1
        self.waiting.append(req)
        return req.req_id

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                break
            self._admit()
            finished.extend(self.step())
            self.metrics["steps"] += 1
        return finished

    # -- scheduling -----------------------------------------------------------
    def _admit(self) -> None:
        while (self.waiting and len(self.running) < self.max_batch):
            req = self.waiting[0]
            if not self.kv.can_admit(len(req.prompt) + 1):
                self.metrics["rejected_admissions"] += 1
                break
            self.waiting.pop(0)
            if not self.kv.create(req.req_id, req.prompt):
                self.waiting.insert(0, req)
                break
            self._prefill(req)
            self.running[req.req_id] = req

    def step(self) -> List[Request]:
        """One continuous-batching decode step for all running seqs."""
        if not self.running:
            return []
        seq_ids = sorted(self.running)
        last_tokens = []
        for s in seq_ids:
            r = self.running[s]
            last_tokens.append(r.out_tokens[-1] if r.out_tokens
                               else r.prompt[-1])
        next_tokens, layer_kv = self._decode_batch(seq_ids, last_tokens)

        finished = []
        for i, s in enumerate(seq_ids):
            r = self.running[s]
            ok = self.kv.append(s, [(k[i], v[i]) for k, v in layer_kv])
            if not ok:
                # out of pages mid-flight: preempt (requeue) this request
                self.kv.free_seq(s)
                del self.running[s]
                self.waiting.insert(0, r)
                continue
            tok = int(next_tokens[i])
            r.out_tokens.append(tok)
            if r.first_token_at is None:
                r.first_token_at = time.perf_counter()
            self.metrics["decoded_tokens"] += 1
            if r.done:
                r.finished_at = time.perf_counter()
                self.kv.free_seq(s)
                del self.running[s]
                finished.append(r)
        return finished

    # -- compute -------------------------------------------------------------
    def _split_layer_params(self):
        cfg = self.cfg
        layers = []
        for gi in range(cfg.n_groups):
            for j in range(len(cfg.pattern)):
                layers.append(jax.tree_util.tree_map(
                    lambda a: a[gi], self.params["groups"][j]))
        for j in range(len(cfg.tail)):
            layers.append(self.params["tail"][j])
        return layers

    def _prefill(self, req: Request) -> None:
        """Run the prompt through the model, appending K/V page-wise.
        Skips compute for fully prefix-shared pages' recompute is avoided
        at the KV level (their K/V already sit in shared pages)."""
        cfg = self.cfg
        tokens = jnp.asarray([req.prompt], jnp.int32)
        kvs, logits = self._prefill_fn(tokens)
        # write K/V token-by-token into pages, SKIPPING tokens whose
        # pages came from the prefix cache (their K/V is already there —
        # this is the recompute-write saving of prefix sharing)
        skip = self.kv.reused_prefix.get(req.req_id, 0)
        self.kv.lengths[req.req_id] = skip
        for t in range(skip, len(req.prompt)):
            self.kv.append(req.req_id,
                           [(k[0, :, t], v[0, :, t]) for k, v in kvs])
        self.metrics["prefills"] += 1
        req.out_tokens.append(int(jnp.argmax(logits[0, -1])))
        req.first_token_at = time.perf_counter()

    def _prefill_fn(self, tokens):
        cfg = self.cfg
        x = jnp.take(self.params["embed"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        kvs = []
        for lp in self._layer_params:
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps, cfg.norm_offset) \
                if cfg.norm == "rms" else L.layer_norm(
                    x, lp["norm1"], lp.get("norm1_b"), cfg.norm_eps)
            b, s, _ = h.shape
            q = (h @ lp["attn"]["wq"]).reshape(
                b, s, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
            k = (h @ lp["attn"]["wk"]).reshape(
                b, s, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            v = (h @ lp["attn"]["wv"]).reshape(
                b, s, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            if cfg.rope_theta is not None:
                pos = jnp.arange(s)
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
            kvs.append((k, v))
            from ..models.attention import sdpa_ref
            o = sdpa_ref(q, k, v, is_causal=cfg.causal,
                         scale=cfg.query_scale or cfg.hd ** -0.5)
            o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
            x = x + o @ lp["attn"]["wo"]
            if "mlp" in lp:
                h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps,
                                cfg.norm_offset) if cfg.norm == "rms" \
                    else L.layer_norm(x, lp["norm2"], lp.get("norm2_b"),
                                      cfg.norm_eps)
                x = x + L.mlp(lp["mlp"], h2, cfg.act)
        x = L.rms_norm(x, self.params["final_norm"], cfg.norm_eps,
                       cfg.norm_offset) if cfg.norm == "rms" else \
            L.layer_norm(x, self.params["final_norm"],
                         self.params.get("final_norm_b"), cfg.norm_eps)
        logits = x @ (self.params["embed"].T if cfg.tie_embeddings
                      else self.params["lm_head"])
        return kvs, logits

    def _token_compute(self, tokens, pos, gathered):
        """One decode step given pre-gathered per-layer K/V."""
        cfg = self.cfg
        x = jnp.take(self.params["embed"], tokens[:, None], axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        new_kv = []
        for li, lp in enumerate(self._layer_params):
            k_cache, v_cache, lens = gathered[li]
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps, cfg.norm_offset) \
                if cfg.norm == "rms" else L.layer_norm(
                    x, lp["norm1"], lp.get("norm1_b"), cfg.norm_eps)
            b = h.shape[0]
            q = (h @ lp["attn"]["wq"]).reshape(
                b, 1, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
            k = (h @ lp["attn"]["wk"]).reshape(
                b, 1, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            v = (h @ lp["attn"]["wv"]).reshape(
                b, 1, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            if cfg.rope_theta is not None:
                q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
                k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
            # attend over gathered cache + the fresh token
            k_full = jnp.concatenate(
                [k_cache, k.astype(k_cache.dtype)], axis=2)
            v_full = jnp.concatenate(
                [v_cache, v.astype(v_cache.dtype)], axis=2)
            o = decode_attention(q, k_full, v_full, cache_len=lens + 1,
                                 scale=cfg.query_scale or cfg.hd ** -0.5,
                                 backend="ref")
            o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
            x = x + o @ lp["attn"]["wo"]
            if "mlp" in lp:
                h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps,
                                cfg.norm_offset) if cfg.norm == "rms" \
                    else L.layer_norm(x, lp["norm2"], lp.get("norm2_b"),
                                      cfg.norm_eps)
                x = x + L.mlp(lp["mlp"], h2, cfg.act)
            new_kv.append((k[:, :, 0], v[:, :, 0]))
        x = L.rms_norm(x, self.params["final_norm"], cfg.norm_eps,
                       cfg.norm_offset) if cfg.norm == "rms" else \
            L.layer_norm(x, self.params["final_norm"],
                         self.params.get("final_norm_b"), cfg.norm_eps)
        logits = x @ (self.params["embed"].T if cfg.tie_embeddings
                      else self.params["lm_head"])
        return jnp.argmax(logits[:, -1], axis=-1), new_kv

    def _decode_batch(self, seq_ids, last_tokens):
        gathered = [self.kv.gather(seq_ids, li)
                    for li in range(self.cfg.n_layers)]
        pos = jnp.asarray([self.kv.lengths[s] for s in seq_ids], jnp.int32)
        tokens = jnp.asarray(last_tokens, jnp.int32)
        next_tokens, new_kv = self._token_fn(tokens, pos, gathered)
        return np.asarray(next_tokens), [
            (np.asarray(k), np.asarray(v)) for k, v in new_kv]

    def stats(self) -> Dict[str, Any]:
        return {**self.metrics, **self.kv.memory_stats()}
