"""Continuous-batching serving engine — thin facade over the
Scheduler/Executor split.

Control flow (admission, chunked-prefill budgeting, preemption, COW,
page tables) is pure host Python in ``scheduler.Scheduler``; data flow
is ONE jitted ``unified_step`` per shape bucket in
``executor.Executor`` — the paper's §5.2 separation, at serving
granularity, with the §5.3 caching allocator underneath
(``kv_cache.PagedKVCache``).

Loop per step:
  1. the scheduler admits waiting requests while pages remain, then
     plans a padded token batch: one decode token per steady-state
     sequence FIRST (liveliness), prefill chunks (≤ ``chunk_size``
     tokens, env ``REPRO_PREFILL_CHUNK``) filling the rest of the budget,
  2. the executor scatters the batch's K/V into pages, attends, and
     samples — one device program, donated KV page arrays,
  3. the scheduler commits: cursors advance, finished sequences release
     pages refcount-immediately (§5.5) for the very next admission.

The pre-refactor monolith survives as ``legacy.LegacyServingEngine``
(the benchmark baseline); the dense-cache ``launch.make_serve_step``
path remains the pod-scale pjit twin.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp

from ..models import lm as LM
from .executor import Executor
from .kv_cache import PagedKVCache
from .scheduler import Request, Scheduler

__all__ = ["ServingEngine", "Request"]


class ServingEngine:
    """Batched serving for pattern-homogeneous attention LMs (the paged
    path supports 'attn' mixers; hybrid archs serve via the dense-cache
    pjit path)."""

    def __init__(self, cfg: LM.LMConfig, params, *, page_size: int = 16,
                 num_pages: int = 512, max_batch: int = 8,
                 greedy: bool = True,
                 chunk_size: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 max_pages_per_seq: Optional[int] = None):
        for spec in cfg.pattern:
            if spec.mixer not in ("attn",):
                raise ValueError(
                    "paged engine serves full-attention models; use the "
                    "dense-cache pjit path for hybrid/ssm archs")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.greedy = greedy
        self.kv = PagedKVCache(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, page_size=page_size, num_pages=num_pages,
            dtype=jnp.float32 if cfg.param_dtype == jnp.float32
            else jnp.bfloat16)
        self.scheduler = Scheduler(
            self.kv, max_batch=max_batch, chunk_size=chunk_size,
            token_budget=token_budget,
            max_pages_per_seq=max_pages_per_seq)
        # size the device table mirror at the pages bucket cap up front:
        # the delta path then never pays a width-growth rebuild
        self.kv.mirror_width_hint = self.scheduler.p_buckets()[-1]
        self.executor = Executor(cfg, params)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: Sequence[int],
               max_new_tokens: int = 16) -> int:
        """Queue a request; returns its request id.  Admission happens
        lazily at the next step, when pages are available."""
        return self.scheduler.submit(prompt, max_new_tokens)

    def _step(self) -> Optional[List[Request]]:
        """One unified continuous-batching step (admission + plan +
        execute + commit).  None = nothing runnable."""
        plan = self.scheduler.plan()
        if plan is None:
            return None
        next_tokens = self.executor.execute(plan, self.kv)
        return self.scheduler.commit(plan, next_tokens)

    def step(self) -> List[Request]:
        """Run one continuous-batching step; returns the requests that
        finished this step (empty when nothing is runnable)."""
        return self._step() or []

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Step until every submitted request finishes (or nothing is
        runnable / ``max_steps`` elapse); returns finished requests in
        completion order."""
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.scheduler.waiting and not self.scheduler.running:
                break
            done = self._step()
            if done is None:
                # nothing runnable: every waiting request is blocked on
                # pages even with the pool otherwise idle — bail like the
                # legacy engine rather than spin
                break
            finished.extend(done)
        return finished

    # -- introspection ------------------------------------------------------
    @property
    def waiting(self) -> List[Request]:
        return self.scheduler.waiting

    @property
    def running(self) -> Dict[int, Request]:
        return self.scheduler.running

    @property
    def metrics(self) -> Dict[str, Any]:
        """Counter snapshot: scheduler counters (``steps``,
        ``prefill_chunks``, ``preemptions``, ``zero_decode_steps``, ...)
        plus ``bucket_compiles`` (jitted ``unified_step`` variants — must
        stay ≤ :attr:`bucket_count`), ``page_hwm`` (live-page high-water
        mark) and ``table_upload_rows`` (host→device block-table rows
        flushed by the delta mirror — O(changed rows), the CI bound)."""
        m = dict(self.scheduler.metrics)
        m["bucket_compiles"] = self.executor.compile_count
        m["page_hwm"] = self.kv.pool.stats.page_hwm
        m["table_upload_rows"] = self.kv.upload_rows_total
        m["table_full_rebuilds"] = self.kv.upload_full_rebuilds
        return m

    @property
    def bucket_count(self) -> int:
        return self.scheduler.bucket_count

    def stats(self) -> Dict[str, Any]:
        """:attr:`metrics` merged with the page-pool memory stats
        (pages used/free, prefix hit rate, COW copies, ...)."""
        return {**self.metrics, **self.kv.memory_stats()}
