"""Continuous-batching serving engine — thin facade over the
Scheduler/Executor split.

Control flow (admission, chunked-prefill budgeting, preemption, COW,
page tables) is pure host Python in ``scheduler.Scheduler``; data flow
is ONE jitted ``unified_step`` per shape bucket in
``executor.Executor`` — the paper's §5.2 separation, at serving
granularity, with the §5.3 caching allocator underneath
(``kv_cache.PagedKVCache``).

Loop per step:
  1. the scheduler expires deadlines and admits waiting requests while
     pages remain, then plans a padded token batch: one decode token
     per steady-state sequence FIRST (liveliness), prefill chunks
     (≤ ``chunk_size`` tokens, env ``REPRO_PREFILL_CHUNK``) filling the
     rest of the budget,
  2. the executor scatters the batch's K/V into pages, attends, and
     SAMPLES IN-JIT (greedy / temperature / top-k / top-p, per-request
     params as operands, position-keyed PRNG — logits never visit the
     host) — one device program, donated KV page arrays — and flags
     any slot whose logits went non-finite,
  3. the scheduler commits: cursors advance, finished sequences release
     pages refcount-immediately (§5.5) for the very next admission.
     With ``spec_k > 0`` a proposer (default ``spec.NgramProposer``)
     widens decode spans with draft tokens verified in the same step;
     commit keeps the longest agreeing prefix + one correction token
     and rewinds KV past the first rejection — bitwise-identical
     output to non-speculative decoding at any temperature, tracked by
     ``metrics["spec_acceptance_rate"]``.

Fault tolerance wraps the loop (the robustness half of "serve heavy
traffic from millions of users"): a flagged or crashed or corrupted
sequence is QUARANTINED — state FAILED, pages reclaimed+scrubbed via
``kv.recover()``, device tables force-rebuilt — and the engine keeps
serving everyone else.  The invariant watchdog (``watchdog.Watchdog``)
audits refcount conservation, table coherence, and per-sequence
progress every ``watchdog_interval`` steps; the deterministic fault
harness (``faults.FaultInjector``, env ``REPRO_FAULTS``) exists to
prove all of this under ``make chaos``.

The pre-refactor monolith survives as ``legacy.LegacyServingEngine``
(the benchmark baseline); the dense-cache ``launch.make_serve_step``
path remains the pod-scale pjit twin.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp

from ..models import lm as LM
from .errors import DeadlineExceeded, RequestFailed
from .executor import Executor
from .faults import FaultInjector
from .kv_cache import PagedKVCache
from .sampling import SamplingParams
from .scheduler import Request, RequestState, Scheduler
from .spec import NgramProposer, Proposer
from .watchdog import Watchdog

__all__ = ["ServingEngine", "Request", "RequestState"]


class ServingEngine:
    """Batched serving for pattern-homogeneous attention LMs (the paged
    path supports 'attn' mixers; hybrid archs serve via the dense-cache
    pjit path)."""

    def __init__(self, cfg: LM.LMConfig, params, *, page_size: int = 16,
                 num_pages: int = 512, max_batch: int = 8,
                 greedy: bool = True,
                 sampling: Optional[SamplingParams] = None,
                 spec_k: int = 0,
                 proposer: Optional[Proposer] = None,
                 chunk_size: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 max_pages_per_seq: Optional[int] = None,
                 max_queue_depth: Optional[int] = None,
                 admit_hwm_frac: float = 1.0,
                 aging_steps: int = 32,
                 watchdog_interval: int = 8,
                 stall_steps: int = 64,
                 max_idle_steps: int = 64,
                 exec_failure_limit: int = 3,
                 faults: Optional[FaultInjector] = None,
                 mesh=None, n_replicas: int = 1,
                 kv_dtype: Optional[str] = None,
                 clock: Callable[[], float] = time.perf_counter):
        for spec in cfg.pattern:
            if spec.mixer not in ("attn",):
                raise ValueError(
                    "paged engine serves full-attention models; use the "
                    "dense-cache pjit path for hybrid/ssm archs")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        # sharded serving: a (data, model) mesh replicates the slot
        # space over `data` (S slots -> n_replicas*S slots; `num_pages`
        # and `token_budget` stay PER replica) and tensor-parallels the
        # layer compute over `model`.  `n_replicas` alone (no mesh)
        # runs the same replicated plan/step layout on one device —
        # the parity testing seam.  The control plane below is mesh-
        # oblivious either way.
        if mesh is not None:
            n_replicas = dict(mesh.shape).get("data", 1)
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.mesh = mesh
        self.n_replicas = n_replicas
        # the sampling contract: an explicit ``sampling`` wins;
        # otherwise ``greedy`` picks argmax (temperature 0) or plain
        # temperature-1.0 sampling — ``greedy=False`` actually samples
        if sampling is None:
            sampling = SamplingParams() if greedy \
                else SamplingParams(temperature=1.0)
        self.sampling = sampling.validate()
        self.greedy = self.sampling.greedy
        if spec_k > 0 and proposer is None:
            proposer = NgramProposer()
        self.spec_k = spec_k
        self.proposer = proposer
        # kv_dtype: None keeps the param-dtype pool (fp32/bf16 — the
        # PR 9 default path, bit-identical); "int8"/"fp8_e4m3" store
        # quantized codes + per-(token, head) fp32 scales and shrink
        # KV bytes ~4×/~3.5× — concurrency is KV-byte-bound, so the
        # same byte budget admits that many more sequences
        self.kv = PagedKVCache(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, page_size=page_size,
            num_pages=num_pages * n_replicas, n_replicas=n_replicas,
            dtype=jnp.float32 if cfg.param_dtype == jnp.float32
            else jnp.bfloat16, kv_dtype=kv_dtype)
        kv_sharding = scale_sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            from ..distributed.sharding import (serving_kv_scale_spec,
                                                serving_kv_spec,
                                                serving_mirror_spec)
            kv_sharding = NamedSharding(mesh, serving_kv_spec(
                cfg.n_kv_heads, mesh, pages_per_replica=num_pages))
            if self.kv.quant_mode is not None:
                scale_sharding = NamedSharding(mesh, serving_kv_scale_spec(
                    cfg.n_kv_heads, mesh, pages_per_replica=num_pages))
            self.kv.place_on_mesh(
                kv_sharding, NamedSharding(mesh, serving_mirror_spec(mesh)),
                scale_sharding)
        self.scheduler = Scheduler(
            self.kv, max_batch=max_batch, chunk_size=chunk_size,
            token_budget=token_budget,
            max_pages_per_seq=max_pages_per_seq,
            max_queue_depth=max_queue_depth,
            admit_hwm_frac=admit_hwm_frac, aging_steps=aging_steps,
            sampling=self.sampling, spec_k=spec_k, proposer=proposer,
            n_replicas=n_replicas, clock=clock)
        # size the device table mirror at the pages bucket cap up front:
        # the delta path then never pays a width-growth rebuild
        self.kv.mirror_width_hint = self.scheduler.p_buckets()[-1]
        self.executor = Executor(cfg, params, mesh=mesh,
                                 n_replicas=n_replicas,
                                 kv_sharding=kv_sharding,
                                 kv_quant=self.kv.quant_mode,
                                 scale_sharding=scale_sharding)
        self.watchdog = Watchdog(interval=watchdog_interval,
                                 stall_steps=stall_steps)
        # fault injection: ctor arg, else env (None = zero overhead)
        self.faults = faults if faults is not None \
            else FaultInjector.from_env()
        self.max_idle_steps = max_idle_steps
        self.exec_failure_limit = exec_failure_limit
        self._step_no = 0
        self._exec_fail_streak = 0
        self._counters = {"watchdog_trips": 0, "executor_failures": 0,
                          "steps_exhausted": 0}

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               *, sampling: Optional[SamplingParams] = None,
               ttft_deadline_ms: Optional[float] = None,
               timeout_ms: Optional[float] = None,
               priority: int = 0, tenant: str = "default") -> int:
        """Queue a request; returns its request id.  Admission happens
        lazily at the next step, when pages are available.  Raises
        :class:`~.errors.AdmissionRejected` (over-cap prompt, queue at
        ``max_queue_depth``, or page-watermark backpressure) — the
        typed signal for a front door to shed load.  ``sampling``
        overrides the engine-wide :class:`SamplingParams` for this
        request only (per-request params are jit operands — no
        recompile).  ``ttft_deadline_ms`` / ``timeout_ms`` arm
        per-request deadlines checked every step; the TTFT deadline is
        also an admission *ordering* key (earliest-deadline-first
        within a priority tier).  ``priority`` (higher admits first)
        and ``tenant`` (fair-share accounting bucket) feed the
        SLO-aware admission rank — all-default submissions keep plain
        FIFO."""
        return self.scheduler.submit(
            prompt, max_new_tokens, sampling=sampling,
            ttft_deadline_ms=ttft_deadline_ms, timeout_ms=timeout_ms,
            priority=priority, tenant=tenant)

    def cancel(self, req_id: int) -> bool:
        """Cancel a request at any point in its lifecycle — queued,
        mid-prefill, or mid-decode.  Its pages are released refcount-
        safely (COW/prefix sharers keep theirs).  Returns False for an
        unknown or already-terminal id."""
        return self.scheduler.cancel(req_id)

    def result(self, req_id: int) -> Optional[Request]:
        """Terminal-state accessor: the finished/cancelled ``Request``
        (with any partial ``out_tokens``), ``None`` while still in
        flight, or a typed raise — :class:`~.errors.DeadlineExceeded`
        for TIMED_OUT, :class:`~.errors.RequestFailed` for FAILED."""
        req = self.scheduler.done.get(req_id)
        if req is None:
            return None
        if req.state is RequestState.TIMED_OUT:
            raise DeadlineExceeded(f"request {req_id}: {req.error}")
        if req.state is RequestState.FAILED:
            raise RequestFailed(f"request {req_id}: {req.error}",
                                req_id=req_id)
        return req

    def drain(self) -> List[Request]:
        """Cancel every queued and running request (pages freed),
        returning them with whatever partial ``out_tokens`` they had —
        the CLI's Ctrl-C path."""
        reqs = list(self.scheduler.running.values()) \
            + list(self.scheduler.waiting)
        for req in reqs:
            self.scheduler.cancel(req.req_id)
        return reqs

    # -- the fault-tolerant step loop ---------------------------------------
    def _quarantine(self, req_id: int, reason: str) -> None:
        """FAIL one request and repair shared state around it: pages
        reclaimed + scrubbed via pool reconciliation, device block
        tables force-rebuilt.  The step loop never stops."""
        self.scheduler.fail(req_id, reason)
        self._counters["watchdog_trips"] += 1
        self.kv.recover()

    def _run_watchdog(self) -> None:
        violations = self.watchdog.check(self.scheduler, self.kv)
        if not violations:
            return
        for v in violations:
            self._counters["watchdog_trips"] += 1
            if v.seq_id is not None:
                self.scheduler.fail(v.seq_id, f"watchdog[{v.kind}]: "
                                    f"{v.detail}")
        self.kv.recover()

    def _step(self) -> Optional[List[Request]]:
        """One unified continuous-batching step (admission + plan +
        execute + commit), with the executor boundary treated as a
        fault line.  None = nothing runnable."""
        self._step_no += 1
        if self.faults is not None:
            self.faults.before_plan(self._step_no, self.scheduler,
                                    self.kv)
        plan = self.scheduler.plan()
        if plan is None:
            return None
        try:
            if self.faults is not None:
                self.faults.before_execute(self._step_no, plan,
                                           self.scheduler, self.kv)
            next_tokens, bad = self.executor.execute(plan, self.kv)
        except RequestFailed as e:
            # attributed executor fault: fail the culprit, keep serving
            self._counters["executor_failures"] += 1
            if e.req_id is not None and \
                    self.scheduler._lookup(e.req_id) is not None:
                self._quarantine(e.req_id, f"executor fault: {e}")
            else:
                self._unattributed_failure(plan, e)
            return []
        except Exception as e:          # noqa: BLE001 — fault line
            self._counters["executor_failures"] += 1
            self._unattributed_failure(plan, e)
            return []
        self._exec_fail_streak = 0
        if bad.any():
            # finite-logits barrier: quarantine flagged slots BEFORE
            # commit so a poisoned token never enters a history
            for s in plan.spans:
                if s.sample and s.req.slot >= 0 and bad[s.req.slot]:
                    self._quarantine(s.req.req_id,
                                     "non-finite logits (executor "
                                     "fault barrier)")
        done = self.scheduler.commit(plan, next_tokens)
        if self.watchdog.due(self._step_no):
            self._run_watchdog()
        return done

    def _unattributed_failure(self, plan, exc: Exception) -> None:
        """Executor exception with no culprit id: retry the step (the
        plan rebuilds from unchanged cursors); after
        ``exec_failure_limit`` consecutive failures quarantine the
        whole planned batch — bounded blast radius, never a wedge."""
        self._exec_fail_streak += 1
        if self._exec_fail_streak < self.exec_failure_limit:
            return
        for rid in sorted({s.req.req_id for s in plan.spans}):
            if self.scheduler._lookup(rid) is not None:
                self._quarantine(
                    rid, f"executor failed x{self._exec_fail_streak}: "
                         f"{exc!r}")
        self._exec_fail_streak = 0

    def step(self) -> List[Request]:
        """Run one continuous-batching step; returns the requests that
        finished this step (empty when nothing is runnable)."""
        return self._step() or []

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Step until every submitted request reaches a terminal state
        (or ``max_steps`` elapse); returns FINISHED requests in
        completion order.  Cancelled/timed-out/failed requests are in
        :attr:`aborted` (and via :meth:`result`).  Hitting the step cap
        retires everything still live as TIMED_OUT and bumps
        ``metrics["steps_exhausted"]`` — never a silent partial return.
        An idle engine (every waiting request blocked on pages) spins at
        most ``max_idle_steps`` before giving up."""
        finished: List[Request] = []
        idle = 0
        for _ in range(max_steps):
            if not self.scheduler.waiting and not self.scheduler.running:
                return finished
            done = self._step()
            if done is None:
                # nothing runnable: spin briefly (deadlines may expire,
                # fault holds may release), then bail rather than hang
                idle += 1
                if idle > self.max_idle_steps:
                    return finished
            else:
                idle = 0
                finished.extend(done)
        if self.scheduler.waiting or self.scheduler.running:
            self._counters["steps_exhausted"] += 1
            self.scheduler.timeout_all(
                f"engine step cap max_steps={max_steps} exhausted")
        return finished

    # -- introspection ------------------------------------------------------
    @property
    def waiting(self) -> List[Request]:
        return self.scheduler.waiting

    @property
    def running(self) -> Dict[int, Request]:
        return self.scheduler.running

    @property
    def aborted(self) -> List[Request]:
        """Requests retired CANCELLED / TIMED_OUT / FAILED (each holds
        its partial ``out_tokens`` and an ``error`` string)."""
        return self.scheduler.aborted

    @property
    def metrics(self) -> Dict[str, Any]:
        """Counter snapshot.  Scheduler counters: ``steps``,
        ``prefills``, ``prefill_chunks``, ``decoded_tokens``,
        ``preemptions``, ``zero_decode_steps``, ``cancellations``,
        ``timeouts``, ``failed_requests``, ``aged_admissions``,
        ``rejected_admissions``, ``rejected_submits``,
        ``ttft_deadline_misses`` (requests whose first-token SLO
        lapsed — the front door's gate signal); speculative
        decoding: ``spec_steps``, ``proposed_tokens``,
        ``accepted_tokens`` and the derived ``spec_acceptance_rate``
        (accepted / proposed — the first-class signal for how much
        speculative work paid off); fault tolerance:
        ``watchdog_trips``, ``executor_failures``, ``steps_exhausted``;
        executor/KV: ``bucket_compiles`` (jitted ``unified_step``
        variants — must stay ≤ :attr:`bucket_count`), ``page_hwm``
        (live-page high-water mark), ``page_hwm_per_replica`` (same,
        per data replica), ``kv_bytes`` (total resident page-pool
        bytes — codes plus scale overhead for a quantized pool),
        ``kv_dtype`` (the pool storage: "float32"/"bfloat16"/"int8"/
        "fp8_e4m3"), ``kv_bytes_per_seq`` (resident bytes of one
        max-length sequence: page bytes × ``max_pages_per_seq`` — the
        capacity-planning number that shows the quantization win),
        ``n_replicas``, ``table_upload_rows`` (host→device
        block-table rows flushed by the delta mirror), and
        ``table_full_rebuilds``."""
        m = dict(self.scheduler.metrics)
        m.update(self._counters)
        m["bucket_compiles"] = self.executor.compile_count
        m["page_hwm"] = self.kv.pool.stats.page_hwm
        m["page_hwm_per_replica"] = list(self.kv.pool.page_hwm_per_replica)
        ms = self.kv.memory_stats()
        m["kv_bytes"] = ms["kv_bytes"]
        m["kv_dtype"] = ms["kv_dtype"]
        m["kv_bytes_per_seq"] = (ms["page_bytes"]
                                 * self.scheduler.max_pages_per_seq)
        m["n_replicas"] = self.n_replicas
        m["table_upload_rows"] = self.kv.upload_rows_total
        m["table_full_rebuilds"] = self.kv.upload_full_rebuilds
        m["spec_acceptance_rate"] = (
            m["accepted_tokens"] / m["proposed_tokens"]
            if m["proposed_tokens"] else 0.0)
        return m

    @property
    def bucket_count(self) -> int:
        return self.scheduler.bucket_count

    def stats(self) -> Dict[str, Any]:
        """:attr:`metrics` merged with the page-pool memory stats
        (pages used/free, prefix hit rate, COW copies, ...)."""
        return {**self.metrics, **self.kv.memory_stats()}
