"""KV quantization math — shared by the page pool (host scatters) and
the executor (in-jit quantize-on-scatter).

The paged KV cache stores int8 / fp8_e4m3 CODES in the page arrays and
fp32 SCALES in parallel ``(num_pages, page_size, n_kv_heads)`` arrays
beside them (the "scales-layout contract", documented in
``docs/kernels.md``).  Scale granularity is per (token, kv-head): one
absmax scale per written K/V vector.  Finer than per-page on purpose —
a decode append that raises a page's absmax would otherwise force a
dequant/requant rewrite of every code already in that page, turning the
O(1) decode scatter into an O(page) read-modify-write.  Per-vector
scales keep every write independent, so the executor's flat
``write_idx`` scatter works unchanged: codes land in the pool, scales
land at the same flat (page*page_size + offset, head) slot.

Scales are stored page-shaped so every page-granular pool operation
(COW copy, truncate, quarantine scrub, recovery) carries them with the
page by construction.

Scheme: symmetric absmax.  ``scale = max|x| / QMAX`` over the head_dim
axis, ``code = round(x / scale)`` clipped to ±127 (int8) or cast to
fp8_e4m3 (QMAX 448, the format's largest finite value);
``dequant = code * scale``.  An all-zero vector stores scale 0 and
dequantizes to exact zeros — unwritten pool slots therefore read as
zeros, same as the fp32 pool.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

# largest code magnitude per format: int8 symmetric (no -128, so the
# scheme stays symmetric under negation), fp8 e4m3's largest finite
QMAX = {"int8": 127.0, "fp8_e4m3": 448.0}

_ALIASES = {"fp8": "fp8_e4m3", "float8": "fp8_e4m3",
            "float8_e4m3fn": "fp8_e4m3"}


def canonical(kv_dtype: Optional[str]) -> Optional[str]:
    """Normalize a ``kv_dtype`` knob to a quantization mode: ``None``
    for the unquantized pool (``None``/"fp32"/"float32"), else
    "int8" / "fp8_e4m3" (aliases "fp8", "float8" accepted)."""
    if kv_dtype is None or kv_dtype in ("fp32", "float32", "bf16",
                                        "bfloat16"):
        return None
    mode = _ALIASES.get(kv_dtype, kv_dtype)
    if mode not in QMAX:
        raise ValueError(
            f"unknown kv_dtype {kv_dtype!r}; expected one of "
            f"fp32, int8, fp8_e4m3")
    return mode


def storage_dtype(mode: str):
    """The pool array dtype for a quantization mode."""
    if mode == "int8":
        return jnp.int8
    dt = getattr(jnp, "float8_e4m3fn", None)
    if dt is None:                      # pragma: no cover - old jax
        raise ValueError("kv_dtype=fp8_e4m3 needs a jax with "
                         "jnp.float8_e4m3fn; use int8 or fp32")
    return dt


def quantize(x: jnp.ndarray, mode: str
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize K/V vectors along the trailing head_dim axis.

    ``x``: (..., head_dim) float.  Returns ``(codes, scales)`` with
    codes (..., head_dim) in the storage dtype and scales (...,) fp32.
    Traceable — the executor runs it inside the jitted unified step."""
    x = x.astype(jnp.float32)
    qmax = QMAX[mode]
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = amax / qmax
    # all-zero vectors: divide by 1, store scale 0 -> exact zeros back
    y = x / jnp.where(scale > 0, scale, 1.0)[..., None]
    if mode == "int8":
        codes = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        codes = y.astype(storage_dtype(mode))
    return codes, scale


def dequantize(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize`: (..., hd) codes × (...,) scales ->
    (..., hd) fp32."""
    return codes.astype(jnp.float32) * scales[..., None]
