"""Serving stack: paged KV allocator (§5.3), pure-Python scheduler
(control plane) and jitted executor (data plane) behind the
``ServingEngine`` facade."""

from .engine import ServingEngine
from .executor import Executor
from .kv_cache import PagedKVCache, PagePool
from .legacy import LegacyServingEngine
from .scheduler import Request, Scheduler, StepPlan

__all__ = ["ServingEngine", "LegacyServingEngine", "PagedKVCache",
           "PagePool", "Scheduler", "Executor", "Request", "StepPlan"]
