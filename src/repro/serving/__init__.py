"""Serving stack: paged KV allocator (§5.3), pure-Python scheduler
(control plane) and jitted executor (data plane) behind the
``ServingEngine`` facade — plus in-jit ``sampling`` (greedy /
temperature / top-k / top-p), speculative-decoding proposers
(``spec``), and the request-lifecycle fault-tolerance layer: typed
``errors``, the invariant ``watchdog``, and the deterministic
``faults`` injection harness.  The asyncio streaming front door
(``frontend``) bridges per-token streams, mid-stream cancellation and
watermark backpressure onto the engine loop."""

from . import errors
from .engine import ServingEngine
from .errors import (AdmissionRejected, BackpressureRejected,
                     BucketOverflow, DeadlineExceeded, FaultInjected,
                     PoolExhausted, RequestFailed, ServingError)
from .frontend import AsyncFrontend, StreamEvent
from .executor import Executor
from .faults import FaultInjector, FaultSpec
from .kv_cache import PagedKVCache, PagePool
from .legacy import LegacyServingEngine
from .sampling import SamplingParams
from .scheduler import Request, RequestState, Scheduler, StepPlan
from .spec import (DraftModelProposer, FixedProposer, NgramProposer,
                   Proposer)
from .watchdog import Violation, Watchdog

__all__ = ["ServingEngine", "LegacyServingEngine", "PagedKVCache",
           "PagePool", "Scheduler", "Executor", "Request", "StepPlan",
           "RequestState", "errors", "ServingError", "AdmissionRejected",
           "BackpressureRejected", "AsyncFrontend", "StreamEvent",
           "PoolExhausted", "BucketOverflow", "DeadlineExceeded",
           "RequestFailed", "FaultInjected", "FaultInjector",
           "FaultSpec", "Watchdog", "Violation", "SamplingParams",
           "Proposer", "NgramProposer", "DraftModelProposer",
           "FixedProposer"]
