"""Deterministic, seedable fault injection for the serving stack.

The TorchBench lesson applied to failure modes: narrow benchmarks (and
happy-path tests) miss what a broad, systematic sweep finds.  This
harness injects the four production failure classes at exact engine
steps so ``make chaos`` can require the engine to degrade gracefully —
fail ONE request, never the step loop — and recover:

  * ``pool_exhaustion``  — steal free pages for ``hold_steps`` steps
    (admission backpressure + preemption must absorb it, and every
    request must still finish once the pages return);
  * ``nan_logits``       — write NaN into a victim sequence's private
    KV page, so its next logits row is non-finite (the executor's
    finite-logits barrier must quarantine exactly that request);
  * ``executor_crash``   — raise :class:`~.errors.FaultInjected` at the
    executor boundary with a culprit req id (the engine's exception
    path must fail the culprit and keep stepping);
  * ``table_corruption`` — overwrite a victim's block-table tail with
    an out-of-range page id (the invariant watchdog must catch it and
    force-rebuild the device tables).

Gating: pass a :class:`FaultInjector` to ``ServingEngine(faults=...)``
or set ``REPRO_FAULTS`` (see :meth:`FaultInjector.from_env`).  When
neither is set the engine holds ``faults is None`` and the hot path
pays a single ``is None`` test per step — zero overhead, nothing to
compile out.

Spec string grammar (``;``-separated, seed via ``REPRO_FAULT_SEED``)::

    kind@step[:key=val[,key=val...]]
    e.g.  REPRO_FAULTS="nan_logits@6;pool_exhaustion@4:pages=16,hold=6"
"""

from __future__ import annotations

import os
import random
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp

from .errors import FaultInjected

__all__ = ["FaultSpec", "FaultInjector"]

KINDS = ("pool_exhaustion", "nan_logits", "executor_crash",
         "table_corruption")


@dataclass
class FaultSpec:
    """One scheduled fault.  ``step`` is the engine step number at (or
    after) which it fires; ``seq`` pins the victim req id (``None`` =
    seeded pick among eligible running requests)."""
    kind: str
    step: int
    seq: Optional[int] = None
    pages: int = 0               # pool_exhaustion: pages to steal
                                 # (0 = every free page)
    hold_steps: int = 4          # pool_exhaustion: steps held
    fired: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class FaultInjector:
    """Injects :class:`FaultSpec` s into a running engine, deterministic
    under (specs, seed).  ``injected`` counts faults actually fired —
    the chaos gate compares it against ``watchdog_trips``."""

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.rng = random.Random(seed)
        self.injected = 0
        # (release_at_step, [page ids]) for pool_exhaustion holds
        self._holds: List[Tuple[int, List[int]]] = []

    # -- construction -----------------------------------------------------
    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultInjector":
        """Build from the spec-string grammar (module docstring)."""
        specs = []
        for part in filter(None, (p.strip() for p in text.split(";"))):
            head, _, opts = part.partition(":")
            kind, _, step = head.partition("@")
            kw = {}
            for kv in filter(None, opts.split(",")):
                k, _, v = kv.partition("=")
                kw[{"hold": "hold_steps"}.get(k, k)] = int(v)
            specs.append(FaultSpec(kind.strip(), int(step or 0), **kw))
        return cls(specs, seed=seed)

    @classmethod
    def from_env(cls) -> Optional["FaultInjector"]:
        """``REPRO_FAULTS`` spec string (+ ``REPRO_FAULT_SEED``);
        returns None when unset so the engine stays zero-overhead."""
        text = os.environ.get("REPRO_FAULTS", "")
        if not text:
            return None
        return cls.parse(text, seed=int(os.environ.get(
            "REPRO_FAULT_SEED", "0")))

    # -- helpers ----------------------------------------------------------
    def _victim(self, spec: FaultSpec, candidates: List[int]
                ) -> Optional[int]:
        if spec.seq is not None:
            return spec.seq if spec.seq in candidates else None
        if not candidates:
            return None
        return self.rng.choice(sorted(candidates))

    # -- engine hooks -----------------------------------------------------
    def before_plan(self, step_no: int, scheduler, kv) -> None:
        """Fire pool-exhaustion / table-corruption faults and release
        expired page holds.  Called by the engine before ``plan()``."""
        for at, pages in list(self._holds):
            if step_no >= at:
                for p in pages:
                    kv.external_refs[p] -= 1
                    if kv.external_refs[p] <= 0:
                        del kv.external_refs[p]
                    kv.pool.release(p)
                self._holds.remove((at, pages))
        for spec in self.specs:
            if spec.fired or step_no < spec.step:
                continue
            if spec.kind == "pool_exhaustion":
                want = spec.pages or kv.pool.num_free
                stolen = []
                for _ in range(min(want, kv.pool.num_free)):
                    p = kv.pool.alloc()
                    if p is None:
                        break
                    stolen.append(p)
                    kv.external_refs[p] = kv.external_refs.get(p, 0) + 1
                self._holds.append((step_no + spec.hold_steps, stolen))
                spec.fired = True
                self.injected += 1
            elif spec.kind == "table_corruption":
                sid = self._victim(spec, [
                    s for s in scheduler.running if kv.tables.get(s)])
                if sid is None:
                    continue
                kv.tables[sid][-1] = kv.pool.num_pages + 3
                kv._bump(sid)           # upload the corrupt row, as a
                spec.fired = True       # real table bug would
                self.injected += 1

    def before_execute(self, step_no: int, plan, scheduler, kv) -> None:
        """Fire NaN-logits / executor-crash faults.  Called between
        ``plan()`` and ``executor.execute`` (may raise)."""
        for spec in self.specs:
            if spec.fired or step_no < spec.step:
                continue
            if spec.kind == "executor_crash":
                sid = self._victim(
                    spec, [s.req.req_id for s in plan.spans])
                if sid is None:
                    continue
                spec.fired = True
                self.injected += 1
                raise FaultInjected(
                    f"injected executor crash at step {step_no}",
                    req_id=sid)
            if spec.kind == "nan_logits":
                sampled = [s.req.req_id for s in plan.spans if s.sample]
                sid = self._victim(spec, sampled)
                if sid is None or kv.lengths.get(sid, 0) < 1:
                    continue
                pos = kv.lengths[sid] - 1
                page = kv.tables[sid][pos // kv.page_size]
                if kv.pool.refs.get(page, 0) != 1:
                    continue            # only poison PRIVATE pages
                kv.k[0] = kv.k[0].at[page, pos % kv.page_size].set(
                    jnp.nan)
                spec.fired = True
                self.injected += 1
