"""Async streaming front door over :class:`~.engine.ServingEngine`.

The paper's thesis — an imperative, plain-Python control plane
coexisting with hardware-rate execution — extended to the live-traffic
boundary: everything here is single-threaded asyncio host Python.  The
engine's jitted ``unified_step`` stays the data plane; the front door
only *routes*:

* **per-token streaming** — :meth:`AsyncFrontend.stream` is an async
  generator yielding one :class:`StreamEvent` per committed token and
  exactly ONE terminal event (``finished`` / ``cancelled`` /
  ``timed_out`` / ``failed``).  Tokens are bridged from the engine loop
  by :meth:`AsyncFrontend.pump`, which runs one continuous-batching
  step and fans newly committed tokens into per-stream queues.
* **mid-stream cancellation** — a consumer that stops iterating
  (client disconnect, ``aclose()``, task cancellation) triggers the
  generator's ``finally``, which calls ``engine.cancel``: the
  request's KV pages release refcount-immediately, in the same
  scheduler tick, so a dead client never holds pool capacity.
* **SLO admission** — ``priority`` / ``tenant`` / ``ttft_deadline_ms``
  plumb straight into the scheduler's SLO-aware admission rank;
  ``max_stream_tokens`` caps any one request's token budget.
* **watermark backpressure** — when live pages or queue depth cross
  the admission watermark for a request's priority tier, ``stream``
  raises :class:`~.errors.BackpressureRejected` *before* submitting
  (the request never holds resources).  The error carries
  ``retry_after_s``; the HTTP layer (``launch/server.py``) maps it to
  ``503`` + ``Retry-After``.  Low-priority traffic sheds at
  ``low_priority_hwm_frac`` while high-priority requests keep
  admitting up to ``hwm_frac`` — the headroom that lets TTFT SLOs
  survive saturation.

Determinism is a design constraint, not an accident: the frontend
never spawns threads and never reads wall time.  Tests and the traffic
simulator drive :meth:`pump` manually against a fake engine clock;
:meth:`run` is the thin convenience loop a real server uses.

Zero-drop contract: every token the engine commits for a streamed
request is enqueued to its stream before (or in the same pump as) the
terminal event — ``metrics["tokens_dropped"]`` counts violations and
must stay 0 (CI-gated by ``benchmarks/bench_traffic.py``).
"""

from __future__ import annotations

import asyncio
from dataclasses import dataclass
from typing import AsyncIterator, Dict, Optional, Sequence

from .engine import ServingEngine
from .errors import BackpressureRejected
from .sampling import SamplingParams
from .scheduler import TERMINAL, Request, RequestState

__all__ = ["AsyncFrontend", "StreamEvent"]


@dataclass
class StreamEvent:
    """One event on a token stream.  ``kind`` is ``"token"`` for a
    committed token (with ``token``/``index`` set) or a terminal state
    value — ``"finished"``, ``"cancelled"``, ``"timed_out"``,
    ``"failed"`` — with ``error`` carrying the retirement reason.  A
    stream yields zero or more token events and exactly one terminal
    event."""
    kind: str
    req_id: int
    token: Optional[int] = None
    index: int = -1
    error: Optional[str] = None

    @property
    def terminal(self) -> bool:
        """True for the stream's single end-of-stream event."""
        return self.kind != "token"


@dataclass
class _Stream:
    """Host-side state for one open stream: the consumer's event queue
    plus the count of tokens already enqueued (``delivered``)."""
    queue: "asyncio.Queue[StreamEvent]"
    delivered: int = 0
    closed: bool = False          # terminal event enqueued


class AsyncFrontend:
    """Asyncio streaming facade over a :class:`ServingEngine`.

    One frontend owns one engine; all methods must run on one event
    loop (the frontend is deliberately lock-free and thread-free).
    ``hwm_frac`` is the page watermark for priority >=
    ``high_priority_min`` requests; ``low_priority_hwm_frac`` (default:
    ``hwm_frac - 0.15``) sheds lower-priority traffic earlier, keeping
    admission headroom for SLO-critical requests.  ``max_queue_depth``
    bounds the scheduler's waiting queue at the front door (typed
    shed, not an engine error)."""

    def __init__(self, engine: ServingEngine, *,
                 hwm_frac: float = 0.95,
                 low_priority_hwm_frac: Optional[float] = None,
                 high_priority_min: int = 1,
                 max_queue_depth: Optional[int] = None,
                 retry_after_s: float = 0.5,
                 max_stream_tokens: Optional[int] = None,
                 idle_sleep_s: float = 0.002):
        self.engine = engine
        self.hwm_frac = hwm_frac
        self.low_priority_hwm_frac = (
            low_priority_hwm_frac if low_priority_hwm_frac is not None
            else max(0.0, hwm_frac - 0.15))
        self.high_priority_min = high_priority_min
        self.max_queue_depth = max_queue_depth
        self.retry_after_s = retry_after_s
        self.max_stream_tokens = max_stream_tokens
        self.idle_sleep_s = idle_sleep_s
        self._streams: Dict[int, _Stream] = {}
        self._running = False
        self.metrics: Dict[str, int] = {
            "streams_opened": 0, "streams_finished": 0,
            "streams_aborted": 0, "client_cancelled": 0,
            "backpressure_rejections": 0, "tokens_streamed": 0,
            "tokens_dropped": 0,
        }

    # -- admission ----------------------------------------------------------
    def _gate(self, priority: int) -> None:
        """Watermark backpressure: shed BEFORE submit so a rejected
        request never holds pages or queue slots.  Low-priority tiers
        shed earlier than high-priority ones."""
        pool = self.engine.kv.pool
        frac = (self.hwm_frac if priority >= self.high_priority_min
                else self.low_priority_hwm_frac)
        live = pool.num_pages - pool.num_free
        if live >= frac * pool.num_pages:
            self.metrics["backpressure_rejections"] += 1
            raise BackpressureRejected(
                f"{live}/{pool.num_pages} pages live >= {frac:.2f} "
                f"watermark for priority {priority}",
                retry_after_s=self.retry_after_s)
        depth = len(self.engine.scheduler.waiting)
        if self.max_queue_depth is not None and \
                depth >= self.max_queue_depth:
            self.metrics["backpressure_rejections"] += 1
            raise BackpressureRejected(
                f"queue depth {depth} at front-door cap "
                f"{self.max_queue_depth}",
                retry_after_s=self.retry_after_s)

    # -- streaming ----------------------------------------------------------
    async def stream(self, prompt: Sequence[int],
                     max_new_tokens: int = 16, *,
                     priority: int = 0, tenant: str = "default",
                     sampling: Optional[SamplingParams] = None,
                     ttft_deadline_ms: Optional[float] = None,
                     timeout_ms: Optional[float] = None
                     ) -> AsyncIterator[StreamEvent]:
        """Submit a request and stream its tokens as they commit.

        Yields ``token`` events then exactly one terminal event, and
        returns.  Raises :class:`BackpressureRejected` /
        :class:`~.errors.AdmissionRejected` before the first yield if
        the request is shed.  Abandoning the iterator at any point
        cancels the request in the engine and releases its KV pages
        immediately."""
        self._gate(priority)
        if self.max_stream_tokens is not None:
            max_new_tokens = min(max_new_tokens, self.max_stream_tokens)
        rid = self.engine.submit(
            prompt, max_new_tokens, sampling=sampling,
            ttft_deadline_ms=ttft_deadline_ms, timeout_ms=timeout_ms,
            priority=priority, tenant=tenant)
        st = _Stream(queue=asyncio.Queue())
        self._streams[rid] = st
        self.metrics["streams_opened"] += 1
        try:
            while True:
                ev = await st.queue.get()
                yield ev
                if ev.terminal:
                    return
        finally:
            self._finalize(rid)

    def _lookup(self, rid: int) -> Optional[Request]:
        sched = self.engine.scheduler
        req = sched.running.get(rid) or sched.done.get(rid)
        if req is None:
            req = next((r for r in sched.waiting if r.req_id == rid),
                       None)
        return req

    def _finalize(self, rid: int) -> None:
        """Close out a stream.  If the request is still live the
        consumer walked away mid-stream: cancel it so its pages free
        NOW.  Any token committed but never enqueued counts as dropped
        (the zero-drop gate)."""
        st = self._streams.pop(rid, None)
        if st is None:
            return
        req = self._lookup(rid)
        if req is not None and req.state not in TERMINAL:
            self.engine.cancel(rid)
            self.metrics["client_cancelled"] += 1
            req = self.engine.scheduler.done.get(rid)
        if req is not None:
            missed = len(req.out_tokens) - st.delivered
            if missed > 0:
                self.metrics["tokens_dropped"] += missed

    # -- the engine bridge --------------------------------------------------
    def pump(self) -> int:
        """Run ONE engine step and fan newly committed tokens (and any
        terminal transitions) into the open stream queues.  Returns the
        number of events enqueued.  This is the only place the frontend
        touches the engine loop — tests and the traffic simulator call
        it directly for deterministic interleaving; :meth:`run` wraps
        it for real servers."""
        self.engine.step()
        events = 0
        for rid, st in list(self._streams.items()):
            if st.closed:
                continue
            req = self._lookup(rid)
            if req is None:
                continue
            out = req.out_tokens
            while st.delivered < len(out):
                st.queue.put_nowait(StreamEvent(
                    "token", rid, token=out[st.delivered],
                    index=st.delivered))
                st.delivered += 1
                self.metrics["tokens_streamed"] += 1
                events += 1
            if req.state in TERMINAL:
                st.queue.put_nowait(StreamEvent(
                    req.state.value, rid, error=req.error))
                st.closed = True
                events += 1
                if req.state is RequestState.FINISHED:
                    self.metrics["streams_finished"] += 1
                else:
                    self.metrics["streams_aborted"] += 1
        return events

    @property
    def busy(self) -> bool:
        """True while any request is queued/running or any stream still
        has a consumer attached."""
        sched = self.engine.scheduler
        return bool(sched.waiting or sched.running or self._streams)

    async def run(self) -> None:
        """Drive :meth:`pump` until :meth:`close` — the server's
        background engine task.  Steps are synchronous (the jitted step
        blocks the loop; acceptable at repro scale and what keeps the
        frontend deterministic and lock-free); when idle it sleeps
        ``idle_sleep_s`` so the loop stays responsive to new
        submissions."""
        self._running = True
        try:
            while self._running:
                moved = self.pump() if self.busy else 0
                # yield to consumers every pump; back off when idle
                await asyncio.sleep(0 if moved else self.idle_sleep_s)
        finally:
            self._running = False

    def close(self) -> None:
        """Stop :meth:`run` after its current iteration and cancel any
        still-open engine requests (their streams see a terminal
        ``cancelled`` event on the next pump)."""
        self._running = False
        for rid in list(self._streams):
            req = self._lookup(rid)
            if req is not None and req.state not in TERMINAL:
                self.engine.cancel(rid)

    def stats(self) -> Dict[str, object]:
        """Frontend counters merged over :attr:`ServingEngine.metrics`
        (frontend keys win on collision; there are none today)."""
        return {**self.engine.metrics, **self.metrics,
                "open_streams": len(self._streams)}
