"""Paged KV-cache allocator — the §5.3 caching allocator reborn for TPU
serving.

PyTorch's insight: dynamic allocation against the raw device API is the
bottleneck, so cache and reuse blocks, round sizes, and keep one pool per
stream.  On TPU under XLA, *training* memory is compiler-planned, but
*serving* reintroduces exactly the same dynamic-allocation problem: KV
grows token by token, requests arrive/finish continuously.  The same
design transplanted:

  * fixed-size PAGES (the 512-byte rounding, at tokens granularity),
  * a free-list that never returns pages to the system (incremental cache),
  * refcounting for immediate reuse (§5.5) — shared prefixes hold
    refcounts per page; copy-on-write on divergence,
  * hash-based prefix reuse (the "cache hit" of Fig. 2, at page level).

Physical layout: one (num_pages, page_size, n_kv_heads, head_dim) array
pair per attention layer; block tables are host-side Python (control
plane) while gathers/scatters are jnp (data plane) — the paper's
control/data-flow separation (§5.2).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PageStats:
    allocated_pages: int = 0
    freed_pages: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    cow_copies: int = 0
    oom_rejections: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / tot if tot else 0.0


class PagePool:
    """Refcounted free-list of physical page ids (one pool; per-stream
    pools degenerate to one on a single serving stream)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.refs: Dict[int, int] = {}
        self.stats = PageStats()

    def alloc(self) -> Optional[int]:
        if not self.free:
            self.stats.oom_rejections += 1
            return None
        page = self.free.pop()
        self.refs[page] = 1
        self.stats.allocated_pages += 1
        return page

    def retain(self, page: int) -> None:
        self.refs[page] += 1

    def release(self, page: int) -> None:
        self.refs[page] -= 1
        if self.refs[page] == 0:
            del self.refs[page]
            self.free.append(page)       # immediate reuse — no deferred GC
            self.stats.freed_pages += 1

    @property
    def num_free(self) -> int:
        return len(self.free)


class PagedKVCache:
    """Physical paged KV storage + per-sequence block tables."""

    def __init__(self, *, n_layers: int, n_kv_heads: int, head_dim: int,
                 page_size: int = 16, num_pages: int = 256,
                 dtype=jnp.bfloat16):
        self.n_layers = n_layers
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.page_size = page_size
        self.pool = PagePool(num_pages)
        shape = (num_pages, page_size, n_kv_heads, head_dim)
        self.k = [jnp.zeros(shape, dtype) for _ in range(n_layers)]
        self.v = [jnp.zeros(shape, dtype) for _ in range(n_layers)]
        # sequence id -> (block_table, length)
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        self.reused_prefix: Dict[int, int] = {}   # tokens whose pages were
                                                  # prefix-cache hits
        # prefix cache: page-content hash chain -> page id
        self._prefix_index: Dict[bytes, int] = {}

    # -- sequence lifecycle ----------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pool.num_free >= self.pages_needed(n_tokens)

    def create(self, seq_id: int, prompt_tokens: Sequence[int]) -> bool:
        """Admit a sequence; reuse shared-prefix pages where the page-
        aligned prompt hash matches (RadixAttention-style, page granular).
        Returns False when out of pages (admission control)."""
        assert seq_id not in self.tables
        n = len(prompt_tokens)
        table: List[int] = []
        reused = 0
        h = hashlib.sha1()
        for start in range(0, n, self.page_size):
            chunk = tuple(prompt_tokens[start:start + self.page_size])
            full_page = len(chunk) == self.page_size
            h.update(repr(chunk).encode())
            key = h.digest()
            hit = self._prefix_index.get(key) if full_page else None
            if hit is not None and hit in self.pool.refs:
                self.pool.retain(hit)
                table.append(hit)
                reused += 1
                self.pool.stats.prefix_hits += 1
                continue
            page = self.pool.alloc()
            if page is None:
                for p in table:
                    self.pool.release(p)
                return False
            self.pool.stats.prefix_misses += 1
            if full_page:
                self._prefix_index[key] = page
            table.append(page)
        self.tables[seq_id] = table
        self.lengths[seq_id] = n
        self.reused_prefix[seq_id] = reused * self.page_size
        return True

    def free_seq(self, seq_id: int) -> None:
        for p in self.tables.pop(seq_id):
            self.pool.release(p)
        del self.lengths[seq_id]
        self.reused_prefix.pop(seq_id, None)

    def _writable_page(self, seq_id: int, page_pos: int) -> Optional[int]:
        """Copy-on-write: if the page is shared, copy it before writing."""
        table = self.tables[seq_id]
        page = table[page_pos]
        if self.pool.refs.get(page, 1) > 1:
            new_page = self.pool.alloc()
            if new_page is None:
                return None
            for layer in range(self.n_layers):
                self.k[layer] = self.k[layer].at[new_page].set(
                    self.k[layer][page])
                self.v[layer] = self.v[layer].at[new_page].set(
                    self.v[layer][page])
            self.pool.release(page)
            table[page_pos] = new_page
            self.pool.stats.cow_copies += 1
            return new_page
        return page

    # -- data plane ---------------------------------------------------------
    def append(self, seq_id: int, layer_kv: List[Tuple[jnp.ndarray,
                                                       jnp.ndarray]]
               ) -> bool:
        """Append ONE token's K/V for every layer.  layer_kv[i] is a
        ((n_kv_heads, head_dim), (n_kv_heads, head_dim)) pair."""
        pos = self.lengths[seq_id]
        page_pos = pos // self.page_size
        offset = pos % self.page_size
        table = self.tables[seq_id]
        if page_pos >= len(table):
            page = self.pool.alloc()
            if page is None:
                return False
            table.append(page)
        page = self._writable_page(seq_id, page_pos)
        if page is None:
            return False
        for layer, (k_t, v_t) in enumerate(layer_kv):
            self.k[layer] = self.k[layer].at[page, offset].set(
                k_t.astype(self.k[layer].dtype))
            self.v[layer] = self.v[layer].at[page, offset].set(
                v_t.astype(self.v[layer].dtype))
        self.lengths[seq_id] = pos + 1
        return True

    def gather(self, seq_ids: Sequence[int], layer: int,
               pad_to: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Materialize contiguous (B, n_kv, L, hd) K/V for a batch of
        sequences from their page tables (gather-based paged attention;
        a block-table Pallas kernel is the further TPU optimization)."""
        max_len = max(self.lengths[s] for s in seq_ids)
        pad_to = pad_to or max_len
        max_pages = self.pages_needed(pad_to)
        tables = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, s in enumerate(seq_ids):
            t = self.tables[s][: max_pages]
            tables[i, : len(t)] = t
        idx = jnp.asarray(tables)                       # (B, P)
        k = jnp.take(self.k[layer], idx, axis=0)        # (B,P,page,kv,hd)
        v = jnp.take(self.v[layer], idx, axis=0)
        b = len(seq_ids)
        k = k.reshape(b, max_pages * self.page_size, self.n_kv_heads,
                      self.head_dim)[:, :pad_to].transpose(0, 2, 1, 3)
        v = v.reshape(b, max_pages * self.page_size, self.n_kv_heads,
                      self.head_dim)[:, :pad_to].transpose(0, 2, 1, 3)
        lens = jnp.asarray([self.lengths[s] for s in seq_ids], jnp.int32)
        return k, v, lens

    def memory_stats(self) -> Dict[str, float]:
        page_bytes = (self.page_size * self.n_kv_heads * self.head_dim
                      * 2 * self.k[0].dtype.itemsize * self.n_layers)
        used = self.pool.num_pages - self.pool.num_free
        return {
            "pages_total": self.pool.num_pages,
            "pages_used": used,
            "pages_free": self.pool.num_free,
            "bytes_used": used * page_bytes,
            "prefix_hit_rate": self.pool.stats.hit_rate,
            "cow_copies": self.pool.stats.cow_copies,
            "oom_rejections": self.pool.stats.oom_rejections,
        }
