"""Paged KV-cache allocator — the §5.3 caching allocator reborn for TPU
serving.

PyTorch's insight: dynamic allocation against the raw device API is the
bottleneck, so cache and reuse blocks, round sizes, and keep one pool per
stream.  On TPU under XLA, *training* memory is compiler-planned, but
*serving* reintroduces exactly the same dynamic-allocation problem: KV
grows token by token, requests arrive/finish continuously.  The same
design transplanted:

  * fixed-size PAGES (the 512-byte rounding, at tokens granularity),
  * a free-list that never returns pages to the system (incremental cache),
  * refcounting for immediate reuse (§5.5) — shared prefixes hold
    refcounts per page; copy-on-write on divergence,
  * hash-based prefix reuse (the "cache hit" of Fig. 2, at page level),
    generation-stamped so a freed-and-reallocated page can never serve a
    stale prefix hit.

Physical layout: one (num_pages, page_size, n_kv_heads, head_dim) array
pair per attention layer; block tables are host-side Python (control
plane) mirrored to device by row-level deltas, writes are batched jnp
scatters, and attention reads the pages in place through the mirror
(data plane) — the paper's control/data-flow separation (§5.2).

Scheduler/executor contract (PR 3):

  * ``lengths[seq]`` counts tokens whose K/V is VALID in the pages (a
    fresh ``create`` sets it to the reused-prefix token count, not the
    prompt length — the executor fills the rest chunk by chunk),
  * ``take_kv`` / ``put_kv`` are the donation hooks: the executor takes
    the page arrays, donates them to the jitted ``unified_step``, and
    puts the results back.  While taken, the host MUST NOT alias them
    (``self.k``/``self.v`` are None so any stray access raises),
  * ``device_tables`` maintains a device-RESIDENT block-table mirror
    updated by row-level DELTAS: per-sequence table versions feed a
    dirty set, and each step flushes only the changed rows as ONE
    scatter (``table_upload_rows`` counts them — the regression gate
    that keeps uploads O(changed rows), not O(total pages)).
"""

from __future__ import annotations

import functools
import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quant


import warnings


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows_jit(mirror: jnp.ndarray, idx: jnp.ndarray,
                      rows: jnp.ndarray) -> jnp.ndarray:
    return mirror.at[idx].set(rows, mode="drop")


def _scatter_rows(mirror: jnp.ndarray, idx: jnp.ndarray,
                  rows: jnp.ndarray) -> jnp.ndarray:
    """One compiled delta flush: scatter ``rows`` into ``mirror`` at row
    ``idx`` (out-of-bounds padding rows drop).  The mirror is donated so
    the device table updates in place; padding idx to pow2 buckets keeps
    the compile count O(log max_batch) instead of one per dirty count.
    Donation is a TPU/GPU optimization — the CPU backend's "donated
    buffers were not usable" warning is suppressed HERE only, not
    process-wide."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _scatter_rows_jit(mirror, idx, rows)


def _warm_scatter_variants(s: int, width: int, scatter=None,
                           sharding=None) -> None:
    """Compile every pow2-padded ``_scatter_rows`` variant for an
    (s, width) mirror up front — a one-time server-startup cost, so no
    delta flush ever compiles on the serving hot path.  ``scatter`` /
    ``sharding`` warm a mesh-placed mirror's dedicated executable (the
    operand sharding is part of the jit cache key)."""
    scatter = scatter or _scatter_rows
    n = 1
    while True:
        n_pad = min(n, s)
        mirror = jnp.zeros((s, width), jnp.int32)
        if sharding is not None:
            mirror = jax.device_put(mirror, sharding)
        scatter(mirror, jnp.full((n_pad,), s, jnp.int32),
                jnp.zeros((n_pad, width), jnp.int32))
        if n >= s:
            break
        n *= 2


@dataclass
class PageStats:
    allocated_pages: int = 0
    freed_pages: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    cow_copies: int = 0
    oom_rejections: int = 0
    page_hwm: int = 0          # high-water mark of live pages

    @property
    def hit_rate(self) -> float:
        tot = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / tot if tot else 0.0


class PagePool:
    """Refcounted free-list of physical page ids (one pool; per-stream
    pools degenerate to one on a single serving stream).

    With ``n_replicas > 1`` (data-parallel serving) page ids stay GLOBAL
    but replica ``r`` owns the contiguous range
    ``[r*pages_per_replica, (r+1)*pages_per_replica)`` — contiguity is
    what lets the physical page arrays shard their page axis over the
    ``data`` mesh axis with a plain ``NamedSharding``.  ``free`` remains
    ONE flat list (watchdog/fault-injector/recovery code keeps working
    on global ids); replica-targeted allocation scans it."""

    def __init__(self, num_pages: int, n_replicas: int = 1):
        if n_replicas < 1 or num_pages % n_replicas:
            from .errors import MeshConfigError
            raise MeshConfigError(
                f"num_pages={num_pages} must divide across "
                f"n_replicas={n_replicas}")
        self.num_pages = num_pages
        self.n_replicas = n_replicas
        self.pages_per_replica = num_pages // n_replicas
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.refs: Dict[int, int] = {}
        # content generation per page: bumped on every alloc, so prefix
        # index entries stamped with an older generation are stale.
        self.gen: List[int] = [0] * num_pages
        # tokens actually WRITTEN into each live page — a prefix hit on a
        # page some sharer has not filled yet must not be trusted for
        # compute reuse (chunked prefill admits sharers before the first
        # writer finishes)
        self.filled: Dict[int, int] = {}
        self.stats = PageStats()
        # live-page high-water mark per replica (ROADMAP item 3 metric)
        self.page_hwm_per_replica: List[int] = [0] * n_replicas

    def replica_of(self, page: int) -> int:
        return page // self.pages_per_replica

    def free_in(self, replica: int) -> int:
        """Free pages owned by ``replica`` (O(free); host-side only)."""
        if self.n_replicas == 1:
            return len(self.free)
        return sum(1 for p in self.free
                   if p // self.pages_per_replica == replica)

    def _live_in(self, replica: int) -> int:
        return self.pages_per_replica - self.free_in(replica)

    def alloc(self, replica: Optional[int] = None) -> Optional[int]:
        """Pop a free page — from ``replica``'s range when given, from
        anywhere otherwise (``None`` keeps the pre-replica callers, e.g.
        the fault injector's page stealer, working unchanged)."""
        if replica is None or self.n_replicas == 1:
            if not self.free:
                self.stats.oom_rejections += 1
                return None
            page = self.free.pop()
        else:
            lo = replica * self.pages_per_replica
            hi = lo + self.pages_per_replica
            i = next((j for j in range(len(self.free) - 1, -1, -1)
                      if lo <= self.free[j] < hi), None)
            if i is None:
                self.stats.oom_rejections += 1
                return None
            page = self.free.pop(i)
        self.refs[page] = 1
        self.gen[page] += 1
        self.filled[page] = 0
        self.stats.allocated_pages += 1
        self.stats.page_hwm = max(self.stats.page_hwm, len(self.refs))
        r = self.replica_of(page)
        self.page_hwm_per_replica[r] = max(self.page_hwm_per_replica[r],
                                           self._live_in(r))
        return page

    def retain(self, page: int) -> None:
        self.refs[page] += 1

    def release(self, page: int) -> None:
        self.refs[page] -= 1
        if self.refs[page] == 0:
            del self.refs[page]
            self.free.append(page)       # immediate reuse — no deferred GC
            self.stats.freed_pages += 1

    @property
    def num_free(self) -> int:
        return len(self.free)


class PagedKVCache:
    """Physical paged KV storage + per-sequence block tables."""

    def __init__(self, *, n_layers: int, n_kv_heads: int, head_dim: int,
                 page_size: int = 16, num_pages: int = 256,
                 dtype=jnp.bfloat16, n_replicas: int = 1,
                 kv_dtype: Optional[str] = None):
        self.n_layers = n_layers
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.page_size = page_size
        self.n_replicas = n_replicas
        self.pool = PagePool(num_pages, n_replicas)
        self.pages_per_replica = self.pool.pages_per_replica
        # quantized pool: pages hold int8/fp8_e4m3 CODES, with fp32
        # per-(token, head) scales in parallel (N, ps, Hkv) arrays —
        # page-shaped so COW/truncate/scrub/recover carry scales with
        # their pages by construction (the scales-layout contract,
        # docs/kernels.md)
        self.quant_mode = quant.canonical(kv_dtype)
        if self.quant_mode is not None:
            dtype = quant.storage_dtype(self.quant_mode)
        elif kv_dtype in ("fp32", "float32"):
            dtype = jnp.float32
        elif kv_dtype in ("bf16", "bfloat16"):
            dtype = jnp.bfloat16
        self.kv_dtype_name = self.quant_mode or np.dtype(dtype).name
        # sequence id -> owning data replica (every page of a sequence
        # lives in ONE replica's contiguous range; its block-table mirror
        # row therefore holds replica-LOCAL page ids)
        self.seq_replica: Dict[int, int] = {}
        shape = (num_pages, page_size, n_kv_heads, head_dim)
        self.k: Optional[List[jnp.ndarray]] = [
            jnp.zeros(shape, dtype) for _ in range(n_layers)]
        self.v: Optional[List[jnp.ndarray]] = [
            jnp.zeros(shape, dtype) for _ in range(n_layers)]
        sshape = (num_pages, page_size, n_kv_heads)
        self.k_scale: Optional[List[jnp.ndarray]] = None
        self.v_scale: Optional[List[jnp.ndarray]] = None
        if self.quant_mode is not None:
            self.k_scale = [jnp.zeros(sshape, jnp.float32)
                            for _ in range(n_layers)]
            self.v_scale = [jnp.zeros(sshape, jnp.float32)
                            for _ in range(n_layers)]
        self.dtype = dtype
        # sequence id -> (block_table, valid-KV length)
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        self.reused_prefix: Dict[int, int] = {}   # tokens whose pages were
                                                  # prefix-cache hits
        # prefix cache: page-content hash chain -> (page id, generation)
        self._prefix_index: Dict[bytes, Tuple[int, int]] = {}
        # device block-table mirror: per-seq versions drive row-level
        # delta uploads (one scatter per step over only the dirty rows)
        self._seq_version: Dict[int, int] = {}
        self._version_counter = 0
        self._mirror: Optional[jnp.ndarray] = None     # (S, width) device
        self._mirror_rows: List[Optional[Tuple[int, int]]] = []
        self.mirror_width_hint = 0     # engine sets this to the pages
                                       # bucket cap so the mirror never
                                       # rebuilds for width growth
        self.upload_rows_total = 0     # host->device rows ever uploaded
        self.upload_full_rebuilds = 0  # slot-layout/width resets
        self.last_upload_rows = 0      # rows flushed by the last call
        # pages legitimately held OUTSIDE any block table (e.g. the
        # fault injector's pool-exhaustion holds) — the watchdog and
        # ``reconcile`` count these as referenced
        self.external_refs: Dict[int, int] = {}
        # mesh placement (``place_on_mesh``): NamedShardings for the
        # page arrays and the table mirror, plus a scatter executable
        # whose out_shardings pin the mirror's sharding so a dirty-row
        # delta flush can never silently reshard the whole mirror
        self._kv_sharding = None
        self._scale_sharding = None
        self._mirror_sharding = None
        self._scatter = _scatter_rows

    # -- sequence lifecycle ----------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int, replica: int = 0) -> bool:
        return self.pool.free_in(replica) >= self.pages_needed(n_tokens)

    def create(self, seq_id: int, prompt_tokens: Sequence[int],
               replica: int = 0) -> bool:
        """Admit a sequence; reuse shared-prefix pages where the page-
        aligned prompt hash matches (RadixAttention-style, page granular).
        ``lengths[seq_id]`` is set to the reused token count — the K/V of
        the remaining tokens is not in the pages yet.  Returns False when
        out of pages (admission control).  ``replica`` pins every page
        (and any prefix hit — sharing never crosses replicas) to that
        data replica's contiguous page range."""
        assert seq_id not in self.tables
        n = len(prompt_tokens)
        table: List[int] = []
        reused = 0
        h = hashlib.sha1()
        for start in range(0, n, self.page_size):
            chunk = tuple(prompt_tokens[start:start + self.page_size])
            full_page = len(chunk) == self.page_size
            h.update(repr(chunk).encode())
            key = h.digest()
            hit = self._prefix_index.get(key) if full_page else None
            if (hit is not None and hit[0] in self.pool.refs
                    and self.pool.gen[hit[0]] == hit[1]
                    and self.pool.replica_of(hit[0]) == replica
                    and reused * self.page_size == start):
                self.pool.retain(hit[0])
                table.append(hit[0])
                reused += 1
                self.pool.stats.prefix_hits += 1
                continue
            page = self.pool.alloc(replica)
            if page is None:
                for p in table:
                    self.pool.release(p)
                return False
            self.pool.stats.prefix_misses += 1
            if full_page:
                self._prefix_index[key] = (page, self.pool.gen[page])
            table.append(page)
        self.tables[seq_id] = table
        self.seq_replica[seq_id] = replica
        # valid KV = the reused prefix, capped by what the sharers have
        # actually WRITTEN so far — a mid-prefill writer's pages are
        # claimed (page dedup) but their unwritten tail is re-computed by
        # this sequence (identical, hash-pledged content)
        self.lengths[seq_id] = min(reused * self.page_size,
                                   self._readable(table))
        self.reused_prefix[seq_id] = reused * self.page_size
        self._bump(seq_id)
        return True

    def _bump(self, seq_id: int) -> None:
        """Mark ``seq_id``'s block table changed since the last device
        flush (versions are globally monotonic, so a freed-and-readmitted
        id can never alias a stale mirror row)."""
        self._version_counter += 1
        self._seq_version[seq_id] = self._version_counter

    def _readable(self, table: List[int]) -> int:
        """Contiguous token prefix actually written across a table."""
        total = 0
        for p in table:
            f = self.pool.filled.get(p, 0)
            total += f
            if f < self.page_size:
                break
        return total

    def _alloc_for(self, seq_id: int) -> Optional[int]:
        """Allocate a page in ``seq_id``'s owning replica (growth, COW,
        speculative tails — a sequence's pages never cross replicas)."""
        return self.pool.alloc(self.seq_replica.get(seq_id, 0))

    def free_seq(self, seq_id: int) -> None:
        for p in self.tables.pop(seq_id):
            self.pool.release(p)
        del self.lengths[seq_id]
        self.reused_prefix.pop(seq_id, None)
        self._seq_version.pop(seq_id, None)
        self.seq_replica.pop(seq_id, None)

    # -- quarantine / recovery --------------------------------------------
    def quarantine_seq(self, seq_id: int) -> None:
        """Drop a SUSPECT sequence's bookkeeping WITHOUT walking its
        (possibly corrupted) block table through the normal release
        path — a corrupt entry must never reach ``pool.release``.  The
        pages it held become orphans that the next :meth:`recover` call
        reclaims, scrubs, and returns to the free list."""
        self.tables.pop(seq_id, None)
        self.lengths.pop(seq_id, None)
        self.reused_prefix.pop(seq_id, None)
        self._seq_version.pop(seq_id, None)
        self.seq_replica.pop(seq_id, None)

    def recover(self) -> int:
        """Force-rebuild allocator + mirror state from the surviving
        block tables — the watchdog's repair path after a quarantine or
        an unattributable invariant violation.

        Reconciles ``pool.refs`` against the reference counts implied
        by the live tables (plus ``external_refs``), rebuilds the free
        list, scrubs reclaimed pages to zero (so poisoned K/V — e.g.
        injected NaNs — can never leak into a future sequence), realigns
        the alloc/free counters so ``allocated == freed + held`` holds
        again, and drops the device table mirror so the next
        ``device_tables`` call does a full rebuild.  Returns the number
        of repaired pages."""
        pool = self.pool
        expected: Dict[int, int] = dict(self.external_refs)
        for table in self.tables.values():
            for p in table:
                if 0 <= p < pool.num_pages:
                    expected[p] = expected.get(p, 0) + 1
        repaired, orphans = 0, []
        for page in range(pool.num_pages):
            want = expected.get(page, 0)
            have = pool.refs.get(page, 0)
            if want == have:
                continue
            repaired += 1
            if want == 0:
                orphans.append(page)
                del pool.refs[page]
                pool.filled.pop(page, None)
            else:
                pool.refs[page] = want
        pool.free = [p for p in range(pool.num_pages - 1, -1, -1)
                     if p not in pool.refs]
        # realign conservation: allocated == freed + held, by definition
        pool.stats.freed_pages = (pool.stats.allocated_pages
                                  - len(pool.refs))
        if orphans:
            self.scrub_pages(orphans)
        self._mirror = None            # next device_tables: full rebuild
        return repaired

    def scrub_pages(self, pages: Sequence[int]) -> None:
        """Zero the K/V content of ``pages`` (quarantine hygiene: a
        reclaimed page must not carry NaN/garbage into its next
        sequence).  Requires the host to own the arrays (not taken)."""
        if not pages or self.k is None:
            return
        idx = jnp.asarray(np.asarray(pages, np.int32))
        for layer in range(self.n_layers):
            self.k[layer] = self.k[layer].at[idx].set(0)
            self.v[layer] = self.v[layer].at[idx].set(0)
            if self.k_scale is not None:
                # scrubbed codes must dequantize to zero too
                self.k_scale[layer] = self.k_scale[layer].at[idx].set(0)
                self.v_scale[layer] = self.v_scale[layer].at[idx].set(0)
        if self._kv_sharding is not None:
            # eager scatters may drop the placement; re-pin so the next
            # unified_step sees the SAME input shardings (no recompile)
            self.k = [jax.device_put(a, self._kv_sharding) for a in self.k]
            self.v = [jax.device_put(a, self._kv_sharding) for a in self.v]
            if self.k_scale is not None:
                self.k_scale = [jax.device_put(a, self._scale_sharding)
                                for a in self.k_scale]
                self.v_scale = [jax.device_put(a, self._scale_sharding)
                                for a in self.v_scale]

    def ensure_capacity(self, seq_id: int, n_tokens: int) -> bool:
        """Grow the block table so ``n_tokens`` positions have pages.
        Returns False (table unchanged in coverage, caller preempts) when
        the pool runs dry."""
        table = self.tables[seq_id]
        need = self.pages_needed(n_tokens)
        grown = []
        while len(table) < need:
            page = self._alloc_for(seq_id)
            if page is None:
                for p in grown:
                    self.pool.release(p)
                    table.pop()
                return False
            table.append(page)
            grown.append(page)
        if grown:
            self._bump(seq_id)
        return True

    def make_writable(self, seq_id: int, start: int, end: int,
                      divergent: bool = True) -> bool:
        """Copy-on-write guard for token span [start, end).

        ``divergent=True`` (generated tokens): any shared page is copied
        first so the write cannot clobber a sibling sequence.
        ``divergent=False`` (prompt-content prefill): shared pages are
        written THROUGH — sharing only ever arises from hash-equal
        prefixes, so every sharer pledges byte-identical content and the
        write is idempotent (this is what lets chunked prefill fill
        dedup'd pages without splitting them)."""
        if not divergent:
            return True
        for page_pos in range(start // self.page_size,
                              -(-end // self.page_size)):
            if self._writable_page(seq_id, page_pos) is None:
                return False
        return True

    def truncate(self, seq_id: int, n_tokens: int) -> bool:
        """Shrink the block table to cover exactly ``n_tokens``
        positions, releasing the tail pages — the speculative-decoding
        rewind (``Scheduler.commit`` drops the pages a rejected draft
        run reserved past the committed end).  ``lengths`` is clamped
        too, so a page whose only content was speculative K/V can be
        freely re-filled later.  Bumps the table version when anything
        changed (the device mirror row re-uploads next step).  Returns
        True when pages were released or the length moved."""
        table = self.tables[seq_id]
        keep = self.pages_needed(n_tokens)
        changed = False
        while len(table) > keep:
            self.pool.release(table.pop())
            changed = True
        if self.lengths[seq_id] > n_tokens:
            self.lengths[seq_id] = n_tokens
            changed = True
        if changed:
            self._bump(seq_id)
        return changed

    def advance(self, seq_id: int, n_tokens: int) -> None:
        """Mark K/V valid (written) up to ``n_tokens`` — called after a
        ``unified_step``/batched write lands."""
        table = self.tables[seq_id]
        ps = self.page_size
        # pages below the already-valid length were marked when first
        # written — skip them (keeps the decode hot loop O(1) per step)
        for i in range(self.lengths[seq_id] // ps, n_tokens // ps):
            self.pool.filled[table[i]] = ps
        if n_tokens % ps:
            p = table[n_tokens // ps]
            self.pool.filled[p] = max(self.pool.filled.get(p, 0),
                                      n_tokens % ps)
        self.lengths[seq_id] = max(self.lengths[seq_id], n_tokens)

    def _writable_page(self, seq_id: int, page_pos: int) -> Optional[int]:
        """Copy-on-write: if the page is shared, copy it before writing."""
        table = self.tables[seq_id]
        page = table[page_pos]
        if self.pool.refs.get(page, 1) > 1:
            new_page = self._alloc_for(seq_id)
            if new_page is None:
                return None
            for layer in range(self.n_layers):
                self.k[layer] = self.k[layer].at[new_page].set(
                    self.k[layer][page])
                self.v[layer] = self.v[layer].at[new_page].set(
                    self.v[layer][page])
                if self.k_scale is not None:
                    # the scales travel with their page's codes
                    self.k_scale[layer] = self.k_scale[layer].at[
                        new_page].set(self.k_scale[layer][page])
                    self.v_scale[layer] = self.v_scale[layer].at[
                        new_page].set(self.v_scale[layer][page])
            self.pool.release(page)
            table[page_pos] = new_page
            self.pool.stats.cow_copies += 1
            self._bump(seq_id)
            return new_page
        return page

    # -- data plane ---------------------------------------------------------
    def append(self, seq_id: int, layer_kv: List[Tuple[jnp.ndarray,
                                                       jnp.ndarray]]
               ) -> bool:
        """Append ONE token's K/V for every layer.  layer_kv[i] is a
        ((n_kv_heads, head_dim), (n_kv_heads, head_dim)) pair."""
        pos = self.lengths[seq_id]
        page_pos = pos // self.page_size
        offset = pos % self.page_size
        table = self.tables[seq_id]
        if page_pos >= len(table):
            page = self._alloc_for(seq_id)
            if page is None:
                return False
            table.append(page)
            self._bump(seq_id)
        page = self._writable_page(seq_id, page_pos)
        if page is None:
            return False
        for layer, (k_t, v_t) in enumerate(layer_kv):
            if self.quant_mode is not None:
                k_t, k_sc = quant.quantize(k_t, self.quant_mode)
                v_t, v_sc = quant.quantize(v_t, self.quant_mode)
                self.k_scale[layer] = self.k_scale[layer].at[
                    page, offset].set(k_sc)
                self.v_scale[layer] = self.v_scale[layer].at[
                    page, offset].set(v_sc)
            self.k[layer] = self.k[layer].at[page, offset].set(
                k_t.astype(self.k[layer].dtype))
            self.v[layer] = self.v[layer].at[page, offset].set(
                v_t.astype(self.v[layer].dtype))
        self.pool.filled[page] = max(self.pool.filled.get(page, 0),
                                     offset + 1)
        self.lengths[seq_id] = pos + 1
        return True

    def flat_slots(self, seq_id: int, start: int, end: int) -> np.ndarray:
        """Flat (page*page_size + offset) destination for each token
        position in [start, end) — the scatter indices the executor (or
        ``write_batch``) uses.  Pages must already exist."""
        pos = np.arange(start, end)
        table = np.asarray(self.tables[seq_id], np.int64)
        return table[pos // self.page_size] * self.page_size \
            + pos % self.page_size

    def write_batch(self, seq_id: int,
                    layer_kv: List[Tuple[jnp.ndarray, jnp.ndarray]],
                    start: int, end: int) -> bool:
        """Write token span [start, end) with ONE scatter per layer
        (replaces the per-token ``append`` loop of the old prefill path).
        layer_kv[i] = ((end-start, n_kv_heads, hd), same for v).
        Allocates pages and COW-copies shared ones as needed."""
        if end <= start:
            return True
        if not self.ensure_capacity(seq_id, end):
            return False
        if not self.make_writable(seq_id, start, end, divergent=False):
            return False
        idx = jnp.asarray(self.flat_slots(seq_id, start, end))
        npg, ps = self.pool.num_pages, self.page_size
        for layer, (k_s, v_s) in enumerate(layer_kv):
            if self.quant_mode is not None:
                # quantize on scatter: codes into the pool, per-token
                # scales into the parallel array at the SAME flat slots
                k_s, k_sc = quant.quantize(k_s, self.quant_mode)
                v_s, v_sc = quant.quantize(v_s, self.quant_mode)
                self.k_scale[layer] = self.k_scale[layer].reshape(
                    npg * ps, self.n_kv_heads).at[idx].set(
                    k_sc).reshape(npg, ps, self.n_kv_heads)
                self.v_scale[layer] = self.v_scale[layer].reshape(
                    npg * ps, self.n_kv_heads).at[idx].set(
                    v_sc).reshape(npg, ps, self.n_kv_heads)
            kf = self.k[layer].reshape(npg * ps, self.n_kv_heads,
                                       self.head_dim)
            vf = self.v[layer].reshape(npg * ps, self.n_kv_heads,
                                       self.head_dim)
            self.k[layer] = kf.at[idx].set(k_s.astype(kf.dtype)).reshape(
                npg, ps, self.n_kv_heads, self.head_dim)
            self.v[layer] = vf.at[idx].set(v_s.astype(vf.dtype)).reshape(
                npg, ps, self.n_kv_heads, self.head_dim)
        self.advance(seq_id, end)
        return True

    def write_prompt(self, seq_id: int,
                     layer_kv: List[Tuple[jnp.ndarray, jnp.ndarray]],
                     n_tokens: int) -> bool:
        """Batched prefill write: store K/V for every prompt token PAST
        the already-valid reused prefix (the skip preserves the
        recompute-write saving of prefix sharing).  layer_kv[i] holds the
        FULL prompt's (n_tokens, n_kv_heads, hd) arrays; the valid slice
        is dropped here."""
        skip = min(self.lengths[seq_id], n_tokens)
        span = [(k[skip:], v[skip:]) for k, v in layer_kv]
        return self.write_batch(seq_id, span, skip, n_tokens)

    # -- device mirror / donation ----------------------------------------
    _EMPTY_ROW = (-1, -1)

    def device_tables(self, seq_ids: Sequence[int], max_pages: int
                      ) -> jnp.ndarray:
        """(len(seq_ids), W) int32 block-table mirror with W >= the
        requested ``max_pages``, rows padded with page 0.  The mirror is
        device-RESIDENT and updated by deltas: slot i is dirty when its
        (seq id, table version) differs from what the device row holds,
        and all dirty rows flush as ONE jitted scatter per call (dirty
        counts are pow2-padded so the scatter compiles O(log) variants).
        A steady decode step whose tables didn't cross a page boundary
        uploads ZERO rows.  Full re-uploads happen only when the slot
        count or width outgrows the mirror (once, when the engine seeds
        ``mirror_width_hint`` with the pages bucket cap).  Callers
        wanting exactly ``max_pages`` columns slice the result — the
        executor does so INSIDE the jitted step, so narrowing costs no
        host→device traffic.  ``upload_rows_total``/``last_upload_rows``
        count transferred rows including the pow2 padding (surfaced as
        ``engine.metrics["table_upload_rows"]``; CI gates it at
        O(changed rows), not O(steps × slots))."""
        s = len(seq_ids)
        targets = [(sid, self._seq_version[sid]) if sid >= 0
                   else self._EMPTY_ROW for sid in seq_ids]

        if (self._mirror is None or self._mirror.shape[0] != s
                or self._mirror.shape[1] < max_pages):
            width = max(max_pages, self.mirror_width_hint,
                        self._mirror.shape[1]
                        if self._mirror is not None else 0)
            out = np.zeros((s, width), np.int32)
            for i, sid in enumerate(seq_ids):
                if sid < 0:
                    continue
                t = self._local_row(sid, width)
                out[i, : len(t)] = t
            self._mirror = (jnp.asarray(out)
                            if self._mirror_sharding is None else
                            jax.device_put(out, self._mirror_sharding))
            self._mirror_rows = list(targets)
            uploaded = s
            self.upload_full_rebuilds += 1
            _warm_scatter_variants(s, width, self._scatter,
                                   self._mirror_sharding)
        else:
            width = self._mirror.shape[1]
            dirty = [i for i, tgt in enumerate(targets)
                     if self._mirror_rows[i] != tgt]
            uploaded = 0
            if dirty:
                # pow2-pad the dirty set; padding rows carry an OOB
                # index and drop in the scatter
                n_pad = 1
                while n_pad < len(dirty):
                    n_pad *= 2
                n_pad = min(n_pad, s)
                idx = np.full(n_pad, s, np.int32)
                rows = np.zeros((n_pad, width), np.int32)
                for j, i in enumerate(dirty):
                    sid = seq_ids[i]
                    if sid >= 0:
                        t = self._local_row(sid, width)
                        rows[j, : len(t)] = t
                    idx[j] = i
                    self._mirror_rows[i] = targets[i]
                self._mirror = self._scatter(
                    self._mirror, jnp.asarray(idx), jnp.asarray(rows))
                uploaded = n_pad
        self.last_upload_rows = uploaded
        self.upload_rows_total += uploaded
        return self._mirror

    def _local_row(self, sid: int, width: int) -> List[int]:
        """A sequence's block-table row in replica-LOCAL page ids — the
        executor's per-replica KV shard is indexed [0, pages_per_replica)
        so mirror rows subtract the owning replica's page-range offset.
        With one replica the offset is 0 and ids are global (unchanged)."""
        off = self.seq_replica.get(sid, 0) * self.pages_per_replica
        t = self.tables[sid][:width]
        return t if off == 0 else [p - off for p in t]

    def place_on_mesh(self, kv_sharding, mirror_sharding,
                      scale_sharding=None) -> None:
        """Pin the page pool and block-table mirror to a device mesh.

        ``kv_sharding`` shards each per-layer (num_pages, page, kv, hd)
        page array (page axis over ``data`` replicas, head axis over
        ``model`` when it divides); ``mirror_sharding`` places the
        (S, W) mirror.  The delta-upload scatter is re-jitted with an
        explicit ``out_shardings=mirror_sharding`` so a dirty-row flush
        can never reshard the mirror — the donation + delta-upload
        invariant survives sharding.  Call once at engine construction,
        before any ``device_tables``."""
        self._kv_sharding = kv_sharding
        self._mirror_sharding = mirror_sharding
        scatter_jit = jax.jit(
            lambda mirror, idx, rows: mirror.at[idx].set(rows, mode="drop"),
            donate_argnums=(0,), out_shardings=mirror_sharding)

        def scatter(mirror, idx, rows):
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                return scatter_jit(mirror, idx, rows)

        self._scatter = scatter
        if self.k is not None:
            self.k = [jax.device_put(a, kv_sharding) for a in self.k]
            self.v = [jax.device_put(a, kv_sharding) for a in self.v]
        if self.k_scale is not None and scale_sharding is not None:
            self._scale_sharding = scale_sharding
            self.k_scale = [jax.device_put(a, scale_sharding)
                            for a in self.k_scale]
            self.v_scale = [jax.device_put(a, scale_sharding)
                            for a in self.v_scale]
        self._mirror = None            # next device_tables: placed rebuild

    def take_kv(self) -> Tuple[List[jnp.ndarray], List[jnp.ndarray]]:
        """Donation hook: hand the page arrays to the executor.  The host
        must not alias them until ``put_kv`` returns the new ones —
        ``unified_step`` donates (consumes) these buffers."""
        ks, vs = self.k, self.v
        assert ks is not None, "KV arrays already taken (donation hazard)"
        self.k = self.v = None
        return ks, vs

    def put_kv(self, ks: List[jnp.ndarray], vs: List[jnp.ndarray]) -> None:
        self.k, self.v = list(ks), list(vs)

    def take_scales(self) -> Tuple[List[jnp.ndarray], List[jnp.ndarray]]:
        """Donation hook for the quantized pool's scale arrays — the
        scales half of the donation invariant: they cross into the
        jitted step WITH the code pages (same scatter indices, same
        donate/return round-trip) and the host holds no alias while
        taken.  Returns empty lists for an unquantized pool, so callers
        need no mode branch."""
        if self.quant_mode is None:
            return [], []
        ks, vs = self.k_scale, self.v_scale
        assert ks is not None, \
            "KV scale arrays already taken (donation hazard)"
        self.k_scale = self.v_scale = None
        return ks, vs

    def put_scales(self, ks: List[jnp.ndarray],
                   vs: List[jnp.ndarray]) -> None:
        if self.quant_mode is None:
            return
        self.k_scale, self.v_scale = list(ks), list(vs)

    def gather(self, seq_ids: Sequence[int], layer: int,
               pad_to: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Materialize contiguous (B, n_kv, L, hd) K/V for a batch of
        sequences from their page tables (host-side gather — debugging /
        legacy-engine path; the executor attends the pages IN PLACE via
        ``paged_attention`` over ``device_tables``)."""
        max_len = max(self.lengths[s] for s in seq_ids)
        pad_to = pad_to or max_len
        max_pages = self.pages_needed(pad_to)
        tables = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, s in enumerate(seq_ids):
            t = self.tables[s][: max_pages]
            tables[i, : len(t)] = t
        idx = jnp.asarray(tables)                       # (B, P)
        k = jnp.take(self.k[layer], idx, axis=0)        # (B,P,page,kv,hd)
        v = jnp.take(self.v[layer], idx, axis=0)
        if self.quant_mode is not None:
            # host oracle path: dequantize the gathered pages (codes ×
            # per-token scales) so callers always see fp32 K/V
            k = quant.dequantize(
                k, jnp.take(self.k_scale[layer], idx, axis=0))
            v = quant.dequantize(
                v, jnp.take(self.v_scale[layer], idx, axis=0))
        b = len(seq_ids)
        k = k.reshape(b, max_pages * self.page_size, self.n_kv_heads,
                      self.head_dim)[:, :pad_to].transpose(0, 2, 1, 3)
        v = v.reshape(b, max_pages * self.page_size, self.n_kv_heads,
                      self.head_dim)[:, :pad_to].transpose(0, 2, 1, 3)
        lens = jnp.asarray([self.lengths[s] for s in seq_ids], jnp.int32)
        return k, v, lens

    def memory_stats(self) -> Dict[str, float]:
        # per-page resident bytes: K+V codes at the storage itemsize,
        # plus (quantized pools) the fp32 per-(token, head) scales
        page_bytes = (self.page_size * self.n_kv_heads * self.head_dim
                      * 2 * np.dtype(self.dtype).itemsize * self.n_layers)
        if self.quant_mode is not None:
            page_bytes += (self.page_size * self.n_kv_heads * 2 * 4
                           * self.n_layers)
        used = self.pool.num_pages - self.pool.num_free
        return {
            "pages_total": self.pool.num_pages,
            "pages_used": used,
            "pages_free": self.pool.num_free,
            "page_bytes": page_bytes,
            "kv_dtype": self.kv_dtype_name,
            "bytes_used": used * page_bytes,
            "kv_bytes": self.pool.num_pages * page_bytes,
            "page_hwm": self.pool.stats.page_hwm,
            "page_hwm_per_replica": list(self.pool.page_hwm_per_replica),
            "prefix_hit_rate": self.pool.stats.hit_rate,
            "cow_copies": self.pool.stats.cow_copies,
            "oom_rejections": self.pool.stats.oom_rejections,
        }
