"""Serving control plane — pure-Python scheduling over the paged KV pool.

The §5.2 separation applied to serving: everything here is host Python
(FIFO admission, chunked-prefill token budgeting, preemption, COW and
page-table maintenance); everything shape-like is bucketed so the
executor's single jitted ``unified_step`` compiles O(log) variants.

A request's lifetime is a single token cursor ``computed`` over its full
token history ``prompt + out_tokens``:

  * prefill = spans of up to ``chunk_size`` tokens per step (so a long
    prompt never blocks the decode tokens of running sequences — chunked
    prefill, no head-of-line blocking),
  * decode = the degenerate 1-token span at the end of the history,
  * the step that processes the FINAL history token samples the next
    token (argmax) — uniform across "last prefill chunk" and "decode".

Preempt/resume falls out of the same cursor: preemption frees the pages
and requeues the request AT THE FRONT with ``out_tokens`` intact;
re-admission rebuilds the history as ``prompt + out_tokens`` and prefills
from the (possibly prefix-cache-reused) start — no token is re-emitted
because sampling only happens at the end of the rebuilt history.  (The
old engine re-prefilled ``prompt`` alone and unconditionally appended a
fresh argmax token — the preemption-data-loss bug this refactor fixes.)

Scheduling policy per step (``token_budget`` tokens total):

  1. decode spans first, one token per running decode-phase sequence —
     a step can never have 0 decode tokens while decodable sequences
     exist (liveliness; violations would bump ``zero_decode_steps``),
  2. remaining budget goes to prefill chunks in admission order,
     ``chunk_size`` (env ``REPRO_PREFILL_CHUNK``) tokens max per request
     per step.

Admission is SLO-aware, not plain FIFO.  Waiting requests are ranked
by :meth:`Scheduler._admission_rank`:

  1. **aged** requests first — a request that has waited
     ``aging_steps`` plans stops being bypassed entirely (the
     starvation guard; its landing counts in ``aged_admissions``),
  2. **priority** tier (``submit(priority=...)``, higher first),
  3. **TTFT-deadline slack** — earliest-deadline-first within a tier:
     ``submitted_at + ttft_deadline_ms - now`` orders who must start
     prefilling NOW to meet its first-token SLO (deadline-less
     requests sort after every armed deadline),
  4. **tenant fair-share** — among otherwise-equal requests the tenant
     with the least tokens scheduled so far (``tenant_tokens``) goes
     first, so one chatty tenant cannot monopolize admission,
  5. submit order (``req_id``) — with default priority/tenant and no
     deadlines the whole rank degenerates to classic FIFO, which is
     what batch callers still get.

A TTFT deadline is therefore an *ordering key* at admission time, not
just an expiry check: ``ttft_deadline_misses`` counts the requests
whose deadline still lapsed (the front door's SLO regression signal).

Speculative decoding (``spec_k > 0`` + a ``spec.Proposer``) widens a
decode span: the pending token plus up to ``spec_k`` host-proposed
draft tokens travel as one multi-token segment, the executor samples a
target token at EVERY draft position in the same jitted call, and
``commit`` keeps the longest prefix where target == draft plus the
first correction token.  Rejected drafts rewind: ``kv.advance`` only
ever covers committed tokens (no stale ``filled`` counts) and
``kv.truncate`` releases the pages past the committed end (bumping the
table version so the device mirror row re-uploads).  Sampling params
(temperature/top-k/top-p/seed) ride per-request and are resolved
in-jit — see ``sampling.py`` for why this makes speculation exact at
any temperature.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .errors import (AdmissionRejected, BucketOverflow, MeshConfigError,
                     PoolExhausted)
from .kv_cache import PagedKVCache
from .sampling import SamplingParams
from .spec import Proposer


class RequestState(Enum):
    """Explicit per-request lifecycle:
    QUEUED → PREFILL → DECODE → {FINISHED, CANCELLED, TIMED_OUT,
    FAILED} (preemption loops PREFILL/DECODE back to QUEUED).  The
    last four are terminal; terminal requests live in
    ``Scheduler.done`` with pages released."""
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    FAILED = "failed"


TERMINAL = (RequestState.FINISHED, RequestState.CANCELLED,
            RequestState.TIMED_OUT, RequestState.FAILED)


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # scheduler state
    computed: int = 0            # history tokens whose compute has run
    slot: int = -1               # executor slot while RUNNING
    created_len: int = 0         # history length at (re-)admission:
                                 # writes below it are hash-pledged
                                 # prompt content, at/above it divergent
    # lifecycle / fault tolerance
    state: RequestState = RequestState.QUEUED
    sampling: SamplingParams = field(default_factory=SamplingParams)
    ttft_deadline_ms: Optional[float] = None   # first token due by
    timeout_ms: Optional[float] = None         # whole request due by
    # SLO-aware admission
    priority: int = 0            # higher = admitted earlier
    tenant: str = "default"      # fair-share accounting bucket
    error: Optional[str] = None  # why a terminal state was reached
    last_advance_step: int = 0   # scheduler step of last cursor move
    age_steps: int = 0           # steps spent QUEUED (aging guard)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    @property
    def history(self) -> List[int]:
        return self.prompt + self.out_tokens

    @property
    def in_decode(self) -> bool:
        """One history token left to process — the continuous-batching
        steady state (also the final chunk of a 1-token-tail prefill)."""
        return self.computed == len(self.prompt) + len(self.out_tokens) - 1


@dataclass
class Span:
    """One request's scheduled token span [start, end) for this step.
    ``drafts`` extends a decode span speculatively: the draft tokens
    are fed (and their K/V written) at positions ``end .. end+len-1``
    but enter ``out_tokens`` only if the executor's target samples
    agree (``Scheduler.commit``)."""
    req: Request
    start: int
    end: int
    sample: bool                 # span covers the last history token
    decode: bool                 # steady-state decode span
    drafts: List[int] = field(default_factory=list)


@dataclass
class StepPlan:
    """Host-built, bucket-padded operands for one ``unified_step``.
    K = ``spec_k`` is fixed per engine, so every operand shape below is
    constant across steps (no bucket growth from speculation)."""
    spans: List[Span]
    slot_seqs: List[int]         # slot -> seq id (-1 = empty slot),
                                 # length R*S; slot = replica*S + lane
    tokens: np.ndarray           # (T,) int32, 0-padded   [R>1: (R, T)]
    seg_ids: np.ndarray          # (T,) int32, -1 = padding; values are
                                 # replica-LOCAL lanes     [R>1: (R, T)]
    positions: np.ndarray        # (T,) int32              [R>1: (R, T)]
    write_idx: np.ndarray        # (T,) int32 replica-local flat page
                                 # slot, OOB = skip        [R>1: (R, T)]
    sample_idx: np.ndarray       # (S, K+1) int32 replica-local token-
                                 # batch rows           [R>1: (R, S, K+1)]
    sample_pos: np.ndarray       # (S,) int32 first new token [R>1: (R, S)]
    temps: np.ndarray            # (S,) f32 temperature      [R>1: (R, S)]
    top_ks: np.ndarray           # (S,) int32 top-k (0 = off) [R>1: (R, S)]
    top_ps: np.ndarray           # (S,) f32 top-p (1 = off)  [R>1: (R, S)]
    seeds: np.ndarray            # (S,) uint32 PRNG seed     [R>1: (R, S)]
    n_tokens: int                # live tokens before padding (all replicas)
    t_bucket: int                # per-replica token width
    p_bucket: int


def pow2_bucket(n: int, lo: int, hi: int) -> int:
    b = lo
    while b < n:
        b *= 2
    if b > hi:
        raise BucketOverflow(f"{n} exceeds bucket cap {hi}")
    return b


class Scheduler:
    """FIFO continuous-batching scheduler with chunked prefill."""

    def __init__(self, kv: PagedKVCache, *, max_batch: int,
                 chunk_size: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 max_pages_per_seq: Optional[int] = None,
                 min_t_bucket: int = 8, min_p_bucket: int = 4,
                 max_queue_depth: Optional[int] = None,
                 admit_hwm_frac: float = 1.0,
                 aging_steps: int = 32,
                 sampling: Optional[SamplingParams] = None,
                 spec_k: int = 0,
                 proposer: Optional[Proposer] = None,
                 n_replicas: int = 1,
                 clock: Callable[[], float] = time.perf_counter):
        self.kv = kv
        self.max_batch = max_batch
        if n_replicas < 1:
            raise MeshConfigError(f"n_replicas must be >= 1, "
                                  f"got {n_replicas}")
        if getattr(kv, "n_replicas", 1) != n_replicas:
            raise MeshConfigError(
                f"scheduler n_replicas={n_replicas} but the KV cache was "
                f"built with n_replicas={getattr(kv, 'n_replicas', 1)}")
        self.n_replicas = n_replicas
        self.total_slots = max_batch * n_replicas
        self.default_sampling = (sampling or SamplingParams()).validate()
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.spec_k = spec_k
        self.proposer = proposer
        self.chunk_size = chunk_size or int(
            os.environ.get("REPRO_PREFILL_CHUNK", "16"))
        # token_budget and max_pages_per_seq are PER-REPLICA: each data
        # replica plans its own (t_bucket,) token row against its own
        # page range, so bucket shapes don't change with replica count
        budget = token_budget or max(2 * max_batch, self.chunk_size)
        self.token_budget = pow2_bucket(max(budget, max_batch), 1, 1 << 30)
        self.max_pages_per_seq = (max_pages_per_seq
                                  or kv.pool.num_pages // n_replicas)
        self.min_t_bucket = min(min_t_bucket, self.token_budget)
        self.min_p_bucket = min(min_p_bucket,
                                pow2_bucket(self.max_pages_per_seq, 1,
                                            1 << 30))
        # admission gates: bounded queue + page-watermark backpressure
        # (defaults leave both OFF so batch callers keep FIFO-forever)
        self.max_queue_depth = max_queue_depth
        self.admit_hwm_frac = admit_hwm_frac
        self.aging_steps = aging_steps   # waiting steps before a blocked
                                         # request stops being bypassed
        self.clock = clock               # injectable for deadline tests
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self.done: Dict[int, Request] = {}    # terminal requests
        self.aborted: List[Request] = []      # CANCELLED/TIMED_OUT/FAILED
        # slot -> seq id; slot = replica * max_batch + lane (the lane is
        # the executor's replica-local segment id)
        self.slots: List[int] = [-1] * self.total_slots
        self._next_id = 0
        # tenant -> tokens scheduled (prompt at admission + emitted
        # tokens at commit): the fair-share admission key
        self.tenant_tokens: Dict[str, int] = {}
        self.metrics = {
            "steps": 0, "prefills": 0, "decoded_tokens": 0,
            "rejected_admissions": 0, "prefill_chunks": 0,
            "preemptions": 0, "zero_decode_steps": 0,
            "cancellations": 0, "timeouts": 0, "failed_requests": 0,
            "aged_admissions": 0, "rejected_submits": 0,
            "ttft_deadline_misses": 0,
            "proposed_tokens": 0, "accepted_tokens": 0, "spec_steps": 0,
        }

    # -- bucket contract --------------------------------------------------
    def t_buckets(self) -> List[int]:
        out, b = [], self.min_t_bucket
        while b <= self.token_budget:
            out.append(b)
            b *= 2
        return out

    def p_buckets(self) -> List[int]:
        cap = pow2_bucket(self.max_pages_per_seq, self.min_p_bucket,
                          1 << 30)
        out, b = [], self.min_p_bucket
        while b <= cap:
            out.append(b)
            b *= 2
        return out

    @property
    def bucket_count(self) -> int:
        return len(self.t_buckets()) * len(self.p_buckets())

    # -- admission --------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               *, sampling: Optional[SamplingParams] = None,
               ttft_deadline_ms: Optional[float] = None,
               timeout_ms: Optional[float] = None,
               priority: int = 0, tenant: str = "default") -> int:
        total = len(prompt) + max_new_tokens
        if self.kv.pages_needed(total) > self.max_pages_per_seq:
            self.metrics["rejected_submits"] += 1
            raise AdmissionRejected(
                f"request needs {self.kv.pages_needed(total)} pages, "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        if self.max_queue_depth is not None and \
                len(self.waiting) >= self.max_queue_depth:
            self.metrics["rejected_submits"] += 1
            raise AdmissionRejected(
                f"queue depth {len(self.waiting)} at "
                f"max_queue_depth={self.max_queue_depth}")
        if self.admit_hwm_frac < 1.0:
            live = self.kv.pool.num_pages - self.kv.pool.num_free
            if live >= self.admit_hwm_frac * self.kv.pool.num_pages:
                self.metrics["rejected_submits"] += 1
                raise PoolExhausted(
                    f"{live}/{self.kv.pool.num_pages} pages live >= "
                    f"admit_hwm_frac={self.admit_hwm_frac} watermark")
        req = Request(self._next_id, list(prompt), max_new_tokens,
                      submitted_at=self.clock(),
                      sampling=(sampling or
                                self.default_sampling).validate(),
                      ttft_deadline_ms=ttft_deadline_ms,
                      timeout_ms=timeout_ms,
                      priority=priority, tenant=tenant)
        self._next_id += 1
        self.waiting.append(req)
        return req.req_id

    def _free_slot(self, replica: int) -> int:
        lo = replica * self.max_batch
        for i in range(lo, lo + self.max_batch):
            if self.slots[i] < 0:
                return i
        return -1

    def _replica_of_slot(self, slot: int) -> int:
        return slot // self.max_batch

    def _candidate_replicas(self) -> List[int]:
        """Replicas with a free lane, most free pages first (ties break
        toward the lowest index so placement is deterministic)."""
        cands = [r for r in range(self.n_replicas)
                 if self._free_slot(r) >= 0]
        cands.sort(key=lambda r: (-self.kv.pool.free_in(r), r))
        return cands

    def _admission_rank(self, req: Request, now: float):
        """SLO-aware admission key (smaller admits first): aged
        requests hold the front, then priority tier (higher first),
        then TTFT-deadline slack (earliest deadline first; no deadline
        sorts last), then tenant fair-share (least tokens scheduled
        first), then submit order.  All-default submissions reduce to
        plain FIFO."""
        slack = (float("inf") if req.ttft_deadline_ms is None
                 else req.submitted_at + req.ttft_deadline_ms / 1e3 - now)
        return (0 if req.age_steps >= self.aging_steps else 1,
                -req.priority, slack,
                self.tenant_tokens.get(req.tenant, 0), req.req_id)

    def _admit(self) -> None:
        # best-effort ranked admission: a blocked request is BYPASSED
        # by lower-ranked ones that do fit — until it has waited
        # ``aging_steps`` plans, after which it ranks at the very front
        # and holds the line (starvation-free aging; the admission that
        # finally lands counts in ``aged_admissions``).  With data
        # replicas, each request lands on ONE replica (free lane + most
        # free pages): its pages, lane, and token budget all come from
        # that replica's share.
        now = self.clock()
        order = sorted(self.waiting,
                       key=lambda r: self._admission_rank(r, now))
        for req in order:
            if len(self.running) >= self.total_slots:
                break
            hist = req.history
            replica = -1
            for r in self._candidate_replicas():
                if (self.kv.can_admit(len(hist) + 1, r)
                        and self.kv.create(req.req_id, hist, r)):
                    replica = r
                    break
            if replica < 0:
                self.metrics["rejected_admissions"] += 1
                if req.age_steps >= self.aging_steps:
                    break                # aged: nobody bypasses it
                continue
            self.waiting.remove(req)
            if req.age_steps >= self.aging_steps:
                self.metrics["aged_admissions"] += 1
            self.tenant_tokens[req.tenant] = (
                self.tenant_tokens.get(req.tenant, 0) + len(hist))
            # prefix reuse skips compute too — capped by what sharers
            # have actually written (kv.lengths) — but the LAST history
            # token is always recomputed: its logits seed the next
            # sample.  Already-valid K/V is not re-written (the executor
            # keeps those rows OOB).
            req.computed = min(self.kv.lengths[req.req_id],
                               len(hist) - 1)
            req.created_len = len(hist)
            req.slot = self._free_slot(replica)
            self.slots[req.slot] = req.req_id
            self.running[req.req_id] = req
            req.state = (RequestState.DECODE if req.in_decode
                         else RequestState.PREFILL)
            req.last_advance_step = self.metrics["steps"]
            self.metrics["prefills"] += 1

    def _preempt(self, req: Request) -> None:
        """Out of pages: free everything, requeue AT THE FRONT keeping
        the generated tokens (resume re-prefills prompt + out_tokens)."""
        self.kv.free_seq(req.req_id)
        self.slots[req.slot] = -1
        req.slot = -1
        req.computed = 0
        req.state = RequestState.QUEUED
        del self.running[req.req_id]
        self.waiting.insert(0, req)
        self.metrics["preemptions"] += 1

    # -- request lifecycle -------------------------------------------------
    def _lookup(self, req_id: int) -> Optional[Request]:
        req = self.running.get(req_id)
        if req is None:
            req = next((r for r in self.waiting if r.req_id == req_id),
                       None)
        return req

    def _retire(self, req: Request, state: RequestState, reason: str,
                quarantine: bool = False) -> None:
        """Move a request to a terminal state, releasing its resources.
        ``quarantine=True`` routes page release through the suspect-
        state path (``kv.quarantine_seq`` — never walks a possibly
        corrupt table through ``pool.release``); the engine follows up
        with ``kv.recover()``."""
        if req.req_id in self.running:
            if quarantine:
                self.kv.quarantine_seq(req.req_id)
            else:
                self.kv.free_seq(req.req_id)
            if req.slot >= 0:
                self.slots[req.slot] = -1
                req.slot = -1
            del self.running[req.req_id]
        elif req in self.waiting:
            self.waiting.remove(req)
        req.state = state
        req.error = reason
        req.finished_at = self.clock()
        self.done[req.req_id] = req
        self.aborted.append(req)

    def cancel(self, req_id: int) -> bool:
        """Cancel a request at ANY lifecycle point — queued, mid-prefill
        or mid-decode.  Pages release refcount-safely (shared/COW pages
        just drop one reference; sharers keep theirs).  Returns False
        when the id is unknown or already terminal."""
        req = self._lookup(req_id)
        if req is None:
            return False
        self._retire(req, RequestState.CANCELLED, "cancelled by caller")
        self.metrics["cancellations"] += 1
        return True

    def fail(self, req_id: int, reason: str) -> bool:
        """Quarantine a request (state FAILED): its bookkeeping is
        dropped WITHOUT trusting its block table; the caller must run
        ``kv.recover()`` afterwards to reclaim + scrub the orphaned
        pages and force a device-table rebuild."""
        req = self._lookup(req_id)
        if req is None:
            return False
        self._retire(req, RequestState.FAILED, reason, quarantine=True)
        self.metrics["failed_requests"] += 1
        return True

    def timeout_all(self, reason: str) -> int:
        """Retire EVERY queued/running request as TIMED_OUT (pages
        freed) — the engine's step-cap drain.  Returns the count."""
        n = 0
        for req in list(self.running.values()) + list(self.waiting):
            self._retire(req, RequestState.TIMED_OUT, reason)
            self.metrics["timeouts"] += 1
            n += 1
        return n

    def _expire_deadlines(self) -> None:
        """Retire requests whose TTFT or total deadline has passed
        (checked every ``plan``; uses the injectable ``clock``)."""
        now = self.clock()
        for req in list(self.waiting) + list(self.running.values()):
            late: Optional[str] = None
            if req.timeout_ms is not None and \
                    now > req.submitted_at + req.timeout_ms / 1e3:
                late = f"timeout_ms={req.timeout_ms} exceeded"
            elif req.ttft_deadline_ms is not None and \
                    req.first_token_at is None and \
                    now > req.submitted_at + req.ttft_deadline_ms / 1e3:
                late = f"ttft_deadline_ms={req.ttft_deadline_ms} missed"
                self.metrics["ttft_deadline_misses"] += 1
            if late is not None:
                self._retire(req, RequestState.TIMED_OUT, late)
                self.metrics["timeouts"] += 1

    # -- step planning ----------------------------------------------------
    def plan(self) -> Optional[StepPlan]:
        """Expire deadlines, admit, pick spans under the token budget,
        maintain pages/COW, and emit bucket-padded operands.  None =
        nothing runnable."""
        self._expire_deadlines()
        for r in self.waiting:
            r.age_steps += 1
        self._admit()
        if not self.running:
            return None

        spans: List[Span] = []
        # one token budget PER data replica: each replica fills its own
        # (t_bucket,) row, so a busy replica can't starve another's
        budget = [self.token_budget] * self.n_replicas
        # priority tier first, then FIFO: req ids are issued in submit
        # order and survive preemption, so ascending id = oldest first
        # (slot index does NOT track age — a young request can land in
        # a freed low slot); a higher-priority request gets budget
        # before an older lower-priority one
        order = sorted((self.running[s] for s in self.slots if s >= 0),
                       key=lambda r: (-r.priority, r.req_id))
        # decode spans first (liveliness); speculation widens them
        for req in order:
            rep = self._replica_of_slot(req.slot)
            if not req.in_decode or budget[rep] <= 0:
                continue
            drafts: List[int] = []
            if self.spec_k > 0 and self.proposer is not None:
                cap = min(self.spec_k,
                          req.max_new_tokens - len(req.out_tokens) - 1,
                          budget[rep] - 1)
                if cap > 0:
                    drafts = list(
                        self.proposer.propose(req.history, cap))[:cap]
            span = self._reserve(req, req.computed + 1, drafts)
            if span is not None:
                spans.append(span)
                budget[rep] -= 1 + len(span.drafts)
                if span.drafts:
                    self.metrics["spec_steps"] += 1
                    self.metrics["proposed_tokens"] += len(span.drafts)
        # prefill chunks with whatever budget remains
        for req in order:
            if req.req_id not in self.running or req.in_decode:
                continue
            rep = self._replica_of_slot(req.slot)
            if budget[rep] <= 0:
                continue
            end = min(req.computed + min(self.chunk_size, budget[rep]),
                      len(req.history))
            span = self._reserve(req, end)
            if span is not None:
                spans.append(span)
                budget[rep] -= span.end - span.start
                self.metrics["prefill_chunks"] += 1

        # liveliness: a STILL-decodable sequence (not OOM-preempted
        # above) with no decode span this step is starvation
        if not any(s.decode for s in spans) and any(
                r.req_id in self.running and r.in_decode for r in order):
            self.metrics["zero_decode_steps"] += 1
        if not spans:
            return None
        return self._pad(spans)

    def _reserve(self, req: Request, end: int,
                 drafts: Sequence[int] = ()) -> Optional[Span]:
        """Allocate pages + COW-protect the span's written range; preempt
        the request itself when the pool is dry.  ``drafts`` extend the
        reservation past ``end`` (always-divergent speculative writes);
        when the pool can't cover the speculative tail the drafts are
        shed FIRST and the span degrades to a plain reservation."""
        start = req.computed
        end_spec = end + len(drafts)
        write_from = max(start, self.kv.lengths[req.req_id])
        divergent = end > req.created_len
        ok = (self.kv.ensure_capacity(req.req_id, end_spec)
              and self.kv.make_writable(req.req_id, write_from,
                                        max(end, write_from),
                                        divergent=divergent)
              and self.kv.make_writable(req.req_id, max(end, write_from),
                                        max(end_spec, write_from),
                                        divergent=True))
        if not ok:
            if drafts:
                self.kv.truncate(req.req_id,
                                 max(end, self.kv.lengths[req.req_id]))
                return self._reserve(req, end)
            self._preempt(req)
            return None
        last = len(req.history) - 1
        return Span(req, start, end, sample=end > last,
                    decode=req.in_decode, drafts=list(drafts))

    def _pad(self, spans: List[Span]) -> StepPlan:
        """Bucket-pad the step's spans into executor operands.  With
        data replicas every token/sample array grows a leading replica
        axis (R, ·): replica r's row holds ONLY its own spans, segment
        ids are replica-LOCAL lanes, and write/sample indices are local
        to the replica's page range / token row — the executor vmaps
        one body over the axis, so per-replica shapes (and hence the
        compiled bucket set) are IDENTICAL to the single-device plan.
        R == 1 squeezes the axis away (bit-for-bit the old layout)."""
        kv = self.kv
        R, S = self.n_replicas, self.max_batch
        n = sum(s.end - s.start + len(s.drafts) for s in spans)
        counts = [0] * R
        for s in spans:
            counts[self._replica_of_slot(s.req.slot)] += \
                s.end - s.start + len(s.drafts)
        t_bucket = pow2_bucket(max(counts), self.min_t_bucket,
                               self.token_budget)
        max_pages = max(len(kv.tables[s.req.req_id]) for s in spans)
        p_bucket = pow2_bucket(max_pages, self.min_p_bucket,
                               pow2_bucket(self.max_pages_per_seq,
                                           self.min_p_bucket, 1 << 30))

        tokens = np.zeros((R, t_bucket), np.int32)
        seg = np.full((R, t_bucket), -1, np.int32)
        pos = np.zeros((R, t_bucket), np.int32)
        oob = kv.pages_per_replica * kv.page_size    # replica-local OOB
        widx = np.full((R, t_bucket), oob, np.int32)
        kp1 = self.spec_k + 1
        sample_idx = np.zeros((R, S, kp1), np.int32)
        sample_pos = np.zeros((R, S), np.int32)
        temps = np.zeros((R, S), np.float32)
        top_ks = np.zeros((R, S), np.int32)
        top_ps = np.ones((R, S), np.float32)
        seeds = np.zeros((R, S), np.uint32)

        cursors = [0] * R
        for s in spans:
            req_id = s.req.req_id
            rep = self._replica_of_slot(s.req.slot)
            lane = s.req.slot - rep * S
            cursor = cursors[rep]
            hist = s.req.history
            m = s.end - s.start + len(s.drafts)
            sl = slice(cursor, cursor + m)
            tokens[rep, sl] = hist[s.start:s.end] + s.drafts
            seg[rep, sl] = lane
            pos[rep, sl] = np.arange(s.start, s.start + m)
            # reused-prefix tokens recomputed for logits keep their
            # already-valid K/V: skip the write (stays OOB)
            wfrom = max(s.start, kv.lengths[req_id])
            if s.start + m > wfrom:
                off = (kv.seq_replica.get(req_id, 0)
                       * kv.pages_per_replica * kv.page_size)
                widx[rep, cursor + (wfrom - s.start): cursor + m] = \
                    kv.flat_slots(req_id, wfrom, s.start + m) - off
            if s.sample:
                # one sample row per new token: the pending token's row
                # plus one per draft (rows of the last 1+len(drafts)
                # fed tokens); unused tail entries repeat the last row
                n_s = 1 + len(s.drafts)
                rows = cursor + (m - n_s) + np.arange(n_s)
                sample_idx[rep, lane, :n_s] = rows
                sample_idx[rep, lane, n_s:] = rows[-1]
                sample_pos[rep, lane] = s.end
                sp = s.req.sampling
                temps[rep, lane] = sp.temperature
                top_ks[rep, lane] = sp.top_k
                top_ps[rep, lane] = sp.top_p
                seeds[rep, lane] = np.uint32(sp.seed & 0xFFFFFFFF)
            cursors[rep] += m
        arrs = [tokens, seg, pos, widx, sample_idx, sample_pos,
                temps, top_ks, top_ps, seeds]
        if R == 1:
            arrs = [a[0] for a in arrs]
        return StepPlan(spans=spans, slot_seqs=list(self.slots),
                        tokens=arrs[0], seg_ids=arrs[1], positions=arrs[2],
                        write_idx=arrs[3], sample_idx=arrs[4],
                        sample_pos=arrs[5], temps=arrs[6],
                        top_ks=arrs[7], top_ps=arrs[8], seeds=arrs[9],
                        n_tokens=n, t_bucket=t_bucket, p_bucket=p_bucket)

    # -- step commit ------------------------------------------------------
    def commit(self, plan: StepPlan, next_tokens: np.ndarray
               ) -> List[Request]:
        """Apply a step's results: advance cursors/lengths, append
        sampled tokens, retire finished requests (pages released for the
        very next admission).

        ``next_tokens`` is the executor's ``(S, K+1)`` target-token
        matrix.  For a speculative span the acceptance rule is the
        standard greedy-verify prefix: with drafts ``d[0..L)`` and
        target row ``t``, keep ``j = |longest prefix with
        t[i] == d[i]|`` drafts plus the correction token ``t[j]`` —
        exactly the tokens a non-speculative loop would have emitted
        (``sampling.py`` pins the PRNG to (seed, position), so ``t[i]``
        IS the non-speculative sample at that position).  Rejected
        drafts rewind: the cursor and ``kv.advance`` stop at the
        committed end and ``kv.truncate`` releases the speculative-tail
        pages (no leaked refcounts, no stale ``filled`` counts)."""
        finished: List[Request] = []
        self.metrics["steps"] += 1
        for s in plan.spans:
            req = s.req
            if self.running.get(req.req_id) is not req:
                continue             # retired mid-step (cancel/fail)
            if not s.sample:         # pure prefill chunk: cursor only
                req.computed = s.end
                req.last_advance_step = self.metrics["steps"]
                self.kv.advance(req.req_id, s.end)
                req.state = (RequestState.DECODE if req.in_decode
                             else RequestState.PREFILL)
                continue
            row = next_tokens[req.slot]
            j = 0
            while j < len(s.drafts) and int(row[j]) == s.drafts[j]:
                j += 1
            room = req.max_new_tokens - len(req.out_tokens)
            take = min(j + 1, room)  # plan() caps drafts so take==j+1;
            toks = (s.drafts[:j] + [int(row[j])])[:take]
            req.out_tokens.extend(toks)
            self.tenant_tokens[req.tenant] = (
                self.tenant_tokens.get(req.tenant, 0) + len(toks))
            # accepted drafts were computed in-step; the correction
            # token was only SAMPLED — its compute runs next step
            req.computed = s.end + min(j, take)
            req.last_advance_step = self.metrics["steps"]
            self.kv.advance(req.req_id, req.computed)
            if s.drafts:
                self.metrics["accepted_tokens"] += min(j, take)
                if j < len(s.drafts):
                    # rejected tail: drop its pages past the next
                    # pending token's page (version bump re-uploads
                    # the device table row)
                    self.kv.truncate(req.req_id, req.computed + 1)
            if req.first_token_at is None:
                req.first_token_at = self.clock()
            if s.decode:
                self.metrics["decoded_tokens"] += len(toks)
            if req.done:
                req.state = RequestState.FINISHED
                req.finished_at = self.clock()
                self.kv.free_seq(req.req_id)
                self.slots[req.slot] = -1
                req.slot = -1
                del self.running[req.req_id]
                self.done[req.req_id] = req
                finished.append(req)
                continue
            # state AFTER any append: a request that just sampled its
            # first token is now in steady-state decode, not prefill
            req.state = (RequestState.DECODE if req.in_decode
                         else RequestState.PREFILL)
        return finished
