"""Per-step invariant watchdog for the serving stack.

The scheduler/executor split makes the control plane pure host Python —
which means its load-bearing invariants are CHECKABLE host-side, every
step, without touching the device:

  * **refcount conservation** — ``allocated == freed + held`` and
    ``held + free == total`` on the page pool, and the pool's refcounts
    must equal the reference counts implied by the live block tables
    (a leaked page or a double-retain shows up here);
  * **table coherence** — every page id in a running sequence's block
    table must be a live, in-range page (a corrupted row is caught
    before it can serve garbage for more than one step);
  * **per-sequence progress** — a decodable sequence whose cursor has
    not advanced in ``stall_steps`` scheduler steps is wedged (an
    executor or commit dysfunction that would otherwise hold its slot
    and pages forever).

The engine runs :meth:`Watchdog.check` every ``interval`` steps and
QUARANTINES the offending sequence on violation: the request lands in
``FAILED``, its pages are reclaimed through the pool-reconciliation
path (``PagedKVCache.recover``), the device table mirror is force-
rebuilt, and the step loop keeps serving everyone else.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import List, Optional

__all__ = ["Violation", "Watchdog"]


@dataclass
class Violation:
    """One detected invariant break.  ``seq_id`` names the offending
    sequence when the break is attributable (table corruption, stall);
    ``None`` means a global inconsistency repaired by reconciliation."""
    kind: str                    # "table" | "refcount" | "stall"
    seq_id: Optional[int]
    detail: str


class Watchdog:
    """Host-side invariant checker over (scheduler, kv) state."""

    def __init__(self, *, interval: int = 8, stall_steps: int = 64):
        self.interval = max(1, interval)
        self.stall_steps = stall_steps
        self.trips = 0

    def due(self, step_no: int) -> bool:
        """True when ``step_no`` is a checking step."""
        return step_no % self.interval == 0

    def check(self, scheduler, kv) -> List[Violation]:
        """Run all invariant checks; returns violations (may be empty).
        Pure inspection — the ENGINE applies quarantine/recovery."""
        out: List[Violation] = []
        pool = kv.pool
        corrupt: set = set()

        # 1. table coherence for running sequences
        for sid in list(scheduler.running):
            table = kv.tables.get(sid)
            if table is None:
                out.append(Violation("table", sid, "running seq has no "
                                     "block table"))
                corrupt.add(sid)
                continue
            for p in table:
                if not (0 <= p < pool.num_pages) or p not in pool.refs:
                    out.append(Violation(
                        "table", sid,
                        f"seq {sid} table references dead/out-of-range "
                        f"page {p}"))
                    corrupt.add(sid)
                    break

        # 2. refcount conservation (skip tables already known corrupt —
        # their quarantine will be followed by a reconcile)
        st = pool.stats
        held = len(pool.refs)
        if st.allocated_pages != st.freed_pages + held:
            out.append(Violation(
                "refcount", None,
                f"allocated({st.allocated_pages}) != "
                f"freed({st.freed_pages}) + held({held})"))
        if held + pool.num_free != pool.num_pages:
            out.append(Violation(
                "refcount", None,
                f"held({held}) + free({pool.num_free}) != "
                f"total({pool.num_pages})"))
        expected = Counter(p for sid, t in kv.tables.items()
                           if sid not in corrupt for p in t)
        expected.update(kv.external_refs)    # e.g. fault-injector holds
        if not corrupt and dict(expected) != pool.refs:
            drift = {p: (expected.get(p, 0), pool.refs.get(p, 0))
                     for p in set(expected) | set(pool.refs)
                     if expected.get(p, 0) != pool.refs.get(p, 0)}
            out.append(Violation(
                "refcount", None,
                f"table-implied refcounts != pool refcounts: {drift}"))

        # 3. per-sequence progress
        steps = scheduler.metrics["steps"]
        for sid, req in list(scheduler.running.items()):
            if sid in corrupt:
                continue
            if req.in_decode and \
                    steps - req.last_advance_step >= self.stall_steps:
                out.append(Violation(
                    "stall", sid,
                    f"seq {sid} decodable but stuck for "
                    f"{steps - req.last_advance_step} steps"))
        self.trips += len(out)
        return out
