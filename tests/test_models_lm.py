"""LM model machinery: block families, decode==prefill parity, training
convergence, unroll==scan, loss math."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import (BlockSpec, LMConfig, abstract_cache,
                             abstract_params, decode_step, forward,
                             init_cache, init_params, lm_loss)

BASE = dict(param_dtype=jnp.float32, remat="none", attn_backend="ref")


def tiny(name, **kw):
    args = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=97, **BASE)
    args.update(kw)
    return LMConfig(name=name, **args)


def rollout_parity(cfg, seq=10, batch=2, rtol=5e-3):
    params = init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (batch, seq), 0,
                                cfg.vocab_size)
    logits, _ = forward(cfg, params, tokens)
    assert not bool(jnp.isnan(logits).any())
    cache = init_cache(cfg, batch, 16, jnp.float32)
    for t in range(seq):
        lg, cache = decode_step(cfg, params, cache, tokens[:, t:t + 1],
                                jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits[:, -1]),
                               rtol=rtol, atol=rtol)


class TestFamilies:
    def test_dense_gqa(self):
        rollout_parity(tiny("t"))

    def test_mqa(self):
        rollout_parity(tiny("t", n_kv_heads=1))

    def test_sliding_ring_buffer(self):
        rollout_parity(tiny("t", window=4,
                            pattern=(BlockSpec("sliding"),
                                     BlockSpec("attn"))))

    def test_mla(self):
        rollout_parity(tiny("t", n_layers=2, q_lora_rank=32,
                            kv_lora_rank=16, mla_nope_dim=16,
                            mla_rope_dim=8, mla_v_dim=16,
                            pattern=(BlockSpec("mla"),)))

    def test_mamba(self):
        rollout_parity(tiny("t", n_layers=2,
                            pattern=(BlockSpec("mamba", "dense"),)))

    def test_rwkv(self):
        rollout_parity(tiny("t", n_layers=2,
                            pattern=(BlockSpec("rwkv", "none"),)))

    def test_jamba_hybrid_pattern(self):
        pattern = tuple(
            BlockSpec(mixer=("attn" if i == 2 else "mamba"),
                      ffn=("moe" if i % 2 else "dense"))
            for i in range(4))
        # dropless capacity so prefill matches (dropless) decode exactly
        rollout_parity(tiny("t", n_layers=4, pattern=pattern, n_experts=4,
                            top_k=2, capacity_factor=2.0))

    def test_tail_layers(self):
        cfg = tiny("t", n_layers=5, window=4,
                   pattern=(BlockSpec("sliding"), BlockSpec("attn")))
        rollout_parity(cfg)

    def test_encoder_bidirectional(self):
        cfg = tiny("t", causal=False, rope_theta=None, lm_head=False,
                   n_classes=10, gated_mlp=False, norm="layer",
                   input_mode="embeddings")
        params = init_params(cfg, jax.random.key(0))
        emb = jax.random.normal(jax.random.key(2), (2, 8, 64))
        out, _ = forward(cfg, params, embeds=emb)
        assert out.shape == (2, 8, 10)
        # bidirectionality: last frame influences first output (use a
        # single-channel perturbation — a constant all-channel shift sits
        # in LayerNorm's null space!)
        emb2 = emb.at[:, -1, 0].add(10.0)
        out2, _ = forward(cfg, params, embeds=emb2)
        assert not np.allclose(np.asarray(out[:, 0]),
                               np.asarray(out2[:, 0]))


class TestStructure:
    def test_unroll_equals_scan(self):
        cfg = tiny("t")
        p = init_params(cfg, jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (2, 8), 0, 97)
        l1, _ = forward(cfg, p, tok)
        l2, _ = forward(replace(cfg, unroll_groups=True), p, tok)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-4, atol=1e-5)

    def test_remat_matches_no_remat(self):
        cfg = tiny("t")
        p = init_params(cfg, jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (2, 8), 0, 97)
        l1, _ = forward(cfg, p, tok)
        l2, _ = forward(replace(cfg, remat="full"), p, tok)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5)

    def test_abstract_params_match_real(self):
        cfg = tiny("t")
        abs_p = abstract_params(cfg)
        real_p = init_params(cfg, jax.random.key(0))
        ja, jr = jax.tree_util.tree_leaves(abs_p), \
            jax.tree_util.tree_leaves(real_p)
        assert len(ja) == len(jr)
        for a, r in zip(ja, jr):
            assert a.shape == r.shape and a.dtype == r.dtype

    def test_abstract_cache_match_real(self):
        cfg = tiny("t", pattern=(BlockSpec("mamba", "dense"),
                                 BlockSpec("attn", "dense")))
        ca = abstract_cache(cfg, 2, 16, jnp.float32)
        cr = init_cache(cfg, 2, 16, jnp.float32)
        for a, r in zip(jax.tree_util.tree_leaves(ca),
                        jax.tree_util.tree_leaves(cr)):
            assert a.shape == r.shape and a.dtype == r.dtype

    def test_moe_capacity_drops_are_bounded(self):
        cfg = tiny("t", n_layers=1, n_experts=4, top_k=1,
                   capacity_factor=0.5,
                   pattern=(BlockSpec("attn", "moe"),))
        p = init_params(cfg, jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
        logits, aux = forward(cfg, p, tok)
        assert not bool(jnp.isnan(logits).any())
        assert float(aux) > 0.0


class TestTraining:
    def test_loss_decreases_overfit(self):
        cfg = tiny("t", n_layers=2, vocab_size=31)
        params = init_params(cfg, jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (4, 16), 0, 31)
        batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

        loss_fn = jax.jit(lambda p: lm_loss(cfg, p, batch))
        grad_fn = jax.jit(jax.grad(lambda p: lm_loss(cfg, p, batch)))
        l0 = float(loss_fn(params))
        for _ in range(30):
            g = grad_fn(params)
            params = jax.tree_util.tree_map(
                lambda p, gg: p - 0.05 * gg.astype(p.dtype), params, g)
        l1 = float(loss_fn(params))
        assert l1 < l0 * 0.7, (l0, l1)

    def test_loss_masking(self):
        cfg = tiny("t", n_layers=1, vocab_size=13)
        p = init_params(cfg, jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (2, 8), 0, 13)
        full = lm_loss(cfg, p, {"tokens": tok, "labels": tok,
                                "mask": jnp.ones((2, 8))})
        half_mask = jnp.concatenate(
            [jnp.ones((2, 4)), jnp.zeros((2, 4))], axis=1)
        half = lm_loss(cfg, p, {"tokens": tok, "labels": tok,
                                "mask": half_mask})
        assert float(full) != float(half)

    def test_ce_matches_reference(self):
        """The vocab-sharded-safe CE must equal standard CE."""
        cfg = tiny("t", n_layers=1, vocab_size=19)
        p = init_params(cfg, jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (2, 8), 0, 19)
        batch = {"tokens": tok, "labels": tok}
        loss = lm_loss(cfg, p, batch, z_loss=0.0)
        logits, aux = forward(cfg, p, tok)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ref = -jnp.take_along_axis(lp, tok[..., None], axis=-1).mean()
        np.testing.assert_allclose(float(loss), float(ref + aux),
                                   rtol=1e-5)
