"""Front door: async streaming, mid-stream cancellation, watermark
backpressure, and SLO-aware admission — all on the deterministic
FakeClock harness (no tier-1 test here sleeps on wall time except the
real-socket HTTP smoke, which is event-driven)."""

import asyncio
import random

import jax
import pytest

from repro.models.lm import init_params
from repro.serving.engine import ServingEngine
from repro.serving.errors import AdmissionRejected, BackpressureRejected
from repro.serving.frontend import AsyncFrontend
from repro.serving.scheduler import TERMINAL, RequestState

from clockutil import FakeClock
from test_serving import dense_rollout, tiny_cfg


def run(coro):
    """Run an async test body on a fresh event loop."""
    return asyncio.run(coro)


async def spin(n: int = 4):
    """Yield the loop ``n`` times so queue consumers drain."""
    for _ in range(n):
        await asyncio.sleep(0)


def make_engine(**kw):
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.key(0))
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_batch", 4)
    clk = kw.pop("clock", None) or FakeClock()
    return ServingEngine(cfg, params, clock=clk, **kw), clk


async def consume(fe, prompt, mnt, events, **kw):
    """Standard consumer: append every StreamEvent; record a typed
    admission rejection as the string 'rejected'."""
    try:
        async for ev in fe.stream(prompt, mnt, **kw):
            events.append(ev)
    except AdmissionRejected:
        events.append("rejected")


def pool_conserved(eng):
    """KV refcount conservation: allocated == freed + held, and held
    pages + free pages == the pool."""
    pool = eng.kv.pool
    held = len(pool.refs)
    st = pool.stats
    return (st.allocated_pages == st.freed_pages + held
            and held + pool.num_free == pool.num_pages)


class TestStreaming:
    def test_stream_matches_dense_oracle(self):
        async def main():
            eng, _ = make_engine()
            fe = AsyncFrontend(eng)
            prompt, n_new = [1, 2, 3, 4, 5], 6
            events = []
            task = asyncio.ensure_future(
                consume(fe, prompt, n_new, events))
            await spin()
            while fe.busy and not task.done():
                fe.pump()
                await spin()
            await task
            toks = [e.token for e in events if e.kind == "token"]
            terminals = [e for e in events if e.terminal]
            cfg = tiny_cfg()
            oracle = dense_rollout(cfg, init_params(cfg,
                                                    jax.random.key(0)),
                                   prompt, n_new)
            assert len(terminals) == 1
            assert terminals[0].kind == "finished"
            assert [e.index for e in events if e.kind == "token"] \
                == list(range(len(toks)))
            assert fe.metrics["tokens_dropped"] == 0
            assert eng.kv.pool.num_free == eng.kv.pool.num_pages
            return toks, oracle

        toks, oracle = run(main())
        assert toks == oracle

    def test_cancel_mid_stream_frees_pages_immediately(self):
        async def main():
            eng, _ = make_engine()
            fe = AsyncFrontend(eng)
            got = []
            agen = fe.stream([1, 2, 3, 4, 5, 6, 7, 8], 64)
            # pull two tokens, then walk away mid-decode
            while len(got) < 2:
                t = asyncio.ensure_future(agen.__anext__())
                await spin()                   # let the body submit
                while not t.done():
                    fe.pump()
                    await spin()
                ev = await t
                assert ev.kind == "token"      # budget 64: no terminal yet
                got.append(ev.token)
            assert eng.running                 # mid-decode, holding pages
            await agen.aclose()                # disconnect
            # cancellation is synchronous: pages free NOW, same tick
            assert eng.kv.pool.num_free == eng.kv.pool.num_pages
            rid = next(iter(eng.scheduler.done))
            assert eng.scheduler.done[rid].state is RequestState.CANCELLED
            assert fe.metrics["client_cancelled"] == 1
            assert fe.metrics["tokens_dropped"] == 0
            assert not fe._streams             # nothing stuck

        run(main())

    def test_disconnect_before_first_token_cancels_queued(self):
        async def main():
            eng, _ = make_engine()
            fe = AsyncFrontend(eng)
            # aclose before the first __anext__ never starts the
            # generator body: nothing submitted, nothing to clean
            agen = fe.stream([1, 2, 3], 8)
            await agen.aclose()
            assert not eng.scheduler.waiting
            assert not fe._streams

            # the submitted-but-unserved variant: body ran (request
            # queued), consumer walks away before any pump
            agen2 = fe.stream([4, 5, 6], 8)
            task = asyncio.ensure_future(agen2.__anext__())
            await spin()                       # body runs -> submitted
            assert len(eng.scheduler.waiting) == 1
            task.cancel()
            await spin()
            await agen2.aclose()
            assert not eng.scheduler.waiting   # cancelled out of queue
            assert eng.kv.pool.num_free == eng.kv.pool.num_pages
            assert not fe._streams

        run(main())

    def test_max_stream_tokens_caps_budget(self):
        async def main():
            eng, _ = make_engine()
            fe = AsyncFrontend(eng, max_stream_tokens=3)
            events = []
            task = asyncio.ensure_future(
                consume(fe, [1, 2, 3, 4], 100, events))
            await spin()
            while not task.done():
                fe.pump()
                await spin()
            await task
            toks = [e for e in events if e.kind == "token"]
            assert len(toks) == 3              # budget clamped
            assert events[-1].terminal

        run(main())


class TestBackpressure:
    def saturate(self, eng, n_tokens):
        """Hold pages via a raw KV sequence (no scheduler involvement)
        so live-page fraction is exact and deterministic."""
        assert eng.kv.create(999, list(range(n_tokens)))

    def test_low_priority_shed_high_priority_meets_deadline(self):
        async def main():
            clk = FakeClock()
            eng, _ = make_engine(num_pages=16, clock=clk)
            fe = AsyncFrontend(eng, hwm_frac=0.95,
                               low_priority_hwm_frac=0.5,
                               retry_after_s=2.5)
            self.saturate(eng, 32)             # 8/16 pages live = 0.5
            # low priority: at the 0.5 watermark -> typed shed
            with pytest.raises(BackpressureRejected) as ei:
                await fe.stream([1, 2, 3], 4, priority=0).__anext__()
            assert isinstance(ei.value, AdmissionRejected)  # satellite
            assert ei.value.retry_after_s == 2.5
            assert fe.metrics["backpressure_rejections"] == 1
            # high priority: below the 0.95 watermark -> serves, and
            # its TTFT deadline is met (no misses) under the fake clock
            events = []
            task = asyncio.ensure_future(consume(
                fe, [1, 2, 3], 4, events, priority=1,
                ttft_deadline_ms=1e4))
            await spin()
            while not task.done():
                fe.pump()
                clk.advance(0.001)
                await spin()
            await task
            assert events[-1].kind == "finished"
            assert eng.metrics["ttft_deadline_misses"] == 0
            eng.kv.free_seq(999)

        run(main())

    def test_queue_depth_gate_carries_retry_after(self):
        async def main():
            eng, _ = make_engine()
            fe = AsyncFrontend(eng, max_queue_depth=1,
                               retry_after_s=0.25)
            agen = fe.stream([1, 2, 3], 4)
            t = asyncio.ensure_future(agen.__anext__())
            await spin()                       # first request queued
            with pytest.raises(BackpressureRejected) as ei:
                await fe.stream([4, 5, 6], 4).__anext__()
            assert ei.value.retry_after_s == 0.25
            t.cancel()
            await spin()
            await agen.aclose()

        run(main())


class TestSLOAdmission:
    def test_edf_orders_queued_admission(self):
        eng, _ = make_engine(max_batch=1)
        rid_a = eng.submit([1, 2, 3], max_new_tokens=2)
        rid_b = eng.submit([4, 5, 6], max_new_tokens=2,
                           ttft_deadline_ms=50.0)
        eng.step()
        # one slot: the deadline-bearing request wins it (EDF), even
        # though it arrived second
        assert rid_b in eng.running
        assert rid_a not in eng.running

    def test_priority_beats_fifo(self):
        eng, _ = make_engine(max_batch=1)
        rid_a = eng.submit([1, 2, 3], max_new_tokens=2)
        rid_b = eng.submit([4, 5, 6], max_new_tokens=2, priority=5)
        eng.step()
        assert rid_b in eng.running
        assert rid_a not in eng.running

    def test_tenant_fair_share_prefers_lighter_tenant(self):
        eng, _ = make_engine(max_batch=1)
        rid = eng.submit([1, 2, 3, 4], max_new_tokens=2, tenant="heavy")
        assert [r.req_id for r in eng.run()] == [rid]
        assert eng.scheduler.tenant_tokens["heavy"] > 0
        rid_h = eng.submit([5, 6, 7], max_new_tokens=2, tenant="heavy")
        rid_l = eng.submit([8, 9, 10], max_new_tokens=2, tenant="light")
        eng.step()
        # same priority, no deadlines: the tenant with fewer scheduled
        # tokens is admitted first despite the later req_id
        assert rid_l in eng.running
        assert rid_h not in eng.running

    def test_defaults_degenerate_to_fifo(self):
        eng, _ = make_engine(max_batch=1)
        rid_a = eng.submit([1, 2, 3], max_new_tokens=2)
        eng.submit([4, 5, 6], max_new_tokens=2)
        eng.step()
        assert rid_a in eng.running

    def test_ttft_deadline_miss_counted(self):
        clk = FakeClock()
        eng, _ = make_engine(max_batch=1, clock=clk)
        eng.submit([1, 2, 3, 4], max_new_tokens=30)
        eng.step()                             # hog takes the slot
        rid = eng.submit([9, 8, 7], max_new_tokens=4,
                         ttft_deadline_ms=50.0)
        clk.advance(0.1)
        eng.step()
        assert eng.scheduler.done[rid].state is RequestState.TIMED_OUT
        assert eng.metrics["ttft_deadline_misses"] == 1

    def test_aging_prevents_priority_starvation(self):
        # one slot + a stream of priority-9 arrivals would starve the
        # priority-0 request forever; aging ranks it to the very front
        # after ``aging_steps`` bypasses
        eng, _ = make_engine(max_batch=1, aging_steps=3)
        rid_low = eng.submit([1, 2, 3], max_new_tokens=2, priority=0)
        hi = [eng.submit([10 + i, 11, 12], max_new_tokens=1, priority=9)
              for i in range(2)]
        for _ in range(40):
            if rid_low in eng.scheduler.done:
                break
            # keep high-priority pressure up: top the queue back up
            if len(eng.scheduler.waiting) < 2 \
                    and eng.metrics["aged_admissions"] == 0:
                hi.append(eng.submit([20, 21, 22], max_new_tokens=1,
                                     priority=9))
            eng.step()
        assert rid_low in eng.scheduler.done
        assert eng.scheduler.done[rid_low].state is RequestState.FINISHED
        assert eng.metrics["aged_admissions"] >= 1


class TestChurnProperty:
    """Satellite: randomized client churn against the frontend.
    Invariants: KV refcount conservation at every pump, exactly one
    terminal event per completed stream, zero dropped tokens, zero
    stuck streams."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_randomized_churn_conserves_and_terminates(self, seed):
        async def main():
            rng = random.Random(seed)
            clk = FakeClock()
            eng, _ = make_engine(num_pages=32, max_batch=3, clock=clk)
            fe = AsyncFrontend(eng, hwm_frac=1.0)
            streams = []                       # (events, task)
            for round_no in range(30):
                act = rng.random()
                if act < 0.45 and len(streams) < 8:
                    events = []
                    prompt = [rng.randrange(1, 96)
                              for _ in range(rng.choice([3, 5, 9]))]
                    t = asyncio.ensure_future(consume(
                        fe, prompt, rng.choice([2, 4, 8]), events,
                        priority=rng.choice([0, 1]),
                        tenant=rng.choice(["a", "b"])))
                    streams.append((events, t))
                elif act < 0.60 and eng.running:
                    # cancel-mid-decode from the server side
                    eng.cancel(rng.choice(list(eng.running)))
                elif act < 0.75 and streams:
                    # client disconnect: kill a random consumer task
                    _, t = rng.choice(streams)
                    if not t.done():
                        t.cancel()
                fe.pump()
                clk.advance(0.01)
                await spin()
                assert pool_conserved(eng), f"round {round_no}"
            # drain: pump until every consumer task resolves
            for _ in range(200):
                if all(t.done() for _, t in streams) and not fe.busy:
                    break
                fe.pump()
                await spin()
            for _, t in streams:
                if not t.done():
                    t.cancel()
                try:
                    await t
                except asyncio.CancelledError:
                    pass
            await spin()
            # exactly one terminal event per stream that got events
            for events, t in streams:
                terms = [e for e in events
                         if e != "rejected" and e.terminal]
                assert len(terms) <= 1
                if events and not t.cancelled() \
                        and "rejected" not in events:
                    assert len(terms) == 1
            assert fe.metrics["tokens_dropped"] == 0
            assert not fe._streams             # zero stuck streams
            assert pool_conserved(eng)
            # frontend held nothing: cancel the raw engine leftovers
            eng.drain()
            assert eng.kv.pool.num_free == eng.kv.pool.num_pages

        run(main())


class TestHttpServer:
    """Real-socket smoke over the raw-asyncio SSE server."""

    def test_sse_roundtrip_metrics_and_503(self):
        from repro.launch.server import HttpFrontendServer, sse_client

        async def main():
            eng, _ = make_engine(num_pages=32)
            fe = AsyncFrontend(eng, hwm_frac=0.95,
                               low_priority_hwm_frac=0.4,
                               idle_sleep_s=0.001)
            server = HttpFrontendServer(fe, "127.0.0.1", 0)
            await server.start()
            try:
                # full stream
                toks, terminal = [], None
                async for ev, data in sse_client(
                        "127.0.0.1", server.port,
                        {"prompt": [1, 2, 3, 4], "max_new_tokens": 3}):
                    if ev == "token":
                        toks.append(data["token"])
                    else:
                        terminal = ev
                assert terminal == "finished"
                assert len(toks) == 3
                # walk away after 1 event: server must cancel + free
                async for ev, data in sse_client(
                        "127.0.0.1", server.port,
                        {"prompt": [5, 6, 7, 8], "max_new_tokens": 64},
                        max_events=1):
                    pass
                for _ in range(500):           # bounded, event-driven
                    if not eng.scheduler.running \
                            and not eng.scheduler.waiting:
                        break
                    await asyncio.sleep(0.01)
                assert not eng.scheduler.running
                assert eng.kv.pool.num_free == eng.kv.pool.num_pages
                # saturated pool -> low-priority 503 + Retry-After
                assert eng.kv.create(999, list(range(64)))  # 16/32 live
                got = []
                async for ev, data in sse_client(
                        "127.0.0.1", server.port,
                        {"prompt": [1, 2], "max_new_tokens": 2}):
                    got.append((ev, data))
                assert got == [("http_error", got[0][1])]
                assert got[0][1]["status"] == 503
                assert got[0][1]["retry_after"] is not None
                eng.kv.free_seq(999)
                # metrics endpoint
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", server.port)
                writer.write(b"GET /metrics HTTP/1.1\r\n"
                             b"Host: x\r\n\r\n")
                await writer.drain()
                raw = await reader.read()
                writer.close()
                body = raw.split(b"\r\n\r\n", 1)[1]
                import json as _json
                stats = _json.loads(body)
                assert stats["streams_finished"] >= 1
                assert stats["tokens_dropped"] == 0
            finally:
                await server.stop()

        run(main())
