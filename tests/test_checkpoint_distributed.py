"""Checkpointing (atomic/async/elastic) and the distributed stack
(sharding rules, DDP, pipeline, multi-device train step) — the
device-count-dependent parts run in subprocesses with
``--xla_force_host_platform_device_count``."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "step": jnp.int32(7)}
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(state, 7)
        restored = mgr.restore_latest(state)
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                                   np.asarray(state["params"]["w"]))
        assert int(restored["step"]) == 7

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save({"x": jnp.ones(3)}, 1)
        names = os.listdir(tmp_path)
        assert "step_1" in names
        assert not any(n.endswith(".tmp") for n in names)
        assert os.path.exists(tmp_path / "step_1" / "manifest.json")

    def test_keep_n_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        for s in (1, 2, 3, 4):
            mgr.save({"x": jnp.ones(2) * s}, s)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async({"x": jnp.ones(4)}, 5)
        mgr.wait()
        assert mgr.all_steps() == [5]

    def test_restore_latest_none_when_empty(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest({"x": jnp.ones(1)}) is None

    def test_elastic_restore_between_meshes(self, tmp_path):
        """Save under a 4-way mesh, restore under an 8-way mesh."""
        out = run_subprocess(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import CheckpointManager
            mesh4 = jax.make_mesh((4,), ("data",),
                devices=jax.devices()[:4])
            w = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                               NamedSharding(mesh4, P("data", None)))
            mgr = CheckpointManager(r"{tmp_path}")
            mgr.save({{"w": w}}, 1)

            mesh8 = jax.make_mesh((8,), ("data",))
            like = jax.device_put(jnp.zeros((8, 4)),
                                  NamedSharding(mesh8, P("data", None)))
            restored = mgr.restore(1, {{"w": like}}, mesh8)
            np.testing.assert_allclose(np.asarray(restored["w"]),
                                       np.arange(32.0).reshape(8, 4))
            assert restored["w"].sharding.mesh.shape["data"] == 8
            print("ELASTIC_OK")
        """)
        assert "ELASTIC_OK" in out


class TestShardingRules:
    def test_param_specs_divisibility(self):
        """Property: every sharded dim must be divisible by the mesh axis
        it is sharded over — checked for all archs × both meshes."""
        out = run_subprocess("""
            import jax
            from repro.configs import ARCHS, get_config
            from repro.models.lm import abstract_params
            from repro.distributed.sharding import param_specs
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            for arch in ARCHS:
                cfg = get_config(arch)
                ap = abstract_params(cfg)
                specs = param_specs(cfg, ap, mesh)
                flat_p = jax.tree_util.tree_leaves(ap)
                flat_s = jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))
                assert len(flat_p) == len(flat_s)
                for leaf, spec in zip(flat_p, flat_s):
                    for dim, entry in zip(leaf.shape, tuple(spec)):
                        if entry is None:
                            continue
                        axes = entry if isinstance(entry, tuple) \\
                            else (entry,)
                        k = 1
                        for a in axes:
                            k *= mesh.shape[a]
                        assert dim % k == 0, (arch, leaf.shape, spec)
            print("SPECS_OK")
        """)
        assert "SPECS_OK" in out

    def test_train_step_runs_and_learns_on_mesh(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.launch.train import make_train_step
            from repro.models.lm import init_params
            from repro.optim.functional import make_optimizer
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            cfg = get_smoke_config("gemma-2b")
            batch_abs = {
                "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            }
            step, shardings, state_abs, _ = make_train_step(
                cfg, mesh, optimizer="adamw", lr=1e-2,
                batch_abs=batch_abs)
            with mesh:
                params = jax.jit(
                    lambda k: init_params(cfg, k),
                    out_shardings=shardings["params"])(jax.random.key(0))
                init_opt, _ = make_optimizer("adamw", lr=1e-2)
                opt = jax.jit(init_opt,
                              out_shardings=shardings["opt"])(params)
                state = {"params": params, "opt": opt,
                         "step": jnp.zeros((), jnp.int32)}
                tok = jax.random.randint(jax.random.key(1), (8, 16), 0,
                                         cfg.vocab_size)
                batch = {"tokens": tok, "labels": tok}
                losses = []
                for _ in range(12):
                    state, metrics = step(state, batch)
                    losses.append(float(metrics["loss"]))
            assert losses[-1] < losses[0] * 0.9, losses
            assert int(state["step"]) == 12
            print("TRAIN_MESH_OK", round(losses[0], 3),
                  "->", round(losses[-1], 3))
        """)
        assert "TRAIN_MESH_OK" in out

    def test_grad_accumulation_matches_full_batch(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.launch.train import make_train_step
            from repro.models.lm import init_params
            from repro.optim.functional import make_optimizer
            mesh = jax.make_mesh((2, 1), ("data", "model"))
            cfg = get_smoke_config("yi-34b")
            batch_abs = {
                "tokens": jax.ShapeDtypeStruct((8, 8), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 8), jnp.int32),
            }
            def build(accum):
                return make_train_step(cfg, mesh, optimizer="sgd",
                                       lr=0.1, batch_abs=batch_abs,
                                       accum_steps=accum, donate=False)
            step1, sh, _, _ = build(1)
            step4, _, _, _ = build(4)
            with mesh:
                params = jax.jit(lambda k: init_params(cfg, k),
                                 out_shardings=sh["params"])(
                    jax.random.key(0))
                init_opt, _ = make_optimizer("sgd", lr=0.1)
                opt = init_opt(params)
                tok = jax.random.randint(jax.random.key(1), (8, 8), 0,
                                         cfg.vocab_size)
                batch = {"tokens": tok, "labels": tok}
                s0 = {"params": params, "opt": opt,
                      "step": jnp.zeros((), jnp.int32)}
                o1, m1 = step1(s0, batch)
                s0b = {"params": params, "opt": opt,
                       "step": jnp.zeros((), jnp.int32)}
                o4, m4 = step4(s0b, batch)
            np.testing.assert_allclose(float(m1["loss"]),
                                       float(m4["loss"]), rtol=1e-4)
            for a, b in zip(jax.tree_util.tree_leaves(o1["params"]),
                            jax.tree_util.tree_leaves(o4["params"])):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=2e-3, atol=2e-5)
            print("ACCUM_OK")
        """)
        assert "ACCUM_OK" in out


class TestDDPAndPipeline:
    def test_ddp_and_pipeline(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            import repro, repro.nn as nn
            import repro.nn.functional as F
            from repro.distributed.ddp import DistributedDataParallel
            from repro.distributed.pipeline import pipeline_apply
            mesh = jax.make_mesh((8,), ("data",))
            m = nn.Sequential(nn.Linear(16, 32), nn.ReLU(),
                              nn.Linear(32, 4))
            ddp = DistributedDataParallel(m, mesh=mesh, bucket_mb=1e-4)
            x = repro.randn(8, 16); y = repro.randint(0, 4, (8,))
            F.cross_entropy(ddp(x), y).backward()
            before = {id(p): np.asarray(p.grad.data).copy()
                      for p in m.parameters()}
            ddp.sync_gradients()
            for p in m.parameters():
                np.testing.assert_allclose(np.asarray(p.grad.data),
                                           before[id(p)], rtol=1e-5)
            assert ddp.stats["num_allreduce"] >= 2
            print("DDP_OK")

            mesh_p = jax.make_mesh((8,), ("pod",))
            ws = jax.random.normal(jax.random.key(0), (8, 16, 16)) * 0.1
            out = pipeline_apply(
                lambda w, x: jnp.tanh(x @ w["w"]), {"w": ws},
                jax.random.normal(jax.random.key(1), (32, 16)),
                mesh=mesh_p, n_microbatches=4)
            ref = jax.random.normal(jax.random.key(1), (32, 16))
            for i in range(8):
                ref = jnp.tanh(ref @ ws[i])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-5)
            print("PIPELINE_OK")
        """)
        assert "DDP_OK" in out and "PIPELINE_OK" in out


class TestFaultTolerance:
    def test_train_restart_resumes(self, tmp_path):
        """Kill training mid-run; restart must resume from checkpoint."""
        code = f"""
            import jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.launch.train import train_loop
            cfg = get_smoke_config("gemma3-1b")
            res = train_loop(cfg, steps={{steps}}, batch_size=4,
                             seq_len=16, optimizer="adamw", lr=1e-3,
                             checkpoint_dir=r"{tmp_path}",
                             checkpoint_every=3, log_every=100)
            print("STEPS_RUN", res["steps"])
        """
        out1 = run_subprocess(code.replace("{steps}", "7"), n_devices=1)
        assert "STEPS_RUN 7" in out1
        out2 = run_subprocess(code.replace("{steps}", "10"), n_devices=1)
        # resumed from step 7 checkpoint → only 3 more steps
        assert "STEPS_RUN 3" in out2
