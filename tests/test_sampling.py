"""In-jit sampling parity: ``serving.sampling`` vs independent host
references.

The executor samples inside the jitted ``unified_step`` (logits never
round-trip to host), so the only way to trust its output is parity:
the fixed-shape, vmapped filter must keep EXACTLY the support a
straightforward host-side implementation keeps (top-k with boundary
ties, exclusive-cumsum top-p, temperature scaling), and the Gumbel-max
draw must match a per-row host recomputation that shares only the PRNG
stream.  Greedy (temperature 0) must be bitwise ``argmax``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampling import (SamplingParams, filter_logits,
                                    sample_ref, sample_tokens)

V = 41


def support(filtered):
    """Kept-lane mask of a filtered row (masked lanes carry
    ``float32 finfo.min``, which IS finite — don't use isfinite)."""
    return np.asarray(filtered) > np.finfo(np.float32).min / 2


def fixed_logits(seed=0, rows=1, v=V):
    """Deterministic logits grid with deliberate ties (round to 0.5
    steps) so top-k boundary-tie handling is actually exercised."""
    rng = np.random.RandomState(seed)
    x = rng.randn(rows, v).astype(np.float32) * 2.0
    return np.round(x * 2) / 2


def ref_filter(logits, temperature, top_k, top_p):
    """Independent numpy re-implementation of the filter contract:
    scale by temperature, keep the top-k by VALUE threshold (ties at
    the k-th value survive), then keep the smallest sorted prefix whose
    exclusive cumulative softmax mass is < top_p (the crossing token
    included).  Returns the boolean keep mask."""
    scaled = logits / max(temperature, 1e-6)
    v = len(scaled)
    k = v if top_k <= 0 else min(top_k, v)
    srt = np.sort(scaled)[::-1]
    keep = scaled >= srt[k - 1]
    probs = np.exp(srt[:k] - srt[:k].max())
    probs = probs / probs.sum()
    cum = np.cumsum(probs) - probs          # exclusive
    kept_vals = srt[:k][cum < top_p]
    keep &= scaled >= kept_vals.min()
    return keep


class TestFilterParity:
    @pytest.mark.parametrize("top_k", [0, 1, 3, 5, 17, V, V + 9])
    def test_topk_support(self, top_k):
        for row in fixed_logits(seed=top_k, rows=8):
            out = np.asarray(filter_logits(
                jnp.asarray(row), jnp.float32(1.0),
                jnp.int32(top_k), jnp.float32(1.0)))
            np.testing.assert_array_equal(
                support(out), ref_filter(row, 1.0, top_k, 1.0))

    @pytest.mark.parametrize("top_p", [0.05, 0.3, 0.7, 0.95, 1.0])
    def test_topp_support(self, top_p):
        for row in fixed_logits(seed=int(top_p * 100), rows=8):
            out = np.asarray(filter_logits(
                jnp.asarray(row), jnp.float32(1.0),
                jnp.int32(0), jnp.float32(top_p)))
            np.testing.assert_array_equal(
                support(out), ref_filter(row, 1.0, 0, top_p))

    @pytest.mark.parametrize("temp,top_k,top_p", [
        (0.7, 5, 0.9), (1.3, 0, 0.5), (0.25, 3, 1.0), (2.0, 20, 0.8)])
    def test_combined_support_and_values(self, temp, top_k, top_p):
        # kept lanes carry the SCALED logit (the gumbel draw downstream
        # depends on the value, not just the mask)
        for row in fixed_logits(seed=7, rows=8):
            out = np.asarray(filter_logits(
                jnp.asarray(row), jnp.float32(temp),
                jnp.int32(top_k), jnp.float32(top_p)))
            mask = ref_filter(row, temp, top_k, top_p)
            np.testing.assert_array_equal(support(out), mask)
            np.testing.assert_allclose(out[mask], (row / temp)[mask],
                                       rtol=1e-6)

    def test_topp_always_keeps_argmax(self):
        # the crossing token is included, so even top_p -> 0 keeps the
        # most probable token (sampling can never be left with nothing)
        for row in fixed_logits(seed=3, rows=8):
            out = np.asarray(filter_logits(
                jnp.asarray(row), jnp.float32(1.0),
                jnp.int32(0), jnp.float32(1e-4)))
            assert support(out)[np.argmax(row)]


class TestSampleParity:
    def test_greedy_is_bitwise_argmax(self):
        logits = fixed_logits(seed=11, rows=16)
        toks = sample_tokens(
            jnp.asarray(logits), jnp.zeros(16, jnp.float32),
            jnp.zeros(16, jnp.int32), jnp.ones(16, jnp.float32),
            jnp.zeros(16, jnp.uint32),
            jnp.arange(16, dtype=jnp.int32))
        np.testing.assert_array_equal(np.asarray(toks),
                                      np.argmax(logits, axis=-1))

    @pytest.mark.parametrize("temp,top_k,top_p", [
        (1.0, 0, 1.0), (0.8, 5, 1.0), (1.0, 0, 0.9), (0.6, 10, 0.8)])
    def test_stochastic_matches_host_reference(self, temp, top_k, top_p):
        # host reference: numpy filter + numpy gumbel formula, sharing
        # ONLY the PRNG uniform draw with the in-jit path
        logits = fixed_logits(seed=5, rows=12)
        rows = logits.shape[0]
        positions = np.arange(100, 100 + rows)
        toks = np.asarray(sample_tokens(
            jnp.asarray(logits), jnp.full(rows, temp, jnp.float32),
            jnp.full(rows, top_k, jnp.int32),
            jnp.full(rows, top_p, jnp.float32),
            jnp.full(rows, 9, jnp.uint32),
            jnp.asarray(positions, jnp.int32)))
        for i in range(rows):
            keep = ref_filter(logits[i], temp, top_k, top_p)
            key = jax.random.fold_in(jax.random.key(np.uint32(9)),
                                     positions[i])
            u = np.asarray(jax.random.uniform(
                key, (V,), jnp.float32, minval=1e-20), np.float64)
            scored = np.where(keep, logits[i] / temp - np.log(-np.log(u)),
                              -np.inf)
            assert toks[i] == np.argmax(scored), f"row {i}"

    def test_samples_stay_inside_filtered_support(self):
        logits = fixed_logits(seed=23, rows=4)
        for pos in range(64):
            tok = sample_ref(logits[pos % 4],
                             SamplingParams(temperature=1.5, top_k=4,
                                            seed=1), pos)
            keep = ref_filter(logits[pos % 4], 1.5, 4, 1.0)
            assert keep[tok]

    def test_position_keyed_determinism(self):
        # same (seed, position) -> same token, independent of where the
        # row sits in the batch — the invariant speculative decoding
        # and preemption-replay both lean on
        logits = fixed_logits(seed=31, rows=1)[0]
        p = SamplingParams(temperature=1.0, seed=77)
        a = [sample_ref(logits, p, pos) for pos in range(8)]
        b = [sample_ref(logits, p, pos) for pos in range(8)]
        assert a == b
        assert len(set(a)) > 1     # and positions actually vary draws

    def test_validate_rejects_bad_params(self):
        with pytest.raises(ValueError):
            SamplingParams(top_p=0.0).validate()
        with pytest.raises(ValueError):
            SamplingParams(top_p=1.5).validate()
        with pytest.raises(ValueError):
            SamplingParams(top_k=-1).validate()
        with pytest.raises(ValueError):
            SamplingParams(temperature=-0.1).validate()
        assert SamplingParams().validate().greedy
