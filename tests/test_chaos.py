"""Deterministic fault-injection chaos matrix (``make chaos``).

Every scenario injects a scheduled fault from ``repro.serving.faults``
into a live continuous-batching engine and gates on graceful
degradation:

  * every HEALTHY request finishes with its full token budget,
  * the faulted request retires FAILED (pages freed, error recorded)
    — one request fails, never the step loop,
  * ``watchdog_trips == injected`` for the quarantine fault classes
    (nan_logits / executor_crash / table_corruption) and ``== 0`` for
    pool_exhaustion (absorbed by backpressure + preemption alone),
  * refcount conservation holds after recovery: the pool drains to
    empty (``allocated == freed``, zero live refs),
  * no zero-decode step ever happens while decodable sequences exist.

The matrix is seeded and fixed — the same (spec, seed) always picks the
same victim at the same step, so failures here bisect cleanly.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.models.lm import LMConfig, init_params
from repro.serving.engine import ServingEngine
from repro.serving.errors import FaultInjected, RequestFailed
from repro.serving.faults import FaultInjector, FaultSpec
from repro.serving.scheduler import RequestState

pytestmark = pytest.mark.slow   # fault matrix: full CI job, not tier-1

CFG = LMConfig(name="chaos-tiny", n_layers=2, d_model=64, n_heads=4,
               n_kv_heads=2, d_ff=128, vocab_size=97,
               param_dtype=jnp.float32, remat="none", attn_backend="ref")

QUARANTINE_KINDS = ("nan_logits", "executor_crash", "table_corruption")
SEEDS = (0, 1)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.key(0))


def make_engine(params, faults=None, **kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_batch", 4)
    kw.setdefault("watchdog_interval", 1)    # audit every step
    return ServingEngine(CFG, params, faults=faults, **kw)


def serve(eng, n=6, max_new=6):
    prompts = [[(7 + 13 * i + j) % 97 for j in range(10)]
               for i in range(n)]
    rids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    done = {r.req_id: r for r in eng.run()}
    return rids, done


def assert_drained(eng):
    st = eng.kv.pool.stats
    assert st.allocated_pages == st.freed_pages
    assert len(eng.kv.pool.refs) == 0
    assert eng.kv.pool.num_free == eng.kv.pool.num_pages
    assert eng.kv.external_refs == {}


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("kind", QUARANTINE_KINDS)
def test_single_fault_fails_one_request_not_the_loop(params, kind, seed):
    inj = FaultInjector([FaultSpec(kind, step=6)], seed=seed)
    eng = make_engine(params, faults=inj)
    rids, done = serve(eng)
    assert inj.injected == 1
    failed = [r for r in eng.aborted if r.state is RequestState.FAILED]
    assert len(failed) == 1
    assert failed[0].error                   # cause recorded
    assert len(done) == len(rids) - 1        # every healthy one finished
    for r in done.values():
        assert len(r.out_tokens) == 6        # full budget, no truncation
    assert eng.metrics["watchdog_trips"] == inj.injected
    assert eng.metrics["zero_decode_steps"] == 0
    with pytest.raises(RequestFailed):
        eng.result(failed[0].req_id)
    assert_drained(eng)


@pytest.mark.parametrize("seed", SEEDS)
def test_pool_exhaustion_absorbed_without_failures(params, seed):
    """Stealing EVERY free page mid-serve must cost only latency:
    backpressure + preemption absorb it, no request fails, and the
    watchdog stays silent (external holds are accounted refs, not
    leaks)."""
    inj = FaultInjector([FaultSpec("pool_exhaustion", step=4,
                                   hold_steps=6)], seed=seed)
    eng = make_engine(params, faults=inj, num_pages=32)
    rids, done = serve(eng)
    assert inj.injected == 1
    assert len(done) == len(rids)            # nobody failed, just delayed
    assert eng.aborted == []
    assert eng.metrics["watchdog_trips"] == 0
    assert eng.metrics["zero_decode_steps"] == 0
    assert_drained(eng)


def test_combined_fault_storm(params):
    """Three distinct fault classes in one serve: three requests fail
    (one per fault), everyone else finishes, trips match injections."""
    inj = FaultInjector.parse(
        "nan_logits@5;executor_crash@9;table_corruption@13", seed=0)
    eng = make_engine(params, faults=inj)
    rids, done = serve(eng, n=8, max_new=8)
    assert inj.injected == 3
    failed = [r for r in eng.aborted if r.state is RequestState.FAILED]
    assert len(failed) == 3
    assert len({r.req_id for r in failed}) == 3   # distinct victims
    assert len(done) == len(rids) - 3
    assert eng.metrics["watchdog_trips"] == inj.injected
    assert eng.metrics["executor_failures"] == 1
    assert eng.metrics["zero_decode_steps"] == 0
    assert_drained(eng)


def test_same_seed_same_victim(params):
    """Determinism: identical (spec, seed) picks the identical victim —
    chaos failures must bisect, not flake."""
    def run_once():
        inj = FaultInjector([FaultSpec("executor_crash", step=7)],
                            seed=3)
        eng = make_engine(params, faults=inj)
        serve(eng)
        failed = [r for r in eng.aborted
                  if r.state is RequestState.FAILED]
        assert len(failed) == 1
        return failed[0].req_id

    assert run_once() == run_once()


class TestSpecGrammar:
    def test_parse_spec_string(self):
        inj = FaultInjector.parse(
            "pool_exhaustion@4:pages=8,hold=6; nan_logits@9:seq=2",
            seed=5)
        assert [(s.kind, s.step) for s in inj.specs] == [
            ("pool_exhaustion", 4), ("nan_logits", 9)]
        assert inj.specs[0].pages == 8
        assert inj.specs[0].hold_steps == 6
        assert inj.specs[1].seq == 2

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultInjector.parse("meteor_strike@3")

    def test_fault_injected_is_typed(self):
        e = FaultInjected("boom", req_id=7)
        assert isinstance(e, RequestFailed)
        assert e.req_id == 7
