"""Define-by-run autograd engine: exactness vs jax.grad, versioning,
graph lifecycle (paper §4.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
import repro.nn.functional as F
from repro.core.autograd import Function, grad as autograd_grad


def assert_grads_match(fn_repro, fn_jax, *arrays, rtol=1e-5, atol=1e-6):
    tensors = [repro.tensor(a, requires_grad=True) for a in arrays]
    out = fn_repro(*tensors)
    out.backward()
    jax_grads = jax.grad(
        lambda *xs: fn_jax(*xs), argnums=tuple(range(len(arrays))))(*arrays)
    for t, g in zip(tensors, jax_grads):
        np.testing.assert_allclose(np.asarray(t.grad.data), np.asarray(g),
                                   rtol=rtol, atol=atol)


class TestTapeVsJax:
    def test_matmul_relu_sum(self):
        a = np.random.randn(4, 8).astype(np.float32)
        b = np.random.randn(8, 3).astype(np.float32)
        assert_grads_match(
            lambda x, y: (x @ y).relu().sum(),
            lambda x, y: jax.nn.relu(x @ y).sum(), a, b)

    def test_broadcast_arith(self):
        a = np.random.randn(4, 8).astype(np.float32)
        b = np.random.randn(8).astype(np.float32)
        assert_grads_match(
            lambda x, y: ((x + y) * (x - y) / 2.0).sum(),
            lambda x, y: ((x + y) * (x - y) / 2.0).sum(), a, b)

    def test_softmax_logsumexp(self):
        a = np.random.randn(5, 7).astype(np.float32)
        assert_grads_match(
            lambda x: (x.softmax(-1) * x.log_softmax(-1)).sum(),
            lambda x: (jax.nn.softmax(x, -1)
                       * jax.nn.log_softmax(x, -1)).sum(), a)

    def test_reductions_and_reshapes(self):
        a = np.random.randn(2, 3, 4).astype(np.float32)
        assert_grads_match(
            lambda x: x.reshape(6, 4).transpose(0, 1).mean(),
            lambda x: x.reshape(6, 4).transpose(1, 0).T.mean(), a)

    def test_indexing(self):
        a = np.random.randn(6, 5).astype(np.float32)
        assert_grads_match(
            lambda x: (x[1:4] ** 2).sum(),
            lambda x: (x[1:4] ** 2).sum(), a)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(2, 6), m=st.integers(2, 6),
        ops=st.lists(st.sampled_from(
            ["exp", "tanh", "sigmoid", "relu", "sqrtabs", "square"]),
            min_size=1, max_size=4),
    )
    def test_random_unary_chains(self, n, m, ops):
        """Property: tape gradients equal jax.grad for arbitrary chains."""
        a = np.random.randn(n, m).astype(np.float32)

        def chain_repro(x):
            for op in ops:
                if op == "sqrtabs":
                    x = (x.abs() + 1.0).sqrt()
                elif op == "square":
                    x = x * x
                else:
                    x = getattr(x, op)()
            return x.sum()

        def chain_jax(x):
            for op in ops:
                if op == "sqrtabs":
                    x = jnp.sqrt(jnp.abs(x) + 1.0)
                elif op == "square":
                    x = x * x
                elif op == "relu":
                    x = jax.nn.relu(x)
                elif op == "sigmoid":
                    x = jax.nn.sigmoid(x)
                else:
                    x = getattr(jnp, op)(x)
            return x.sum()

        assert_grads_match(chain_repro, chain_jax, a,
                           rtol=1e-4, atol=1e-5)

    def test_shared_subexpression_accumulates(self):
        a = repro.randn(4, requires_grad=True)
        b = a * 2.0
        out = (b * b).sum() + b.sum()
        out.backward()
        expect = 2 * (2 * np.asarray(a.data) * 2.0) + 2.0
        np.testing.assert_allclose(np.asarray(a.grad.data), expect,
                                   rtol=1e-5)

    def test_multi_output_node(self):
        lstm_in = repro.randn(2, 5, 3, requires_grad=True)
        import repro.nn as nn
        lstm = nn.LSTM(3, 4)
        out, (h, c) = lstm(lstm_in)
        (out.sum() + h.sum()).backward()
        assert lstm_in.grad is not None
        assert lstm_in.grad.shape == (2, 5, 3)


class TestVersioning:
    def test_mutation_after_save_errors(self):
        a = repro.randn(4, requires_grad=True)
        c = a * 2.0
        d = c.exp()
        with repro.no_grad():
            c.mul_(3.0)
        with pytest.raises(RuntimeError, match="inplace"):
            d.sum().backward()

    def test_leaf_inplace_guard(self):
        a = repro.randn(4, requires_grad=True)
        with pytest.raises(RuntimeError, match="leaf"):
            a.add_(1.0)

    def test_differentiable_inplace(self):
        a = repro.randn(4, requires_grad=True)
        b = a * 2.0
        b.add_(1.0)
        b.mul_(3.0)
        b.sum().backward()
        np.testing.assert_allclose(np.asarray(a.grad.data),
                                   np.full(4, 6.0), rtol=1e-6)

    def test_view_writes_through(self):
        v = repro.zeros(3, 4)
        row = v[1]
        row.fill_(7.0)
        assert np.asarray(v.data)[1].tolist() == [7.0] * 4
        v[2] = 5.0
        assert np.asarray(v.data)[2].tolist() == [5.0] * 4

    def test_view_shares_version(self):
        v = repro.zeros(3, 4)
        row = v[0]
        assert row._version is v._version
        row.fill_(1.0)
        assert v._version.value > 0


class TestGraphLifecycle:
    def test_double_backward_without_retain_errors(self):
        p = repro.randn(3, requires_grad=True)
        q = (p * p).sum()
        q.backward()
        with pytest.raises(RuntimeError, match="second time"):
            q.backward()

    def test_retain_graph(self):
        p = repro.randn(3, requires_grad=True)
        q = (p * p).sum()
        q.backward(retain_graph=True)
        q.backward()
        np.testing.assert_allclose(np.asarray(p.grad.data),
                                   4 * np.asarray(p.data), rtol=1e-5)

    def test_no_grad(self):
        a = repro.randn(3, requires_grad=True)
        with repro.no_grad():
            b = a * 2.0
        assert b.grad_fn is None

    def test_grad_fn_named(self):
        a = repro.randn(3, requires_grad=True)
        assert (a * 2.0).grad_fn.name == "mul"

    def test_autograd_grad_api(self):
        a = repro.randn(3, requires_grad=True)
        b = repro.randn(3, requires_grad=True)
        out = (a * b).sum()
        ga, gb = autograd_grad(out, [a, b])
        np.testing.assert_allclose(np.asarray(ga.data),
                                   np.asarray(b.data), rtol=1e-6)
        assert a.grad is None  # .grad not polluted

    def test_implicit_scalar_only(self):
        a = repro.randn(3, requires_grad=True)
        with pytest.raises(RuntimeError, match="scalar"):
            (a * 2.0).backward()


class TestCustomFunction:
    def test_function_forward_backward(self):
        class Cube(Function):
            @staticmethod
            def forward(ctx, x):
                ctx.save_for_backward(x)
                return repro.Tensor(x.data ** 3)

            @staticmethod
            def backward(ctx, g):
                (x,) = ctx.saved_tensors
                return repro.Tensor(3 * x.data ** 2) * g

        a = repro.randn(5, requires_grad=True)
        out = Cube.apply(a)
        out.sum().backward()
        np.testing.assert_allclose(np.asarray(a.grad.data),
                                   3 * np.asarray(a.data) ** 2, rtol=1e-5)

    def test_function_version_check(self):
        class Identity(Function):
            @staticmethod
            def forward(ctx, x):
                ctx.save_for_backward(x)
                return repro.Tensor(x.data + 0)

            @staticmethod
            def backward(ctx, g):
                return g

        a = repro.randn(4, requires_grad=True)
        b = a * 1.0
        out = Identity.apply(b)
        with repro.no_grad():
            b.mul_(2.0)
        with pytest.raises(RuntimeError, match="inplace"):
            out.sum().backward()


class TestCompiledPath:
    def test_compile_matches_eager(self):
        f = lambda x, w: (x @ w).relu().sum()
        cf = repro.compile(f)
        x = repro.randn(4, 8)
        w = repro.randn(8, 3)
        np.testing.assert_allclose(float(cf(x, w).data),
                                   float(f(x, w).data), rtol=1e-6)

    def test_tape_disabled_under_trace(self):
        @repro.compile
        def f(x):
            y = x * 2.0
            assert y.grad_fn is None  # tracing: no tape
            return y.sum()

        x = repro.randn(3, requires_grad=True)
        out = f(x)
        assert out.grad_fn is None

    def test_value_and_grad(self):
        vg = repro.value_and_grad(lambda x: (x.exp()).sum())
        x = repro.randn(4)
        v, g = vg(x)
        np.testing.assert_allclose(np.asarray(g.data),
                                   np.exp(np.asarray(x.data)), rtol=1e-5)
