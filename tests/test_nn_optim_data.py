"""Module system, layers, optimizers, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
import repro.nn as nn
import repro.nn.functional as F
import repro.optim as optim
from repro.data import (BatchSampler, DataLoader, DistributedSampler,
                        RandomSampler, SyntheticLMDataset, TensorDataset)
from repro.data.shared_memory import PickleChannel, ShmChannel
from repro.nn import functional_call, param_dict


class TestModule:
    def make(self):
        class Net(nn.Module):
            def __init__(self):
                super().__init__()
                self.fc1 = nn.Linear(8, 16)
                self.fc2 = nn.Linear(16, 4)
                self.register_buffer("scale", repro.ones(1))

            def forward(self, x):
                return self.fc2(F.relu(self.fc1(x))) * self.scale

        return Net()

    def test_named_parameters(self):
        net = self.make()
        names = dict(net.named_parameters())
        assert set(names) == {"fc1.weight", "fc1.bias", "fc2.weight",
                              "fc2.bias"}
        assert dict(net.named_buffers()).keys() == {"scale"}

    def test_state_dict_roundtrip(self):
        net, net2 = self.make(), self.make()
        x = repro.randn(2, 8)
        net2.load_state_dict(net.state_dict())
        np.testing.assert_allclose(np.asarray(net(x).data),
                                   np.asarray(net2(x).data), rtol=1e-6)

    def test_train_eval_mode(self):
        net = self.make()
        net.eval()
        assert all(not m.training for m in net.modules())

    def test_functional_call_matches_eager(self):
        net = self.make()
        x = repro.randn(3, 8)
        eager = net(x)
        params = {k: v.data for k, v in param_dict(net).items()}
        out = functional_call(net, params, x)
        np.testing.assert_allclose(np.asarray(out.data),
                                   np.asarray(eager.data), rtol=1e-6)
        # under jit with swapped params
        def f(p, xd):
            return functional_call(net, p, repro.Tensor(xd)).data.sum()
        v1 = jax.jit(f)(params, x.data)
        # params restored after functional_call
        assert isinstance(net.fc1.weight, nn.Parameter)
        zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
        assert float(jax.jit(f)(zeros, x.data)) == 0.0
        assert float(v1) != 0.0

    def test_tape_grads_equal_jax_grads_through_module(self):
        net = self.make()
        x = repro.randn(4, 8)
        y = repro.randint(0, 4, (4,))
        loss = F.cross_entropy(net(x), y)
        loss.backward()
        params = {k: v.data for k, v in param_dict(net).items()}
        jg = jax.grad(lambda p: F.cross_entropy(
            functional_call(net, p, x), y).data)(params)
        for name, p in net.named_parameters():
            np.testing.assert_allclose(np.asarray(p.grad.data),
                                       np.asarray(jg[name]),
                                       rtol=2e-4, atol=1e-5)


class TestLayers:
    def test_layer_norm_matches_formula(self):
        ln = nn.LayerNorm(16)
        x = repro.randn(4, 16)
        out = np.asarray(ln(x).data)
        xd = np.asarray(x.data)
        ref = (xd - xd.mean(-1, keepdims=True)) / np.sqrt(
            xd.var(-1, keepdims=True) + 1e-5)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_batchnorm_updates_running_stats(self):
        bn = nn.BatchNorm2d(3)
        x = repro.randn(8, 3, 4, 4) * 2.0 + 1.0
        bn(x)
        assert not np.allclose(np.asarray(bn._buffers["running_mean"].data),
                               0.0)
        bn.eval()
        before = np.asarray(bn._buffers["running_mean"].data).copy()
        bn(x)
        np.testing.assert_allclose(
            np.asarray(bn._buffers["running_mean"].data), before)

    def test_conv2d_matches_lax(self):
        conv = nn.Conv2d(2, 5, 3, stride=2, padding=1)
        x = repro.randn(2, 2, 9, 9)
        out = conv(x)
        ref = jax.lax.conv_general_dilated(
            x.data, conv.weight.data, (2, 2), ((1, 1), (1, 1)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        ref = ref + conv.bias.data.reshape(1, -1, 1, 1)
        np.testing.assert_allclose(np.asarray(out.data), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_embedding_gather(self):
        emb = nn.Embedding(10, 4)
        idx = repro.tensor([1, 3, 1])
        out = np.asarray(emb(idx).data)
        w = np.asarray(emb.weight.data)
        np.testing.assert_allclose(out, w[[1, 3, 1]])

    def test_dropout_train_eval(self):
        d = nn.Dropout(0.5)
        x = repro.ones(1000)
        out = d(x)
        frac = float((out.data == 0).mean())
        assert 0.3 < frac < 0.7
        d.eval()
        np.testing.assert_allclose(np.asarray(d(x).data),
                                   np.asarray(x.data))

    def test_sdpa_gqa_matches_manual(self):
        q = repro.randn(2, 8, 16, 4)
        k = repro.randn(2, 2, 16, 4)
        v = repro.randn(2, 2, 16, 4)
        out = F.scaled_dot_product_attention(q, k, v, is_causal=True,
                                             backend="ref")
        assert out.shape == (2, 8, 16, 4)
        # causality: output at position 0 ignores later keys
        v2 = repro.Tensor(v.data.at[:, :, 1:].set(0.0))
        out2 = F.scaled_dot_product_attention(q, k, v2, is_causal=True,
                                              backend="ref")
        np.testing.assert_allclose(np.asarray(out.data[:, :, 0]),
                                   np.asarray(out2.data[:, :, 0]),
                                   rtol=1e-5)


class TestOptim:
    def _fit(self, opt_cls, steps=200, **kw):
        repro.manual_seed(0)
        m = nn.Linear(2, 1)
        opt = opt_cls(m.parameters(), **kw)
        x = repro.randn(128, 2)
        w_true = repro.tensor([[1.5], [-2.0]])
        y = x @ w_true
        for _ in range(steps):
            opt.zero_grad()
            loss = F.mse_loss(m(x), y)
            loss.backward()
            opt.step()
        return float(loss.data)

    def test_sgd_momentum(self):
        assert self._fit(optim.SGD, lr=0.05, momentum=0.9) < 1e-3

    def test_adam(self):
        assert self._fit(optim.Adam, lr=0.05) < 1e-3

    def test_adamw(self):
        assert self._fit(optim.AdamW, lr=0.05, weight_decay=0.0) < 1e-3

    def test_adafactor(self):
        assert self._fit(optim.Adafactor, lr=0.05, steps=400) < 1e-2

    def test_adam_matches_reference_formula(self):
        p = repro.tensor([1.0], requires_grad=True)
        opt = optim.Adam([p], lr=0.1)
        (p * 3.0).sum().backward()
        opt.step()
        # after one step, update = -lr * mhat/(sqrt(vhat)+eps) ≈ -lr
        np.testing.assert_allclose(float(p.data[0]), 1.0 - 0.1, rtol=1e-4)

    def test_state_dict_roundtrip(self):
        m = nn.Linear(3, 3)
        opt = optim.Adam(m.parameters(), lr=0.1)
        F.mse_loss(m(repro.randn(4, 3)), repro.randn(4, 3)).backward()
        opt.step()
        sd = opt.state_dict()
        opt2 = optim.Adam(m.parameters(), lr=0.1)
        opt2.load_state_dict(sd)
        assert len(opt2.state) == len(opt.state)


class TestData:
    def test_tensor_dataset_loader(self):
        x = repro.randn(20, 3)
        y = repro.arange(20)
        dl = DataLoader(TensorDataset(x, y), batch_size=6)
        batches = list(dl)
        assert len(batches) == 4
        assert batches[0][0].shape == (6, 3)
        assert batches[-1][0].shape == (2, 3)

    def test_drop_last(self):
        ds = SyntheticLMDataset(50, 4, size=20)
        assert len(DataLoader(ds, batch_size=6, drop_last=True)) == 3

    def test_workers_and_pinned(self):
        ds = SyntheticLMDataset(100, 8, size=32)
        dl = DataLoader(ds, batch_size=4, num_workers=3, pin_memory=True,
                        shuffle=True, seed=1)
        seen = [tuple(np.asarray(t.data)[0, :3]) for t, _ in dl]
        assert len(seen) == 8

    def test_determinism_with_seed(self):
        ds = SyntheticLMDataset(100, 8, size=32)
        a = [np.asarray(t.data) for t, _ in
             DataLoader(ds, batch_size=4, shuffle=True, seed=7)]
        b = [np.asarray(t.data) for t, _ in
             DataLoader(ds, batch_size=4, shuffle=True, seed=7)]
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    @given(n=st.integers(4, 100), reps=st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_distributed_sampler_partition(self, n, reps):
        """Property: ranks partition (pad-extended) indices w/o overlap."""
        ds = list(range(n))
        all_idx = []
        lens = set()
        for rank in range(reps):
            s = DistributedSampler(ds, num_replicas=reps, rank=rank,
                                   shuffle=True, seed=3)
            idx = list(iter(s))
            lens.add(len(idx))
            all_idx.extend(idx)
        assert len(lens) == 1           # equal length per rank
        assert set(all_idx) == set(range(n))  # full coverage
        assert len(all_idx) == -(-n // reps) * reps

    def test_straggler_refetch(self):
        import time as _t

        class SlowDS(SyntheticLMDataset):
            def __getitem__(self, i):
                if i == 5:
                    _t.sleep(0.3)
                return super().__getitem__(i)

        ds = SlowDS(50, 4, size=16)
        dl = DataLoader(ds, batch_size=4, num_workers=2,
                        worker_timeout_s=0.05)
        n = sum(1 for _ in dl)
        assert n == 4
        assert dl.straggler_events >= 1

    def test_shm_channel_zero_copy_vs_pickle(self):
        arr = np.random.randn(256, 256).astype(np.float32)
        shm = ShmChannel()
        shm.send(arr)
        out = shm.recv()
        np.testing.assert_array_equal(out, arr)
        shm.close()
        pk = PickleChannel()
        pk.send(arr)
        np.testing.assert_array_equal(pk.recv(), arr)
