"""Dispatch fast path: signature-keyed op/VJP cache, elementwise fusion
queue, fused foreach optimizers."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.core import dispatch as D
from repro.core import fuse as F
from repro.core.autograd import no_grad


@pytest.fixture(autouse=True)
def fresh_cache():
    D.reset_dispatch_cache()
    yield
    D.reset_dispatch_cache()


class TestDispatchCache:
    def test_hit_miss_stats(self):
        x = repro.randn(16, 16)
        _ = x.exp()
        s = repro.dispatch_cache_stats()
        assert s["num_misses"] >= 1 and s["num_hits"] == 0
        _ = x.exp()
        s = repro.dispatch_cache_stats()
        assert s["num_hits"] == 1
        # different signature -> new entry, not a hit
        _ = repro.randn(8, 8).exp()
        s2 = repro.dispatch_cache_stats()
        assert s2["num_misses"] == s["num_misses"] + 1
        assert s2["num_entries"] == s2["num_misses"]

    def test_grad_flag_and_statics_key(self):
        x = repro.randn(4, 4, requires_grad=True)
        y = repro.randn(4, 4)  # no grad
        _ = x.exp()
        _ = y.exp()  # same shapes, different grad flag -> distinct entry
        assert repro.dispatch_cache_stats()["num_misses"] == 2
        _ = x.sum(dim=0)
        _ = x.sum(dim=1)  # static differs -> distinct entry
        assert repro.dispatch_cache_stats()["num_misses"] == 4

    def test_cached_vjp_matches_fresh_jax_vjp(self):
        xd = jnp.asarray(np.random.default_rng(0).standard_normal(
            (32, 32), dtype=np.float32))
        # fresh jax.vjp reference
        f = lambda a: jnp.tanh(a * 2.0 + 1.0) * a  # noqa: E731
        out_ref, vjp_ref = jax.vjp(f, xd)
        cot = jnp.ones_like(out_ref)
        (g_ref,) = vjp_ref(cot)

        def run():
            x = repro.Tensor(xd, requires_grad=True)
            y = (x * 2.0 + 1.0).tanh() * x
            y.backward(repro.Tensor(cot))
            return np.asarray(y.data), np.asarray(x.grad.data)

        y1, g1 = run()  # populates the cache (miss)
        y2, g2 = run()  # replays cached fwd + vjp (hit)
        assert repro.dispatch_cache_stats()["num_hits"] > 0
        for y, g in ((y1, g1), (y2, g2)):
            np.testing.assert_allclose(y, np.asarray(out_ref),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(g, np.asarray(g_ref),
                                       rtol=1e-6, atol=1e-6)

    def test_unhashable_static_falls_back(self):
        x = repro.randn(4, 4)
        before = repro.dispatch_cache_stats()["num_fallback_unhashable"]
        # advanced (array) indexing: no hashable static -> uncached path
        idx = repro.tensor(np.array([0, 2]))
        _ = x[idx]
        s = repro.dispatch_cache_stats()
        assert (s["num_uncached"] >= 1
                or s["num_fallback_unhashable"] > before)

    def test_tensor_valued_static_never_cached(self):
        # a Tensor is hashable (by id) but must never key a cached
        # closure: stale data would replay after mutation
        from repro.core.tensor import _static_ok
        t = repro.randn(())
        assert not _static_ok((t,))
        assert not _static_ok(t)
        assert _static_ok((1, 2.0, None, "s", (3, jnp.float32)))
        x = repro.randn(4, 4)
        before = D.dispatch_cache_stats()["num_fallback_unhashable"]
        with pytest.raises(TypeError):
            _ = x.clamp(min=t)  # unsupported operand, but must not
        s = D.dispatch_cache_stats()  # poison the cache on the way out
        assert s["num_fallback_unhashable"] == before + 1
        assert s["num_entries"] == 0

    def test_bool_index_key_distinct_from_int(self):
        # bool is an int subclass: x[True] must not replay x[1]'s entry
        x = repro.tensor(np.arange(12).reshape(3, 4))
        assert x[1].shape == (4,)
        assert x[True].shape == (1, 3, 4)

    def test_statics_keyed_by_type(self):
        # 0 and 0.0 hash equal but bake different closures (promotion)
        t = repro.tensor(np.arange(6, dtype=np.int32))
        assert str(t.clamp(0, 1).dtype) == "int32"
        assert str(t.clamp(0.0, 1.0).dtype) == "float32"

    def test_cache_disabled_context(self):
        x = repro.randn(4, 4)
        with D.cache_disabled():
            _ = x.exp()
            _ = x.exp()
        assert repro.dispatch_cache_stats()["num_entries"] == 0

    def test_compile_unhashable_static_falls_back(self):
        calls = []

        @repro.compile(static_argnums=(1,))
        def f(x, flag):
            calls.append(1)
            return x * 2.0 if flag else x

        x = repro.randn(4)
        before = repro.dispatch_cache_stats()["num_fallback_unhashable"]
        with pytest.warns(UserWarning):
            out = f(x, [1, 2])  # unhashable static -> eager fallback
        assert isinstance(out, repro.Tensor)
        assert repro.dispatch_cache_stats()["num_fallback_unhashable"] \
            == before + 1


class TestFusionQueue:
    def test_chain_defers_and_flushes_once(self):
        x = repro.randn(16, 16, requires_grad=True)
        with F.fusion():
            y = ((x * 2.0 + 1.0).tanh() * x).sigmoid()
            assert y._pending is not None
            got = np.asarray(y.numpy())  # materialization point
        assert y._pending is None
        xd = np.asarray(x.data)
        ref = 1 / (1 + np.exp(-(np.tanh(xd * 2 + 1) * xd)))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
        # whole chain = ONE fused cache entry
        assert any(repro.dispatch_cache_stats()["num_entries"] >= 1
                   for _ in [0])

    def test_fused_backward_matches_eager(self):
        xd = jnp.asarray(np.random.default_rng(1).standard_normal(
            (16, 16), dtype=np.float32))
        x1 = repro.Tensor(xd, requires_grad=True)
        with F.fusion():
            ((x1 * 3.0).exp() + x1).sum().backward()
        x2 = repro.Tensor(xd, requires_grad=True)
        ((x2 * 3.0).exp() + x2).sum().backward()
        np.testing.assert_allclose(np.asarray(x1.grad.data),
                                   np.asarray(x2.grad.data),
                                   rtol=1e-6, atol=1e-6)

    def test_intermediates_materialized_from_same_kernel(self):
        x = repro.randn(8, requires_grad=True)
        with F.fusion():
            m = x * 3.0
            z = m.exp()
            (z.sum() + m.sum()).backward()
        ref = np.exp(np.asarray(x.data) * 3) * 3 + 3
        np.testing.assert_allclose(np.asarray(x.grad.data), ref,
                                   rtol=1e-5, atol=1e-6)

    def test_inplace_mutation_flushes_with_premutation_value(self):
        a = repro.randn(8)
        with F.fusion():
            b = a * 3.0
            expect = np.asarray(a.data) * 3.0
            a.add_(1.0)  # mutation barrier: b flushed against old a
            np.testing.assert_allclose(np.asarray(b.data), expect,
                                       rtol=1e-6)

    def test_version_counter_detects_mutation_before_backward(self):
        w = repro.randn(8, requires_grad=True)
        y = w * 2.0  # eager op: w saved with its version
        with F.fusion():
            z = y.exp()
            z.numpy()  # flush records y's version in the fused node
        y._version.bump()  # simulate an in-place mutation of the input
        with pytest.raises(RuntimeError, match="inplace"):
            z.sum().backward()

    def test_no_grad_boundary_not_fused_through(self):
        w = repro.randn(8, requires_grad=True)
        with F.fusion():
            with no_grad():
                c = w * 2.0  # constant chain
            y = c * w
            y.sum().backward()
        # dy/dw must treat c as a constant: grad == c, not 4w
        np.testing.assert_allclose(np.asarray(w.grad.data),
                                   np.asarray(c.data), rtol=1e-6)

    def test_depth_cap_flushes(self):
        x = repro.randn(4)
        with F.fusion():
            y = x
            for _ in range(F.MAX_CHAIN_DEPTH + 2):
                y = y + 1.0
            # deep chains flush automatically; the value is right
            np.testing.assert_allclose(
                np.asarray(y.data),
                np.asarray(x.data) + (F.MAX_CHAIN_DEPTH + 2),
                rtol=1e-6)

    def test_fusion_inside_jit_is_bypassed(self):
        @repro.compile
        def f(t):
            with F.fusion():
                return (t * 2.0).exp()

        x = repro.randn(4)
        out = f(x)
        np.testing.assert_allclose(np.asarray(out.data),
                                   np.exp(np.asarray(x.data) * 2),
                                   rtol=1e-5)


class TestFusedElementwiseKernel:
    def test_pallas_interpret_matches_composite(self):
        from repro.kernels.ops import fused_elementwise
        a = jnp.asarray(np.random.default_rng(2).standard_normal(
            (20, 15), dtype=np.float32))
        b = jnp.full((20, 15), 0.5, jnp.float32)
        fn = lambda p, q: (p * q, jnp.tanh(p * q) + q)  # noqa: E731
        o1, o2 = fused_elementwise(fn, a, b, interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(a) * 0.5,
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(o2),
            np.tanh(np.asarray(a) * 0.5) + 0.5, rtol=1e-5, atol=1e-6)


class TestForeachOptimizers:
    def _params(self, n2d=12, n1d=12):
        repro.manual_seed(3)
        return ([repro.randn(16, 8, requires_grad=True)
                 for _ in range(n2d)]
                + [repro.randn(8, requires_grad=True)
                   for _ in range(n1d)])

    def _run(self, opt_cls, foreach, steps=3, **kw):
        import repro.optim as optim
        ps = self._params()
        opt = getattr(optim, opt_cls)(ps, foreach=foreach, **kw)
        for s in range(steps):
            rng = np.random.default_rng(s)
            for p in ps:
                p.grad = repro.Tensor(jnp.asarray(
                    rng.standard_normal(p.shape, dtype=np.float32)))
            opt.step()
        return [np.asarray(p.data) for p in ps]

    @pytest.mark.parametrize("opt_cls,kw", [
        ("SGD", dict(lr=1e-2, momentum=0.9, nesterov=True,
                     weight_decay=1e-4)),
        ("Adam", dict(lr=1e-3)),
        ("AdamW", dict(lr=1e-3, weight_decay=0.01)),
        ("Adafactor", dict(lr=1e-2)),
    ])
    def test_foreach_equivalent_to_perleaf(self, opt_cls, kw):
        fe = self._run(opt_cls, True, **kw)
        pl = self._run(opt_cls, False, **kw)
        for a, b in zip(fe, pl):
            np.testing.assert_allclose(a, b, rtol=2e-6, atol=2e-7)

    def test_staggered_grads_keep_perleaf_bias_correction(self):
        import repro.optim as optim

        def run(foreach):
            repro.manual_seed(11)
            p1 = repro.randn(8, requires_grad=True)
            p2 = repro.randn(8, requires_grad=True)
            opt = optim.Adam([p1, p2], lr=1e-2, foreach=foreach)
            for s in range(6):
                rng = np.random.default_rng(s)
                p1.grad = repro.Tensor(jnp.asarray(
                    rng.standard_normal(8).astype(np.float32)))
                p2.grad = (repro.Tensor(jnp.asarray(
                    rng.standard_normal(8).astype(np.float32)))
                    if s >= 5 else None)  # p2 frozen for 5 steps
                opt.step()
            return (np.asarray(p1.data), np.asarray(p2.data),
                    int(opt.state[id(p2)]["step"]))

        a1, a2, st_f = run(True)
        b1, b2, st_l = run(False)
        np.testing.assert_allclose(a1, b1, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(a2, b2, rtol=1e-6, atol=1e-7)
        assert st_f == st_l == 1

    def test_state_dict_roundtrip_preserves_perleaf_state(self):
        import repro.optim as optim
        ps = self._params(4, 0)
        opt = optim.AdamW(ps, lr=1e-3, foreach=True)
        for p in ps:
            p.grad = repro.Tensor(p.data * 0.1)
        opt.step()
        sd = opt.state_dict()
        assert len(sd["state"]) == 4
        assert all("m" in s and "v" in s and "step" in s
                   for s in sd["state"])
        opt2 = optim.AdamW(ps, lr=1e-3, foreach=True)
        opt2.load_state_dict(sd)
        assert int(opt2.state[id(ps[0])]["step"]) == 1

    def test_functional_foreach_make_optimizer(self):
        from repro.optim.functional import make_optimizer
        rng = np.random.default_rng(0)
        params = {"a": jnp.asarray(rng.standard_normal(
            (8, 4), dtype=np.float32)),
            "b": jnp.asarray(rng.standard_normal(4, dtype=np.float32))}
        grads = jax.tree_util.tree_map(lambda p: p * 0.1, params)
        for name in ("sgd", "adamw"):
            init_r, upd_r = make_optimizer(name, lr=1e-2)
            init_f, upd_f = make_optimizer(name, foreach=True, lr=1e-2)
            s_r, s_f = init_r(params), init_f(params)
            p_r, s_r = upd_r(grads, s_r, params)
            p_f, s_f = upd_f(grads, s_f, params)
            jax.tree_util.tree_map(
                lambda a, b: np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-7),
                p_r, p_f)
