"""Quantized-KV quality-drift gate (the named test CI's quant-gate job
runs) plus unit properties of the quantizer.

End-to-end: the SAME seeded workload served through an int8 / fp8_e4m3
page pool must reproduce the fp32 engine's tokens at or above the
tier's token-agreement floor — under greedy decoding AND seeded
temperature sampling (the position-keyed PRNG draws identical noise in
both engines, so disagreement is attributable to KV quantization
alone).  Tier floors are documented in docs/kernels.md."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import LMConfig, init_params
from repro.serving import quant
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams

# token-agreement floors vs the fp32 engine (measured on the tiny
# preset: both tiers sit at ~0.88 greedy / 1.0 seeded-sampled; floors
# leave margin while still catching a broken scale path, which lands
# near chance = 1/vocab)
GREEDY_FLOOR = {"int8": 0.75, "fp8_e4m3": 0.5}
SAMPLED_FLOOR = {"int8": 0.75, "fp8_e4m3": 0.5}

PROMPTS = [[(3 + 11 * i + j) % 96 + 1 for j in range(4 + 5 * (i % 3))]
           for i in range(8)]


def tiny_cfg():
    return LMConfig(name="serve-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab_size=97,
                    param_dtype=jnp.float32, remat="none",
                    attn_backend="ref")


def serve(kv_dtype, sampling=None, max_new=10):
    """Serve PROMPTS through one engine; returns (outputs, metrics)."""
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                        max_batch=4, chunk_size=8, kv_dtype=kv_dtype)
    rids = [eng.submit(p, max_new_tokens=max_new, sampling=sampling)
            for p in PROMPTS]
    done = {r.req_id: r.out_tokens for r in eng.run()}
    return [done[r] for r in rids], eng.metrics


def agreement(base, outs):
    agree = sum(sum(a == b for a, b in zip(x, y))
                for x, y in zip(base, outs))
    total = sum(len(x) for x in base)
    return agree / total


class TestQualityDriftGate:
    def test_fp32_default_is_deterministic(self):
        """Two fp32 runs are bit-identical — the baseline the drift
        floors are measured against is itself stable."""
        a, _ = serve(None)
        b, _ = serve(None)
        assert a == b

    @pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
    def test_greedy_token_agreement(self, kv_dtype):
        base, _ = serve(None)
        outs, m = serve(kv_dtype)
        assert m["kv_dtype"] == kv_dtype
        got = agreement(base, outs)
        assert got >= GREEDY_FLOOR[kv_dtype], \
            f"greedy {kv_dtype} agreement {got:.3f} < floor"

    @pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
    def test_seeded_sampling_token_agreement(self, kv_dtype):
        """temperature > 0 with a fixed seed: both engines draw the
        same per-position noise, so the floor isolates KV drift."""
        sp = SamplingParams(temperature=0.7, seed=1234)
        base, _ = serve(None, sampling=sp)
        outs, _ = serve(kv_dtype, sampling=sp)
        got = agreement(base, outs)
        assert got >= SAMPLED_FLOOR[kv_dtype], \
            f"sampled {kv_dtype} agreement {got:.3f} < floor"

    @pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
    def test_kv_bytes_per_seq_at_least_halved(self, kv_dtype):
        """The capacity claim behind the quantized sweep: a quantized
        page (1-byte codes + fp32 per-token scales) must cost at most
        half an fp32 page, so a fixed byte budget holds >= 2x the
        sequences."""
        _, m32 = serve(None, max_new=2)
        _, mq = serve(kv_dtype, max_new=2)
        assert mq["kv_bytes_per_seq"] * 2 <= m32["kv_bytes_per_seq"]
        assert mq["kv_bytes"] * 2 <= m32["kv_bytes"]


class TestQuantPrimitives:
    def test_canonical_names_and_aliases(self):
        assert quant.canonical(None) is None
        assert quant.canonical("fp32") is None
        assert quant.canonical("float32") is None
        assert quant.canonical("bf16") is None
        assert quant.canonical("int8") == "int8"
        assert quant.canonical("fp8") == "fp8_e4m3"
        assert quant.canonical("float8_e4m3fn") == "fp8_e4m3"
        with pytest.raises(ValueError):
            quant.canonical("int4")

    def test_int8_roundtrip_error_bound(self):
        """Symmetric absmax: per-element error <= scale/2, scale =
        amax/127 per (token, head) vector."""
        x = jax.random.normal(jax.random.key(3), (32, 4, 16))
        codes, scale = quant.quantize(x, "int8")
        assert codes.dtype == jnp.int8
        assert scale.shape == (32, 4)
        err = np.abs(np.asarray(quant.dequantize(codes, scale) - x))
        bound = np.asarray(scale)[..., None] * 0.5 + 1e-7
        assert (err <= bound).all()

    def test_fp8_roundtrip_relative_error(self):
        """e4m3 keeps a 3-bit mantissa: relative error <= 2^-3 of each
        element after absmax prescaling."""
        x = jax.random.normal(jax.random.key(4), (32, 4, 16))
        codes, scale = quant.quantize(x, "fp8_e4m3")
        dq = np.asarray(quant.dequantize(codes, scale))
        err = np.abs(dq - np.asarray(x))
        assert (err <= np.abs(np.asarray(x)) * 0.125 + 1e-6).all()

    def test_all_zero_vectors_roundtrip_exactly(self):
        """amax = 0 stores scale 0 (not inf/nan) and dequantizes to
        exact zeros — the state of every scrubbed / never-filled page."""
        x = jnp.zeros((5, 2, 8))
        for mode in ("int8", "fp8_e4m3"):
            codes, scale = quant.quantize(x, mode)
            assert not np.isnan(np.asarray(scale)).any()
            np.testing.assert_array_equal(
                np.asarray(quant.dequantize(codes, scale)), 0.0)

    def test_quantize_preserves_shape_and_scale_layout(self):
        """scale drops exactly the trailing head_dim axis — the
        (N, ps, Hkv) parallel-array contract the pool relies on."""
        x = jax.random.normal(jax.random.key(5), (6, 4, 2, 8))
        for mode in ("int8", "fp8_e4m3"):
            codes, scale = quant.quantize(x, mode)
            assert codes.shape == x.shape
            assert scale.shape == x.shape[:-1]
            assert scale.dtype == jnp.float32
