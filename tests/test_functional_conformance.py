"""Op-surface conformance harness for ``nn.functional`` (the gate on the
dispatch-cache extension).

Every ``F.*`` op runs three ways against a plain-jnp reference:

  * **uncached** — dispatch cache disabled (the re-traced seed path),
    checked ``allclose`` against the reference math,
  * **cold** — cache reset, first dispatch (miss: traces + populates),
  * **warm** — second dispatch with identical inputs (must HIT).

Cold and warm must be **bitwise identical**: both replay the same jitted
executable, so any difference means the cache key selected a *different*
entry — i.e. a closure capture missing from the op's ``static=`` tuple.
A wrong key cannot pass this suite silently.

The cache-hygiene regression tests at the bottom pin the whole nn layer
to the fast path: a full MLP train step must finish with zero uncached
and zero unhashable-fallback dispatches.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
import repro.nn as nn
import repro.nn.functional as F
import repro.optim as optim
from repro.core import dispatch as D

pytestmark = pytest.mark.slow   # cold/warm conformance matrix: full CI job


@pytest.fixture(autouse=True)
def fresh_cache():
    D.reset_dispatch_cache()
    yield
    D.reset_dispatch_cache()


def _rng(seed=0):
    return np.random.default_rng(seed)


def _randn(*shape, seed=0, scale=1.0):
    return jnp.asarray(
        _rng(seed).standard_normal(shape, dtype=np.float32) * scale)


# ----------------------------------------------------------------------
# the op surface: (name, build) where build() -> (call, ref)
#   call(): runs the F.* op over repro Tensors, returns Tensor
#   ref():  the same math in plain jnp over the raw arrays
# ----------------------------------------------------------------------

def _elementwise(op, ref, seed=0, shape=(5, 7)):
    x = _randn(*shape, seed=seed)
    return (lambda: op(repro.Tensor(x)), lambda: ref(x))


def _case_relu():
    return _elementwise(F.relu, jax.nn.relu)


def _case_relu6():
    return _elementwise(F.relu6, jax.nn.relu6, seed=1)


def _case_gelu_tanh():
    return _elementwise(lambda t: F.gelu(t, approximate="tanh"),
                        lambda a: jax.nn.gelu(a, approximate=True), seed=2)


def _case_gelu_none():
    return _elementwise(lambda t: F.gelu(t, approximate="none"),
                        lambda a: jax.nn.gelu(a, approximate=False), seed=2)


def _case_silu():
    return _elementwise(F.silu, jax.nn.silu, seed=3)


def _case_sigmoid():
    return _elementwise(F.sigmoid, jax.nn.sigmoid, seed=4)


def _case_tanh():
    return _elementwise(F.tanh, jnp.tanh, seed=5)


def _case_softplus():
    return _elementwise(F.softplus, jax.nn.softplus, seed=6)


def _case_hardswish():
    return _elementwise(F.hardswish, jax.nn.hard_swish, seed=7)


def _case_leaky_relu():
    return _elementwise(lambda t: F.leaky_relu(t, 0.2),
                        lambda a: jax.nn.leaky_relu(a, 0.2), seed=8)


def _case_elu():
    return _elementwise(lambda t: F.elu(t, alpha=1.5),
                        lambda a: jax.nn.elu(a, 1.5), seed=9)


def _case_softmax_dim0():
    return _elementwise(lambda t: F.softmax(t, dim=0),
                        lambda a: jax.nn.softmax(a, axis=0), seed=10)


def _case_softmax_dimlast():
    return _elementwise(lambda t: F.softmax(t, dim=-1),
                        lambda a: jax.nn.softmax(a, axis=-1), seed=10)


def _case_log_softmax():
    return _elementwise(lambda t: F.log_softmax(t, dim=-1),
                        lambda a: jax.nn.log_softmax(a, axis=-1), seed=11)


def _case_linear_bias():
    x, w, b = _randn(4, 6, seed=12), _randn(3, 6, seed=13), \
        _randn(3, seed=14)
    return (lambda: F.linear(repro.Tensor(x), repro.Tensor(w),
                             repro.Tensor(b)),
            lambda: x @ w.T + b)


def _case_linear_nobias():
    x, w = _randn(4, 6, seed=12), _randn(3, 6, seed=13)
    return (lambda: F.linear(repro.Tensor(x), repro.Tensor(w)),
            lambda: x @ w.T)


def _case_embedding():
    w = _randn(11, 5, seed=15)
    idx = jnp.asarray(_rng(16).integers(0, 11, size=(4, 3)))
    return (lambda: F.embedding(repro.Tensor(idx), repro.Tensor(w)),
            lambda: jnp.take(w, idx, axis=0))


def _case_layer_norm():
    x = _randn(4, 6, seed=17)
    w, b = _randn(6, seed=18), _randn(6, seed=19)

    def ref():
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mean) * jax.lax.rsqrt(var + 1e-5) * w + b

    return (lambda: F.layer_norm(repro.Tensor(x), (6,), repro.Tensor(w),
                                 repro.Tensor(b)), ref)


def _case_layer_norm_plain():
    x = _randn(4, 6, seed=17)

    def ref():
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mean) * jax.lax.rsqrt(var + 1e-5)

    return (lambda: F.layer_norm(repro.Tensor(x), (6,)), ref)


def _case_rms_norm():
    x, w = _randn(4, 6, seed=20), _randn(6, seed=21)

    def ref():
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(var + 1e-6) * (1.0 + w)

    return (lambda: F.rms_norm(repro.Tensor(x), repro.Tensor(w),
                               offset=1.0), ref)


def _case_batch_norm_eval():
    x = _randn(2, 3, 4, 4, seed=22)
    rm, rv = _randn(3, seed=23) * 0.1, jnp.abs(_randn(3, seed=24)) + 0.5
    w, b = _randn(3, seed=25), _randn(3, seed=26)

    def ref():
        sh = (1, 3, 1, 1)
        out = (x - rm.reshape(sh)) * jax.lax.rsqrt(rv.reshape(sh) + 1e-5)
        return out * w.reshape(sh) + b.reshape(sh)

    return (lambda: F.batch_norm(
        repro.Tensor(x), repro.Tensor(rm), repro.Tensor(rv),
        repro.Tensor(w), repro.Tensor(b), training=False), ref)


def _case_batch_norm_train():
    x = _randn(2, 3, 4, 4, seed=27)

    def ref():
        m = jnp.mean(x, axis=(0, 2, 3)).reshape(1, 3, 1, 1)
        v = jnp.var(x, axis=(0, 2, 3)).reshape(1, 3, 1, 1)
        return (x - m) * jax.lax.rsqrt(v + 1e-5)

    def call():
        rm, rv = repro.zeros(3), repro.ones(3)
        return F.batch_norm(repro.Tensor(x), rm, rv, training=True)

    return (call, ref)


def _conv2d_ref(x, w, b, stride, pad, dilation=(1, 1), groups=1):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=pad, rhs_dilation=dilation,
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out


def _case_conv2d():
    x, w, b = _randn(2, 3, 8, 8, seed=28), _randn(4, 3, 3, 3, seed=29), \
        _randn(4, seed=30)
    return (lambda: F.conv2d(repro.Tensor(x), repro.Tensor(w),
                             repro.Tensor(b), stride=2, padding=1),
            lambda: _conv2d_ref(x, w, b, (2, 2), ((1, 1), (1, 1))))


def _case_conv2d_same_dilated():
    x, w = _randn(1, 2, 8, 8, seed=31), _randn(2, 2, 3, 3, seed=32)
    return (lambda: F.conv2d(repro.Tensor(x), repro.Tensor(w),
                             padding="same", dilation=2),
            lambda: _conv2d_ref(x, w, None, (1, 1), "SAME", (2, 2)))


def _case_conv2d_grouped():
    x, w = _randn(1, 4, 6, 6, seed=33), _randn(4, 2, 3, 3, seed=34)
    return (lambda: F.conv2d(repro.Tensor(x), repro.Tensor(w), groups=2),
            lambda: _conv2d_ref(x, w, None, (1, 1),
                                ((0, 0), (0, 0)), groups=2))


def _case_conv1d():
    x, w, b = _randn(2, 3, 10, seed=35), _randn(5, 3, 3, seed=36), \
        _randn(5, seed=37)

    def ref():
        out = jax.lax.conv_general_dilated(
            x, w, window_strides=(1,), padding=((1, 1),),
            rhs_dilation=(1,), feature_group_count=1,
            dimension_numbers=("NCH", "OIH", "NCH"))
        return out + b.reshape(1, -1, 1)

    return (lambda: F.conv1d(repro.Tensor(x), repro.Tensor(w),
                             repro.Tensor(b), padding=1), ref)


def _case_max_pool2d():
    x = _randn(2, 3, 8, 8, seed=38)
    return (lambda: F.max_pool2d(repro.Tensor(x), 2),
            lambda: jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2),
                ((0, 0), (0, 0), (0, 0), (0, 0))))


def _case_avg_pool2d():
    x = _randn(2, 3, 8, 8, seed=39)
    return (lambda: F.avg_pool2d(repro.Tensor(x), 2),
            lambda: jax.lax.reduce_window(
                x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2),
                ((0, 0), (0, 0), (0, 0), (0, 0))) / 4.0)


def _case_adaptive_avg_pool2d():
    x = _randn(2, 3, 8, 8, seed=40)
    return (lambda: F.adaptive_avg_pool2d(repro.Tensor(x), 2),
            lambda: x.reshape(2, 3, 2, 4, 2, 4).mean(axis=(3, 5)))


def _case_dropout():
    # explicit rng key: the mask is then a pure function of the key, so
    # cold and warm calls see identical operands (bitwise check valid)
    x = _randn(6, 6, seed=41)
    key = jax.random.key(7)

    def ref():
        mask = jax.random.bernoulli(key, 0.75, (6, 6)).astype(x.dtype)
        return x * mask * (1.0 / 0.75)

    return (lambda: F.dropout(repro.Tensor(x), p=0.25, rng=key), ref)


def _ce_ref(lg, tgt, ignore_index=-100, label_smoothing=0.0,
            reduction="mean"):
    logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    flat_lp = logp.reshape(-1, lg.shape[-1])
    flat_t = tgt.reshape(-1)
    valid = flat_t != ignore_index
    safe = jnp.where(valid, flat_t, 0)
    picked = jnp.take_along_axis(flat_lp, safe[:, None], axis=-1)[:, 0]
    if label_smoothing > 0.0:
        smooth = jnp.mean(flat_lp, axis=-1)
        picked = (1 - label_smoothing) * picked + label_smoothing * smooth
    loss = -jnp.where(valid, picked, 0.0)
    if reduction == "mean":
        return loss.sum() / jnp.maximum(valid.sum(), 1)
    if reduction == "sum":
        return loss.sum()
    return loss.reshape(tgt.shape)


def _case_cross_entropy():
    lg = _randn(5, 8, seed=42)
    tgt = jnp.asarray(_rng(43).integers(0, 8, size=(5,)))
    return (lambda: F.cross_entropy(repro.Tensor(lg), repro.Tensor(tgt)),
            lambda: _ce_ref(lg, tgt))


def _case_cross_entropy_smooth_ignore():
    lg = _randn(6, 8, seed=44)
    tgt = jnp.asarray(np.array([1, 2, -100, 4, -100, 7]))
    return (lambda: F.cross_entropy(repro.Tensor(lg), repro.Tensor(tgt),
                                    label_smoothing=0.1, reduction="sum"),
            lambda: _ce_ref(lg, tgt, label_smoothing=0.1, reduction="sum"))


def _case_nll_loss():
    lp = jax.nn.log_softmax(_randn(5, 8, seed=45), axis=-1)
    tgt = jnp.asarray(_rng(46).integers(0, 8, size=(5,)))

    def ref():
        picked = jnp.take_along_axis(lp, tgt[:, None], axis=-1)[:, 0]
        return -picked.mean()

    return (lambda: F.nll_loss(repro.Tensor(lp), repro.Tensor(tgt)), ref)


def _case_mse_loss():
    a, b = _randn(4, 5, seed=47), _randn(4, 5, seed=48)
    return (lambda: F.mse_loss(repro.Tensor(a), repro.Tensor(b)),
            lambda: jnp.square(a - b).mean())


def _case_mse_loss_none():
    a, b = _randn(4, 5, seed=47), _randn(4, 5, seed=48)
    return (lambda: F.mse_loss(repro.Tensor(a), repro.Tensor(b),
                               reduction="none"),
            lambda: jnp.square(a - b))


def _case_bce_logits():
    lg = _randn(4, 5, seed=49)
    t = (jnp.asarray(_rng(50).random((4, 5))) > 0.5).astype(jnp.float32)

    def ref():
        loss = (jnp.maximum(lg, 0) - lg * t
                + jnp.log1p(jnp.exp(-jnp.abs(lg))))
        return loss.mean()

    return (lambda: F.binary_cross_entropy_with_logits(
        repro.Tensor(lg), repro.Tensor(t)), ref)


def _case_sdpa_causal():
    from repro.kernels import ref as kref
    q, k, v = (_randn(1, 2, 6, 4, seed=s) for s in (51, 52, 53))
    return (lambda: F.scaled_dot_product_attention(
        repro.Tensor(q), repro.Tensor(k), repro.Tensor(v), is_causal=True),
        lambda: kref.flash_attention(q, k, v, causal=True))


def _case_sdpa_masked():
    from repro.models.attention import sdpa_ref
    q, k, v = (_randn(1, 2, 6, 4, seed=s) for s in (54, 55, 56))
    mask = jnp.asarray(_rng(57).random((1, 1, 6, 6)) > 0.3)
    return (lambda: F.scaled_dot_product_attention(
        repro.Tensor(q), repro.Tensor(k), repro.Tensor(v),
        attn_mask=repro.Tensor(mask)),
        lambda: sdpa_ref(q, k, v, mask=mask))


def _case_pad():
    x = _randn(3, 4, seed=58)
    return (lambda: F.pad(repro.Tensor(x), (1, 2, 0, 1), value=-1.0),
            lambda: jnp.pad(x, ((0, 1), (1, 2)), constant_values=-1.0))


def _case_normalize():
    x = _randn(4, 6, seed=59)

    def ref():
        n = jnp.linalg.norm(x, ord=2.0, axis=-1, keepdims=True)
        return x / jnp.maximum(n, 1e-12)

    return (lambda: F.normalize(repro.Tensor(x)), ref)


CASES = {
    name[len("_case_"):]: fn
    for name, fn in sorted(globals().items())
    if name.startswith("_case_")
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_forward_conformance_cold_warm(case):
    call, ref = CASES[case]()
    expected = np.asarray(ref())

    # uncached reference path: cache disabled entirely
    with D.cache_disabled():
        uncached = np.asarray(call().data)
    np.testing.assert_allclose(uncached, expected, rtol=2e-5, atol=1e-6)

    # cold: fresh cache, first dispatch populates
    D.reset_dispatch_cache()
    cold = np.asarray(call().data)
    misses = repro.dispatch_cache_stats()["num_misses"]
    assert misses >= 1

    # warm: identical call must hit and be bitwise identical — a wrong
    # cache key would replay a different closure and diverge
    warm_t = call()
    warm = np.asarray(warm_t.data)
    stats = repro.dispatch_cache_stats()
    assert stats["num_hits"] >= 1, stats
    assert stats["num_misses"] == misses, \
        f"warm call re-missed: {stats}"
    assert cold.tobytes() == warm.tobytes(), \
        f"{case}: cold vs warm results differ — wrong cache key"
    np.testing.assert_allclose(cold, expected, rtol=2e-5, atol=1e-6)


def test_per_op_breakdown_attributes_ops():
    x = repro.randn(4, 4)
    _ = F.relu(x)
    _ = F.relu(x)
    _ = F.gelu(x)
    per_op = repro.dispatch_cache_stats()["per_op"]
    assert per_op["relu"]["misses"] == 1
    assert per_op["relu"]["hits"] == 1
    assert per_op["relu"]["hit_rate"] == 0.5
    assert per_op["gelu"]["misses"] == 1


class TestCacheHygiene:
    """The whole nn layer must stay on the fast path: any future call
    site dropping its ``static=`` descriptor trips these."""

    def _mlp_step(self, steps=2):
        repro.manual_seed(0)
        model = nn.Sequential(
            nn.Linear(16, 32), nn.ReLU(),
            nn.Linear(32, 32), nn.GELU(),
            nn.Linear(32, 4))
        opt = optim.SGD(model.parameters(), lr=1e-2, momentum=0.9)
        x = repro.randn(8, 16)
        y = repro.randn(8, 4)
        for _ in range(steps):
            out = model(x)
            loss = F.mse_loss(out, y)
            opt.zero_grad()
            loss.backward()
            opt.step()
        return loss

    def test_mlp_train_step_fully_cached(self):
        self._mlp_step()
        stats = repro.dispatch_cache_stats()
        assert stats["num_uncached"] == 0, stats
        assert stats["num_fallback_unhashable"] == 0, stats
        # every op that dispatched is attributable and on the fast path
        for op, rec in stats["per_op"].items():
            assert rec["uncached"] == 0, (op, rec)
            assert rec["fallback_unhashable"] == 0, (op, rec)

    def test_mlp_second_step_all_hits(self):
        self._mlp_step(steps=1)
        s1 = repro.dispatch_cache_stats()
        self._mlp_step(steps=1)  # same shapes: fully warm
        s2 = repro.dispatch_cache_stats()
        assert s2["num_misses"] == s1["num_misses"], (s1, s2)
        assert s2["num_hits"] > s1["num_hits"]

    def test_classifier_step_with_ce_and_softmax(self):
        repro.manual_seed(1)
        model = nn.Sequential(nn.Linear(10, 24), nn.ReLU(),
                              nn.LayerNorm(24), nn.Linear(24, 6))
        opt = optim.AdamW(model.parameters(), lr=1e-3)
        x = repro.randn(8, 10)
        tgt = repro.tensor(np.asarray(_rng(5).integers(0, 6, size=(8,))))
        for _ in range(2):
            loss = F.cross_entropy(model(x), tgt)
            opt.zero_grad()
            loss.backward()
            opt.step()
        stats = repro.dispatch_cache_stats()
        assert stats["num_uncached"] == 0, stats
        assert stats["num_fallback_unhashable"] == 0, stats


class TestCompileSeeding:
    def test_compile_seeds_eager_entries(self):
        lin = nn.Linear(8, 8)

        @repro.compile(seed_cache=True)
        def fwd(t):
            return F.gelu(lin(t))

        _ = fwd(repro.randn(3, 8))
        assert "linear" in fwd.seeded_ops and "gelu" in fwd.seeded_ops
        stats = repro.dispatch_cache_stats()
        assert stats["num_seeded"] > 0

        # the eager dispatch of the same signature starts warm: no miss
        misses_before = stats["num_misses"]
        _ = F.gelu(lin(repro.randn(3, 8)))
        stats = repro.dispatch_cache_stats()
        assert stats["num_misses"] == misses_before, stats
        assert stats["per_op"]["gelu"]["hits"] >= 1
        assert stats["per_op"]["linear"]["hits"] >= 1

    def test_seeded_entry_value_matches_uncached(self):
        lin = nn.Linear(6, 6)
        x = repro.randn(2, 6)

        with D.cache_disabled():
            expected = np.asarray(F.silu(lin(x)).data)

        @repro.compile(seed_cache=True)
        def fwd(t):
            return F.silu(lin(t))

        _ = fwd(x)
        got = np.asarray(F.silu(lin(x)).data)  # replays seeded entries
        np.testing.assert_allclose(got, expected, rtol=2e-6, atol=1e-7)
