"""Deterministic fake-clock harness shared across tier-1 tests.

Every deadline-bearing component in the serving stack (``Scheduler``,
``ServingEngine``, and through them the front door) takes an injectable
``clock`` callable.  :class:`FakeClock` is the test-side implementation:
virtual seconds that only move when a test says so, so no tier-1 test
ever sleeps on wall time and every deadline assertion is reproducible.

Usage::

    from clockutil import FakeClock

    clk = FakeClock()
    eng = ServingEngine(cfg, params, clock=clk, ...)
    eng.submit(prompt, ttft_deadline_ms=50.0)
    clk.advance(0.1)        # 100ms of virtual time
    eng.step()              # deadline expiry is now observable

(The tests directory is on ``sys.path`` via pytest's rootdir insertion;
``benchmarks/bench_traffic.py`` imports this module the same way so the
traffic simulator and the tests share one clock.)
"""

from __future__ import annotations

__all__ = ["FakeClock"]


class FakeClock:
    """Deterministic virtual clock (seconds).  Call it like
    ``time.monotonic``; move it with :meth:`advance`."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        """Advance virtual time by ``dt`` seconds; returns the new
        time.  Negative ``dt`` is rejected — deadlines assume a
        monotone clock."""
        if dt < 0:
            raise ValueError(f"clock must be monotone (dt={dt})")
        self.t += dt
        return self.t
