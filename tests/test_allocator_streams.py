"""Caching allocator (§5.3), refcounting (§5.5), streams/events (§5.2)."""

import gc

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.core.allocator import (ROUND_BYTES, CachingAllocator,
                                  round_size)
from repro.core.stream import Event, Stream, current_stream, stream


class TestRounding:
    def test_rounds_to_512(self):
        assert round_size(1) == ROUND_BYTES
        assert round_size(512) == 512
        assert round_size(513) == 1024

    @given(n=st.integers(0, 1 << 24))
    @settings(max_examples=100, deadline=None)
    def test_round_properties(self, n):
        r = round_size(n)
        assert r >= max(n, ROUND_BYTES)
        assert r % ROUND_BYTES == 0
        assert r - n < ROUND_BYTES or n == 0


class TestCachePolicy:
    def test_same_size_reuses_block(self):
        alloc = CachingAllocator()
        b1 = alloc.allocate(1000, stream=0)
        alloc.free(b1)
        b2 = alloc.allocate(900, stream=0)  # same rounded size (1024)
        assert b2 is b1
        assert alloc.stats.num_cache_hits == 1
        assert alloc.stats.num_system_allocs == 1

    def test_per_stream_pools(self):
        alloc = CachingAllocator()
        b1 = alloc.allocate(1024, stream=0)
        alloc.free(b1)
        b2 = alloc.allocate(1024, stream=1)  # different pool: miss
        assert b2 is not b1
        assert alloc.stats.num_cache_misses == 2

    def test_cross_stream_free_defers_reuse(self):
        alloc = CachingAllocator()
        b = alloc.allocate(2048, stream=0)
        alloc.free(b, stream=1)          # freed on another stream
        b2 = alloc.allocate(2048, stream=0)
        assert b2 is not b               # not reusable until sync
        alloc.synchronize()
        b3 = alloc.allocate(2048, stream=0)
        assert b3 is b

    def test_empty_cache(self):
        alloc = CachingAllocator()
        blocks = [alloc.allocate(4096) for _ in range(4)]
        for b in blocks:
            alloc.free(b)
        freed = alloc.empty_cache()
        assert freed == 4 * 4096
        assert alloc.stats.bytes_reserved == 0

    @given(sizes=st.lists(st.integers(1, 1 << 16), min_size=1,
                          max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_accounting_invariants(self, sizes):
        """Property: active ≤ reserved; peak ≥ active; free-all zeroes
        active but keeps reserved (the cache)."""
        alloc = CachingAllocator()
        blocks = []
        for s in sizes:
            blocks.append(alloc.allocate(s))
            st_ = alloc.stats
            assert st_.bytes_active <= st_.bytes_reserved
            assert st_.peak_bytes_active >= st_.bytes_active
        for b in blocks:
            alloc.free(b)
        assert alloc.stats.bytes_active == 0
        assert alloc.stats.bytes_reserved == sum(
            round_size(s) for s in sizes)
        # second pass with identical sizes: 100% cache hits
        before = alloc.stats.num_system_allocs
        for s in sizes:
            alloc.allocate(s)
        assert alloc.stats.num_system_allocs == before


class TestRefcounting:
    def test_tensor_del_returns_block(self):
        alloc = repro.allocator.device_allocator()
        base_active = alloc.stats.bytes_active
        t = repro.zeros(1024, 1024)  # 4MB
        assert alloc.stats.bytes_active >= base_active + 4 * 1024 * 1024
        del t
        gc.collect()
        assert alloc.stats.bytes_active <= base_active + ROUND_BYTES

    def test_graph_release_frees_saved(self):
        alloc = repro.allocator.device_allocator()
        a = repro.randn(256, 256, requires_grad=True)
        loss = (a.exp() * 2.0).sum()
        mid = alloc.stats.bytes_active
        loss.backward()  # releases node closures
        del loss
        gc.collect()
        assert alloc.stats.bytes_active < mid

    def test_views_share_storage(self):
        t = repro.zeros(64, 64)
        v = t[0]
        assert v._storage is t._storage


class TestStreams:
    def test_current_stream_context(self):
        s = Stream()
        assert current_stream() is not s
        with stream(s):
            assert current_stream() is s
            t = repro.randn(8)
        assert current_stream() is not s

    def test_stream_synchronize_and_query(self):
        s = Stream()
        with stream(s):
            x = repro.randn(64, 64)
            y = x @ x
        s.synchronize()
        assert s.query()

    def test_event_ordering(self):
        s1, s2 = Stream(), Stream()
        with stream(s1):
            x = repro.randn(32, 32) @ repro.randn(32, 32)
        ev = s1.record_event()
        s2.wait_event(ev)
        assert ev.query()

    def test_event_timing(self):
        e1 = Event(enable_timing=True)
        e2 = Event(enable_timing=True)
        e1.record()
        _ = repro.randn(64, 64) @ repro.randn(64, 64)
        e2.record()
        assert e1.elapsed_time(e2) >= 0.0

    def test_tensor_tracks_stream(self):
        s = Stream()
        with stream(s):
            t = repro.randn(4)
        assert t._storage.stream_id == s.stream_id
