"""Numeric gradient checking for the ``nn.functional`` surface.

``gradcheck(fn, inputs)`` compares the tape's ``backward()`` against a
central-difference numeric vJp.  Because every ``F.*`` op replays a
*cached* jitted VJP after its first dispatch, this suite is the gradient
half of the dispatch-cache gate: a ``static=`` tuple missing a closure
capture produces *silently wrong gradients* (same op name + same shapes
+ forgotten kwarg = stale entry replayed with the wrong closure), and
the kwarg-collision tests below are built to trip exactly that.

Method: with a fixed random cotangent ``v``, ``backward(v)`` yields
``v^T J`` per input; the numeric side perturbs each input element by
``±eps`` and differences ``<f(x), v>``.  One backward + 2·numel cached
forward replays per input — cheap, and itself a cache stress test (every
perturbation shares one dispatch signature).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
import repro.nn.functional as F
from repro.core import dispatch as D

pytestmark = pytest.mark.slow   # numeric-gradient matrix: full CI job


@pytest.fixture(autouse=True)
def fresh_cache():
    D.reset_dispatch_cache()
    yield
    D.reset_dispatch_cache()


def _rng(seed=0):
    return np.random.default_rng(seed)


def _randn(*shape, seed=0, scale=1.0):
    return jnp.asarray(
        _rng(seed).standard_normal(shape, dtype=np.float32) * scale)


def _randn_away_from(kinks, *shape, seed=0, margin=0.08):
    """Standard normals pushed ``margin`` away from each kink point, so
    central differences of piecewise-linear ops never straddle one."""
    a = _rng(seed).standard_normal(shape).astype(np.float64)
    for k in kinks:
        near = np.abs(a - k) < margin
        a = np.where(near, k + np.sign(a - k + 1e-12) * margin, a)
    return jnp.asarray(a.astype(np.float32))


def _distinct_grid(*shape, seed=0, step=0.1):
    """All-distinct values (gaps >= step): argmax selections in pooling
    stay stable under +-eps perturbation."""
    n = int(np.prod(shape))
    vals = _rng(seed).permutation(n).astype(np.float32) * step
    return jnp.asarray(vals.reshape(shape))


def gradcheck(fn, inputs, eps=1e-2, rtol=5e-2, atol=1e-2, seed=123):
    """Check ``backward()`` of ``fn(*inputs)`` against central differences.

    ``fn`` maps repro Tensors to one Tensor; ``inputs`` are raw arrays.
    Returns True, or raises AssertionError naming the offending input.
    """
    tensors = [repro.Tensor(a, requires_grad=True) for a in inputs]
    out = fn(*tensors)
    cot = _rng(seed).standard_normal(out.shape).astype(np.float32)
    out.backward(repro.Tensor(jnp.asarray(cot)))
    analytic = [
        np.zeros(t.shape, np.float64) if t.grad is None
        else np.asarray(t.grad.data, dtype=np.float64)
        for t in tensors
    ]

    def eval_dot(arrays):
        with repro.no_grad():
            o = fn(*[repro.Tensor(a) for a in arrays])
        return float(np.vdot(np.asarray(o.data, dtype=np.float64), cot))

    arrays = [np.asarray(a, dtype=np.float64) for a in inputs]
    for ai, a in enumerate(arrays):
        numeric = np.zeros(a.size, np.float64)
        flat = a.ravel()
        for i in range(a.size):
            orig = flat[i]
            flat[i] = orig + eps
            plus = eval_dot([jnp.asarray(x, dtype=jnp.float32)
                             for x in arrays])
            flat[i] = orig - eps
            minus = eval_dot([jnp.asarray(x, dtype=jnp.float32)
                              for x in arrays])
            flat[i] = orig
            numeric[i] = (plus - minus) / (2 * eps)
        np.testing.assert_allclose(
            numeric.reshape(a.shape), analytic[ai], rtol=rtol, atol=atol,
            err_msg=f"input {ai}: analytic vjp disagrees with "
                    f"central differences")
    return True


# ----------------------------------------------------------------------
# the differentiable F.* surface
# ----------------------------------------------------------------------

GRAD_CASES = {
    "relu": lambda: gradcheck(
        F.relu, [_randn_away_from((0.0,), 4, 5, seed=1)]),
    "relu6": lambda: gradcheck(
        F.relu6, [_randn_away_from((0.0, 6.0), 4, 5, seed=2, margin=0.1)]),
    "leaky_relu": lambda: gradcheck(
        lambda t: F.leaky_relu(t, 0.2),
        [_randn_away_from((0.0,), 4, 5, seed=3)]),
    "elu": lambda: gradcheck(
        lambda t: F.elu(t, alpha=1.5), [_randn(4, 5, seed=4)]),
    "gelu_tanh": lambda: gradcheck(
        lambda t: F.gelu(t, "tanh"), [_randn(4, 5, seed=5)]),
    "gelu_none": lambda: gradcheck(
        lambda t: F.gelu(t, "none"), [_randn(4, 5, seed=6)]),
    "silu": lambda: gradcheck(F.silu, [_randn(4, 5, seed=7)]),
    "sigmoid": lambda: gradcheck(F.sigmoid, [_randn(4, 5, seed=8)]),
    "tanh": lambda: gradcheck(F.tanh, [_randn(4, 5, seed=9)]),
    "softplus": lambda: gradcheck(F.softplus, [_randn(4, 5, seed=10)]),
    "hardswish": lambda: gradcheck(
        F.hardswish,
        [_randn_away_from((-3.0, 3.0), 4, 5, seed=11, margin=0.1)]),
    "softmax": lambda: gradcheck(
        lambda t: F.softmax(t, dim=-1), [_randn(3, 6, seed=12)]),
    "softmax_dim0": lambda: gradcheck(
        lambda t: F.softmax(t, dim=0), [_randn(3, 6, seed=12)]),
    "log_softmax": lambda: gradcheck(
        lambda t: F.log_softmax(t, dim=-1), [_randn(3, 6, seed=13)]),
    "linear": lambda: gradcheck(
        F.linear, [_randn(3, 4, seed=14), _randn(2, 4, seed=15),
                   _randn(2, seed=16)]),
    "embedding": lambda: gradcheck(
        lambda w: F.embedding(
            repro.Tensor(jnp.asarray([[0, 2], [3, 1]])), w),
        [_randn(5, 3, seed=17)]),
    "layer_norm": lambda: gradcheck(
        lambda x, w, b: F.layer_norm(x, (6,), w, b),
        [_randn(3, 6, seed=18), _randn(6, seed=19), _randn(6, seed=20)]),
    "rms_norm": lambda: gradcheck(
        lambda x, w: F.rms_norm(x, w, offset=1.0),
        [_randn(3, 6, seed=21), _randn(6, seed=22)]),
    "batch_norm_train": lambda: gradcheck(
        lambda x, w, b: F.batch_norm(x, None, None, w, b, training=True),
        [_randn(2, 3, 4, 4, seed=23), _randn(3, seed=24),
         _randn(3, seed=25)], eps=2e-2, rtol=8e-2, atol=2e-2),
    "batch_norm_eval": lambda: gradcheck(
        lambda x, w, b: F.batch_norm(
            x, repro.Tensor(_randn(3, seed=26) * 0.1),
            repro.Tensor(jnp.abs(_randn(3, seed=27)) + 0.5),
            w, b, training=False),
        [_randn(2, 3, 4, 4, seed=28), _randn(3, seed=29),
         _randn(3, seed=30)]),
    "conv2d": lambda: gradcheck(
        lambda x, w, b: F.conv2d(x, w, b, stride=2, padding=1),
        [_randn(1, 2, 6, 6, seed=31), _randn(2, 2, 3, 3, seed=32),
         _randn(2, seed=33)]),
    "conv1d": lambda: gradcheck(
        lambda x, w: F.conv1d(x, w, padding=1),
        [_randn(1, 2, 8, seed=34), _randn(3, 2, 3, seed=35)]),
    "max_pool2d": lambda: gradcheck(
        lambda x: F.max_pool2d(x, 2),
        [_distinct_grid(1, 2, 6, 6, seed=36)]),
    "avg_pool2d": lambda: gradcheck(
        lambda x: F.avg_pool2d(x, 2), [_randn(1, 2, 6, 6, seed=37)]),
    "adaptive_avg_pool2d": lambda: gradcheck(
        lambda x: F.adaptive_avg_pool2d(x, 2),
        [_randn(1, 2, 6, 6, seed=38)]),
    "dropout": lambda: gradcheck(
        lambda x: F.dropout(x, p=0.25, rng=jax.random.key(3)),
        [_randn(5, 5, seed=39)]),
    "cross_entropy": lambda: gradcheck(
        lambda lg: F.cross_entropy(
            lg, repro.Tensor(jnp.asarray([1, 3, -100, 0])),
            label_smoothing=0.1),
        [_randn(4, 6, seed=40)]),
    "nll_loss": lambda: gradcheck(
        lambda lp: F.nll_loss(
            lp, repro.Tensor(jnp.asarray([1, 3, 0]))),
        [_randn(3, 6, seed=41)]),
    "mse_loss": lambda: gradcheck(
        F.mse_loss, [_randn(3, 4, seed=42), _randn(3, 4, seed=43)]),
    "bce_logits": lambda: gradcheck(
        lambda lg, t: F.binary_cross_entropy_with_logits(lg, t),
        [_randn(3, 4, seed=44),
         jnp.abs(_randn(3, 4, seed=45)) % 1.0]),
    "sdpa": lambda: gradcheck(
        lambda q, k, v: F.scaled_dot_product_attention(
            q, k, v, is_causal=True),
        [_randn(1, 1, 4, 3, seed=46), _randn(1, 1, 4, 3, seed=47),
         _randn(1, 1, 4, 3, seed=48)]),
    "pad": lambda: gradcheck(
        lambda x: F.pad(x, (1, 1), value=0.5), [_randn(3, 4, seed=49)]),
    "normalize": lambda: gradcheck(
        lambda x: F.normalize(x, dim=-1), [_randn(3, 4, seed=50)]),
}


@pytest.mark.parametrize("case", sorted(GRAD_CASES))
def test_gradcheck(case):
    assert GRAD_CASES[case]()


def test_gradcheck_warm_replay_matches_cold():
    """The SAME gradcheck run twice: the second pass replays cached
    jitted VJPs for every op, so it double-checks the warm path."""
    x = _randn(3, 6, seed=60)
    assert gradcheck(lambda t: F.softmax(t, dim=-1), [x])
    hits_before = repro.dispatch_cache_stats()["num_hits"]
    assert gradcheck(lambda t: F.softmax(t, dim=-1), [x])
    assert repro.dispatch_cache_stats()["num_hits"] > hits_before


# ----------------------------------------------------------------------
# kwarg-collision cases: same op name, same operand shapes, different
# closure kwargs.  If any one ``static=`` tuple is emptied these replay
# a stale entry and fail loudly.
# ----------------------------------------------------------------------

class TestKwargCollisions:
    def test_softmax_dim_collision(self):
        x = repro.Tensor(_randn(4, 4, seed=70), requires_grad=True)
        a = F.softmax(x, dim=0)
        b = F.softmax(x, dim=-1)
        np.testing.assert_allclose(
            np.asarray(a.data), np.asarray(jax.nn.softmax(x.data, axis=0)),
            rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(b.data),
            np.asarray(jax.nn.softmax(x.data, axis=-1)), rtol=1e-6)

    def test_softmax_dim_collision_gradients(self):
        # the VJP entry is keyed by the same signature: a dropped static
        # would replay dim=0's backward for the dim=-1 call
        xd = _randn(4, 4, seed=71)
        _ = F.softmax(repro.Tensor(xd, requires_grad=True), dim=0) \
            .sum().backward()
        x = repro.Tensor(xd, requires_grad=True)
        (F.softmax(x, dim=-1) * repro.Tensor(xd)).sum().backward()
        ref = jax.grad(
            lambda v: (jax.nn.softmax(v, axis=-1) * xd).sum())(xd)
        np.testing.assert_allclose(np.asarray(x.grad.data),
                                   np.asarray(ref), rtol=1e-4, atol=1e-6)

    def test_gelu_approximate_collision(self):
        xd = _randn(4, 4, seed=72)
        a = F.gelu(repro.Tensor(xd), "tanh")
        b = F.gelu(repro.Tensor(xd), "none")
        np.testing.assert_allclose(
            np.asarray(a.data),
            np.asarray(jax.nn.gelu(xd, approximate=True)), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(b.data),
            np.asarray(jax.nn.gelu(xd, approximate=False)), rtol=1e-6)
        assert not np.allclose(np.asarray(a.data), np.asarray(b.data),
                               rtol=1e-6, atol=1e-7)

    def test_leaky_relu_slope_collision(self):
        xd = _randn(4, 4, seed=73)
        a = F.leaky_relu(repro.Tensor(xd), 0.01)
        b = F.leaky_relu(repro.Tensor(xd), 0.5)
        np.testing.assert_allclose(
            np.asarray(a.data), np.asarray(jax.nn.leaky_relu(xd, 0.01)),
            rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(b.data), np.asarray(jax.nn.leaky_relu(xd, 0.5)),
            rtol=1e-6)

    def test_elu_alpha_collision(self):
        xd = _randn(4, 4, seed=74)
        for alpha in (1.0, 2.0):
            got = F.elu(repro.Tensor(xd), alpha=alpha)
            np.testing.assert_allclose(
                np.asarray(got.data), np.asarray(jax.nn.elu(xd, alpha)),
                rtol=1e-6)

    def test_norm_eps_collision(self):
        xd = _randn(3, 6, seed=75)
        for eps in (1e-6, 0.5):
            got = F.rms_norm(repro.Tensor(xd), eps=eps)
            var = jnp.mean(jnp.square(xd), axis=-1, keepdims=True)
            np.testing.assert_allclose(
                np.asarray(got.data),
                np.asarray(xd * jax.lax.rsqrt(var + eps)), rtol=1e-6)

    def test_conv2d_padding_dilation_collision(self):
        # padding=1/dilation=1 and padding=2/dilation=2 give the SAME
        # output shape for a 3x3 kernel: only the statics tell them apart
        xd, wd = _randn(1, 2, 8, 8, seed=76), _randn(2, 2, 3, 3, seed=77)

        def ref(pad, dil):
            return jax.lax.conv_general_dilated(
                xd, wd, (1, 1), ((pad, pad), (pad, pad)),
                rhs_dilation=(dil, dil),
                dimension_numbers=("NCHW", "OIHW", "NCHW"))

        a = F.conv2d(repro.Tensor(xd), repro.Tensor(wd), padding=1)
        b = F.conv2d(repro.Tensor(xd), repro.Tensor(wd), padding=2,
                     dilation=2)
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a.data),
                                   np.asarray(ref(1, 1)), rtol=2e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(b.data),
                                   np.asarray(ref(2, 2)), rtol=2e-5,
                                   atol=1e-6)

    def test_cross_entropy_kwarg_collisions(self):
        lg = _randn(5, 7, seed=78)
        tgt = jnp.asarray([1, 2, 3, 4, 5])
        mean = F.cross_entropy(repro.Tensor(lg), repro.Tensor(tgt))
        summed = F.cross_entropy(repro.Tensor(lg), repro.Tensor(tgt),
                                 reduction="sum")
        np.testing.assert_allclose(float(summed.item()),
                                   float(mean.item()) * 5, rtol=1e-5)
        smooth = F.cross_entropy(repro.Tensor(lg), repro.Tensor(tgt),
                                 label_smoothing=0.2)
        assert not np.isclose(float(smooth.item()), float(mean.item()))
        ignored = F.cross_entropy(repro.Tensor(lg),
                                  repro.Tensor(jnp.asarray([1, 2, 3, 4, 1])),
                                  ignore_index=1)
        assert not np.isclose(float(ignored.item()), float(mean.item()))

    def test_dropout_p_collision(self):
        xd = jnp.ones((64, 64), jnp.float32)
        key = jax.random.key(11)
        for p in (0.25, 0.5):
            got = np.asarray(F.dropout(repro.Tensor(xd), p=p,
                                       rng=key).data)
            mask = np.asarray(jax.random.bernoulli(key, 1.0 - p,
                                                   (64, 64)))
            np.testing.assert_allclose(
                got, mask.astype(np.float32) / (1.0 - p), rtol=1e-6)

    def test_normalize_dim_collision(self):
        xd = _randn(4, 6, seed=79)
        for dim in (0, -1):
            got = F.normalize(repro.Tensor(xd), dim=dim)
            n = jnp.linalg.norm(xd, ord=2.0, axis=dim, keepdims=True)
            np.testing.assert_allclose(
                np.asarray(got.data),
                np.asarray(xd / jnp.maximum(n, 1e-12)), rtol=1e-5,
                atol=1e-7)

    def test_pad_value_collision(self):
        xd = _randn(3, 3, seed=80)
        for val in (0.0, -7.0):
            got = F.pad(repro.Tensor(xd), (1, 1), value=val)
            np.testing.assert_allclose(
                np.asarray(got.data),
                np.asarray(jnp.pad(xd, ((0, 0), (1, 1)),
                                   constant_values=val)), rtol=1e-6)

    def test_missing_static_is_caught_by_this_harness(self):
        """Negative control: dispatch the same op name with an emptied
        static tuple and *different* closures — the second call replays
        the first closure's entry, i.e. the exact silent-wrong-result
        failure mode the conformance + collision suites exist to trip."""
        from repro.core.tensor import _apply_op
        xd = _randn(4, 4, seed=81)

        def buggy_softmax(dim):
            # simulates a call site that forgot `dim` in its statics
            return _apply_op("buggy_softmax",
                             lambda v: jax.nn.softmax(v, axis=dim),
                             repro.Tensor(xd), static=())

        a = np.asarray(buggy_softmax(0).data)
        b = np.asarray(buggy_softmax(-1).data)
        # stale replay: b silently equals a instead of axis=-1's result
        np.testing.assert_allclose(a, b, rtol=1e-6)
        assert not np.allclose(
            b, np.asarray(jax.nn.softmax(xd, axis=-1)), rtol=1e-3)
