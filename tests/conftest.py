import os
import random
import sys
import types

# smoke tests and benches must see the single real CPU device — the
# 512-device flag belongs ONLY to the dry-run entry point.  Exception:
# the multi-device CI job (sharded serving) opts in explicitly with
# REPRO_ALLOW_MULTIDEVICE=1 + a SMALL forced device count.
assert os.environ.get("REPRO_ALLOW_MULTIDEVICE") == "1" or \
    "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally " \
    "(REPRO_ALLOW_MULTIDEVICE=1 overrides for the multi-device CI job)"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # heavyweights (chaos / conformance / gradcheck matrices) opt out of
    # the tier-1 fast gate with @pytest.mark.slow; `make test-fast`
    # deselects them, the full-matrix CI job still runs everything
    config.addinivalue_line(
        "markers", "slow: heavyweight matrix tests excluded from the "
        "tier-1 fast gate (run via `make test` / the full CI job)")


def _install_hypothesis_stub():
    """Deterministic mini-``hypothesis`` for containers without the real
    package: samples a fixed number of pseudo-random examples per test.

    Supports exactly the surface the suite uses: ``given(**kwargs)``,
    ``settings``, ``strategies.integers/lists/sampled_from``.
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.draw(rng)
            for _ in range(rng.randint(min_size, max_size))
        ])

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: __wrapped__ would expose the inner
            # signature and make pytest hunt for fixtures named after
            # the strategy kwargs
            def wrapper(*args, **kwargs):
                # @settings may sit above or below @given
                n = getattr(wrapper, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 20))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._stub_max_examples = getattr(
                fn, "_stub_max_examples", 20)
            return wrapper
        return deco

    def settings(max_examples=20, **_kwargs):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat_mod = types.ModuleType("hypothesis.strategies")
    strat_mod.integers = integers
    strat_mod.lists = lists
    strat_mod.sampled_from = sampled_from
    mod.strategies = strat_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat_mod


_install_hypothesis_stub()
