import os
import sys

# smoke tests and benches must see the single real CPU device — the
# 512-device flag belongs ONLY to the dry-run entry point.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
