"""Paged KV cache + scheduler/executor continuous-batching engine."""

import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import (LMConfig, decode_step, forward, init_cache,
                             init_params)
from repro.serving.engine import ServingEngine
from repro.serving.errors import (AdmissionRejected, BucketOverflow,
                                  DeadlineExceeded, PoolExhausted,
                                  RequestFailed)
from repro.serving import quant
from repro.serving.kv_cache import PagedKVCache, PagePool
from repro.serving.legacy import LegacyServingEngine
from repro.serving.scheduler import RequestState, pow2_bucket

from clockutil import FakeClock


def tiny_cfg():
    return LMConfig(name="serve-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab_size=97,
                    param_dtype=jnp.float32, remat="none",
                    attn_backend="ref")


@functools.lru_cache(maxsize=None)
def _jitted_decode_step(cfg):
    """One jitted ``decode_step`` per config.  Eager ``decode_step``
    rebuilds its layer-scan closure every call, so EVERY call is a
    fresh XLA trace+compile — thousands over the suite, enough
    accumulated compiler state to segfault the CPU backend late in a
    long session.  Jitting (with the cache padded to one bucket below)
    collapses that to one executable per (config, cache shape)."""
    return jax.jit(functools.partial(decode_step, cfg))


def dense_rollout(cfg, params, prompt, n_new):
    """Greedy continuation via the dense-cache ``decode_step`` — the
    oracle every engine path must reproduce token-for-token.

    The cache is padded to a pow2 bucket (attention masks the unwritten
    tail) so every rollout in the suite hits the same jitted
    executable instead of compiling per distinct length."""
    step = _jitted_decode_step(cfg)
    cap = max(64, 1 << (len(prompt) + n_new + 1).bit_length())
    cache = init_cache(cfg, 1, cap, jnp.float32)
    lg = None
    for t, tok in enumerate(prompt):
        lg, cache = step(params, cache, jnp.asarray([[tok]]), jnp.int32(t))
    seq = []
    cur = int(jnp.argmax(lg[0, -1]))
    pos = len(prompt)
    for _ in range(n_new):
        seq.append(cur)
        lg, cache = step(params, cache, jnp.asarray([[cur]]), jnp.int32(pos))
        cur = int(jnp.argmax(lg[0, -1]))
        pos += 1
    return seq


class TestPagePool:
    def test_refcount_release(self):
        pool = PagePool(4)
        p = pool.alloc()
        pool.retain(p)
        pool.release(p)
        assert p not in pool.free
        pool.release(p)
        assert p in pool.free

    def test_oom_returns_none(self):
        pool = PagePool(1)
        assert pool.alloc() is not None
        assert pool.alloc() is None
        assert pool.stats.oom_rejections == 1


class TestPagedKVCache:
    def make(self, num_pages=16, page_size=4):
        return PagedKVCache(n_layers=2, n_kv_heads=2, head_dim=8,
                            page_size=page_size, num_pages=num_pages,
                            dtype=jnp.float32)

    def test_create_and_free_releases_pages(self):
        kv = self.make()
        assert kv.create(0, list(range(10)))
        used = kv.pool.num_pages - kv.pool.num_free
        assert used == 3  # ceil(10/4)
        kv.free_seq(0)
        assert kv.pool.num_free == kv.pool.num_pages

    def test_prefix_sharing_and_cow(self):
        kv = self.make()
        prompt = list(range(8))          # 2 full pages
        kv.create(0, prompt)
        kv.create(1, prompt)             # shares both pages
        assert kv.pool.stats.prefix_hits == 2
        used = kv.pool.num_pages - kv.pool.num_free
        assert used == 2                 # shared!
        # writing through seq 1 triggers copy-on-write
        k_t = jnp.ones((2, 8))
        kv.lengths[1] = 7                # overwrite last slot of page 2
        kv.append(1, [(k_t, k_t), (k_t, k_t)])
        assert kv.pool.stats.cow_copies == 1
        # seq 0's data unchanged
        page0 = kv.tables[0][1]
        page1 = kv.tables[1][1]
        assert page0 != page1

    def test_admission_control(self):
        kv = self.make(num_pages=2)
        assert kv.can_admit(8)
        assert not kv.can_admit(9)
        assert kv.create(0, list(range(8)))
        assert not kv.create(1, list(range(90, 94)))  # no pages left

    def test_gather_roundtrip(self):
        kv = self.make()
        kv.create(0, [1, 2, 3, 4, 5])
        kv.lengths[0] = 0
        writes = []
        for t in range(5):
            k_t = jnp.full((2, 8), float(t + 1))
            writes.append(k_t)
            kv.append(0, [(k_t, k_t * 2), (k_t, k_t * 2)])
        k, v, lens = kv.gather([0], layer=0)
        assert int(lens[0]) == 5
        for t in range(5):
            np.testing.assert_allclose(np.asarray(k[0, :, t]),
                                       np.asarray(writes[t]))
            np.testing.assert_allclose(np.asarray(v[0, :, t]),
                                       np.asarray(writes[t]) * 2)


class TestEngine:
    def test_batched_greedy_matches_dense_rollout(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4)
        prompts = [[5, 6, 7, 8, 9, 10, 11, 12, 20 + i] for i in range(3)]
        for pr in prompts:
            eng.submit(pr, max_new_tokens=4)
        done = {r.req_id: r for r in eng.run()}
        assert len(done) == 3

        for rid, pr in enumerate(prompts):
            seq = dense_rollout(cfg, params, pr, 4)
            assert done[rid].out_tokens == seq, (rid, done[rid].out_tokens,
                                                 seq)

    def test_prefix_sharing_across_requests(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=8)
        shared = [5, 6, 7, 8, 9, 10, 11, 12]
        for i in range(5):
            eng.submit(shared + [30 + i], max_new_tokens=2)
        eng.run()
        assert eng.stats()["prefix_hit_rate"] > 0.3

    def test_pages_released_after_completion(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=32,
                            max_batch=2)
        for i in range(4):
            eng.submit([1 + i, 2, 3, 4, 5], max_new_tokens=3)
        eng.run()
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_admission_backpressure(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        # only enough pages for ~1 sequence at a time
        eng = ServingEngine(cfg, params, page_size=4, num_pages=4,
                            max_batch=4)
        for i in range(3):
            eng.submit([1, 2, 3, 4, 5, 6 + i], max_new_tokens=2)
        done = eng.run()
        assert len(done) == 3            # all eventually served
        assert eng.metrics["rejected_admissions"] > 0

    def test_hybrid_arch_rejected(self):
        from repro.models.lm import BlockSpec
        cfg = LMConfig(name="x", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab_size=31,
                       pattern=(BlockSpec("mamba", "dense"),),
                       param_dtype=jnp.float32, remat="none")
        with pytest.raises(ValueError, match="paged engine"):
            ServingEngine(cfg, {}, num_pages=4)


class TestChunkedPrefill:
    def test_long_prompt_does_not_block_decode(self):
        """A long prompt prefills in chunks while short requests keep
        decoding every step (no head-of-line blocking) — and everyone
        still matches the dense oracle."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=96,
                            max_batch=4, chunk_size=8, token_budget=16)
        long_prompt = [(3 + 7 * i) % 97 for i in range(40)]
        shorts = [[50 + i, 2, 3, 4, 5] for i in range(3)]
        rid_long = eng.submit(long_prompt, max_new_tokens=4)
        rid_short = [eng.submit(p, max_new_tokens=6) for p in shorts]
        done = {r.req_id: r for r in eng.run()}
        assert len(done) == 4
        m = eng.metrics
        assert m["prefill_chunks"] >= 5       # 40 tokens / 8-token chunks
        assert m["zero_decode_steps"] == 0
        # the shorts (submitted AFTER the long prompt) must not wait for
        # its full prefill before their first token
        for rid in rid_short:
            assert done[rid].first_token_at < done[rid_long].first_token_at
        assert done[rid_long].out_tokens == dense_rollout(
            cfg, params, long_prompt, 4)
        for rid, p in zip(rid_short, shorts):
            assert done[rid].out_tokens == dense_rollout(cfg, params, p, 6)

    def test_fifo_admission_order(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        # one slot: strict FIFO service order
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=1)
        rids = [eng.submit([10 + i, 3, 4], max_new_tokens=2)
                for i in range(4)]
        done = eng.run()
        assert [r.req_id for r in done] == rids

    def test_prefill_budget_is_fifo_not_slot_order(self):
        """A newly admitted request landing in a freed LOW slot must not
        steal the whole prefill budget from an older request still
        prefilling in a higher slot."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=96,
                            max_batch=2, chunk_size=8, token_budget=8)
        long_a = [(3 + 7 * j) % 97 for j in range(40)]
        long_b = [(5 + 11 * j) % 97 for j in range(40)]
        rid_short = eng.submit([9, 8, 7], max_new_tokens=2)  # slot 0
        rid_a = eng.submit(long_a, max_new_tokens=2)         # slot 1
        rid_b = eng.submit(long_b, max_new_tokens=2)         # waits,
        # then refills slot 0 mid-prefill of rid_a
        done = eng.run()
        assert [r.req_id for r in done] == [rid_short, rid_a, rid_b]

    def test_bucketed_compiles_bounded(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=96,
                            max_batch=4, chunk_size=8, token_budget=16,
                            max_pages_per_seq=16)
        prompts = [[(i * 11 + j) % 97 for j in range(3 + 5 * i)]
                   for i in range(6)]
        for p in prompts:
            eng.submit(p, max_new_tokens=3)
        done = eng.run()
        assert len(done) == 6
        assert 1 <= eng.metrics["bucket_compiles"] <= eng.bucket_count


class TestPreemptionResume:
    def test_preempted_request_resumes_without_data_loss(self):
        """Regression for the preemption-data-loss bug: a requeued
        request must re-prefill prompt + out_tokens and must NOT emit a
        duplicate first token on resume."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        prompts = [[(5 + 13 * i + j) % 97 for j in range(8)]
                   for i in range(2)]
        # 16-token final histories x2 = 8 pages needed, pool of 6 forces
        # a mid-decode preemption
        eng = ServingEngine(cfg, params, page_size=4, num_pages=6,
                            max_batch=2)
        rids = [eng.submit(p, max_new_tokens=8) for p in prompts]
        done = {r.req_id: r for r in eng.run()}
        assert len(done) == 2
        assert eng.metrics["preemptions"] > 0
        for rid, p in zip(rids, prompts):
            assert done[rid].out_tokens == dense_rollout(cfg, params, p, 8)

    def test_legacy_engine_resume_keeps_tokens(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        prompts = [[(5 + 13 * i + j) % 97 for j in range(8)]
                   for i in range(2)]
        eng = LegacyServingEngine(cfg, params, page_size=4, num_pages=6,
                                  max_batch=2)
        rids = [eng.submit(p, max_new_tokens=8) for p in prompts]
        done = {r.req_id: r for r in eng.run()}
        assert len(done) == 2
        for rid, p in zip(rids, prompts):
            assert done[rid].out_tokens == dense_rollout(cfg, params, p, 8)


class TestPrefixSharingDivergence:
    def test_shared_prefix_divergence_keeps_outputs_independent(self):
        """Requests sharing dedup'd prompt pages must produce exactly
        the tokens they'd produce alone — divergent decode writes land in
        private pages (or COW copies), never in a sibling's."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        shared = [5, 6, 7, 8, 9, 10, 11, 12]    # 2 full pages at ps=4
        prompts = [shared + [30 + i] for i in range(3)]
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4)
        rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
        done = {r.req_id: r for r in eng.run()}
        assert eng.kv.pool.stats.prefix_hits > 0
        for rid, p in zip(rids, prompts):
            assert done[rid].out_tokens == dense_rollout(cfg, params, p, 5)

    def test_page_aligned_full_reuse_recomputes_last_token(self):
        """A page-aligned fully-reused prompt still yields a first token:
        the last prompt token is recomputed for logits with its write
        skipped (the shared page is not COW-split)."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        prompt = [5, 6, 7, 8, 9, 10, 11, 12]
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=2)
        eng.submit(prompt, max_new_tokens=4)
        done1 = eng.run()
        # second identical request: full-page prefix hit on VALID pages
        eng.submit(prompt, max_new_tokens=4)
        done2 = eng.run()
        oracle = dense_rollout(cfg, params, prompt, 4)
        assert done1[0].out_tokens == oracle
        assert done2[0].out_tokens == oracle
        assert eng.kv.pool.stats.cow_copies == 0

    def test_stale_prefix_index_entry_never_hits(self):
        """Generation stamps: a freed page reallocated with different
        content must not serve a prefix hit for its old hash."""
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=8,
                          page_size=4, num_pages=4, dtype=jnp.float32)
        assert kv.create(0, list(range(8)))
        kv.advance(0, 8)
        kv.free_seq(0)
        # reallocate the same physical pages for different tokens
        assert kv.create(1, list(range(50, 58)))
        kv.advance(1, 8)
        hits_before = kv.pool.stats.prefix_hits
        assert kv.create(2, list(range(8)))      # old hash, stale pages
        assert kv.pool.stats.prefix_hits == hits_before
        assert set(kv.tables[2]).isdisjoint(set(kv.tables[1]))


class TestRefcountConservation:
    def test_randomized_workload_conserves_pages(self):
        """allocated == freed + held at every point of a randomized
        submit/run/finish trace, and the pool drains to empty."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=24,
                            max_batch=3, chunk_size=4, token_budget=8)
        rng = random.Random(1234)
        submitted = 0
        finished = []
        for step in range(200):
            if submitted < 12 and rng.random() < 0.4:
                n = rng.randint(1, 14)
                base = rng.choice([0, 40])       # some shared prefixes
                eng.submit([(base + j) % 97 for j in range(n)],
                           max_new_tokens=rng.randint(1, 5))
                submitted += 1
            finished.extend(eng.step())
            st = eng.kv.pool.stats
            held = len(eng.kv.pool.refs)
            assert st.allocated_pages == st.freed_pages + held
            assert held + eng.kv.pool.num_free == eng.kv.pool.num_pages
            if submitted >= 12 and not eng.waiting and not eng.running:
                break
        finished.extend(eng.run())
        assert len(finished) == 12
        st = eng.kv.pool.stats
        assert st.allocated_pages == st.freed_pages
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_randomized_workload_with_cancels_conserves_pages(self):
        """Same property trace with interleaved ``cancel()`` calls at
        arbitrary lifecycle points (queued, mid-prefill-chunk, mid-
        decode, COW/prefix sharers): conservation holds every step,
        every request reaches a terminal state, the pool drains."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=24,
                            max_batch=3, chunk_size=4, token_budget=8)
        rng = random.Random(4321)
        ids = []
        for step in range(300):
            if len(ids) < 14 and rng.random() < 0.4:
                n = rng.randint(1, 14)
                base = rng.choice([0, 40])       # some shared prefixes
                ids.append(eng.submit([(base + j) % 97 for j in range(n)],
                                      max_new_tokens=rng.randint(1, 5)))
            if ids and rng.random() < 0.15:
                eng.cancel(rng.choice(ids))      # may be terminal: False
            eng.step()
            st = eng.kv.pool.stats
            held = len(eng.kv.pool.refs)
            assert st.allocated_pages == st.freed_pages + held
            assert held + eng.kv.pool.num_free == eng.kv.pool.num_pages
            if len(ids) >= 14 and not eng.waiting and not eng.running:
                break
        eng.run()
        assert len(eng.scheduler.done) == 14     # all terminal
        assert eng.metrics["cancellations"] > 0
        st = eng.kv.pool.stats
        assert st.allocated_pages == st.freed_pages
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages


class TestQuantizedPoolChurn:
    """Quantized (int8/fp8_e4m3) page pools: the per-token scale arrays
    must stay shape- AND index-aligned with their code pools through
    every page-lifecycle event — COW, truncate, scrub, recover — and a
    randomized engine churn must conserve pages while the finished
    outputs track the fp32 dense oracle within the tier bound."""

    def _assert_aligned(self, kv):
        """Scales are parallel (N, ps, Hkv) fp32 arrays beside the
        (N, ps, Hkv, hd) code pools — one scale per stored vector."""
        for l in range(kv.n_layers):
            assert kv.k[l].shape[:-1] == kv.k_scale[l].shape
            assert kv.v[l].shape[:-1] == kv.v_scale[l].shape
            assert kv.k_scale[l].dtype == jnp.float32

    @pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
    def test_scales_track_cow_truncate_recover(self, kv_dtype):
        """Content round-trips: gather() over a quantized pool must
        return dequantize(quantize(x)) for exactly the vectors written,
        across write_batch, prefix-shared pages, COW, truncate + refill,
        and a recover() pass."""
        n_layers, hkv, hd, ps = 2, 2, 8, 4
        kv = PagedKVCache(n_layers=n_layers, n_kv_heads=hkv, head_dim=hd,
                          page_size=ps, num_pages=16, kv_dtype=kv_dtype)
        self._assert_aligned(kv)
        toks = list(range(1, 9))                       # 2 full pages
        key = jax.random.key(11)
        xs = [jax.random.normal(jax.random.fold_in(key, i), (8, hkv, hd))
              for i in range(2 * n_layers)]

        def expect(x):                                 # the storage oracle
            return np.asarray(quant.dequantize(*quant.quantize(
                x, kv_dtype)))

        assert kv.create(0, toks)
        assert kv.write_batch(0, [(xs[2 * l], xs[2 * l + 1])
                                  for l in range(n_layers)], 0, 8)
        kv.lengths[0] = 8
        self._assert_aligned(kv)
        for l in range(n_layers):
            k, v, _ = kv.gather([0], l)
            np.testing.assert_allclose(np.asarray(k[0]),
                                       expect(xs[2 * l]).transpose(1, 0, 2),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(np.asarray(v[0]),
                                       expect(xs[2 * l + 1]).transpose(1, 0, 2),
                                       rtol=1e-6, atol=1e-6)

        # prefix sharing then COW through the sharer: seq 0's view of
        # the shared page must be byte-stable (scales copied with codes)
        assert kv.create(1, toks)
        assert kv.pool.stats.prefix_hits == 2
        div = jax.random.normal(jax.random.fold_in(key, 99), (hkv, hd))
        kv.lengths[1] = 7                # overwrite last slot of page 2
        assert kv.append(1, [(div, div)] * n_layers)
        assert kv.pool.stats.cow_copies == 1
        self._assert_aligned(kv)
        k0, _, _ = kv.gather([0], 0)
        np.testing.assert_allclose(np.asarray(k0[0]),
                                   expect(xs[0]).transpose(1, 0, 2),
                                   rtol=1e-6, atol=1e-6)
        k1, _, _ = kv.gather([1], 0)
        np.testing.assert_allclose(np.asarray(k1[0, :, :7]),
                                   expect(xs[0]).transpose(1, 0, 2)[:, :7],
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(k1[0, :, 7]), expect(div),
                                   rtol=1e-6, atol=1e-6)

        # truncate + refill: the freed tail page's scales must not leak
        # into the fresh content written over it
        assert kv.truncate(0, 4)
        fresh = jax.random.normal(jax.random.fold_in(key, 123),
                                  (4, hkv, hd))
        assert kv.write_batch(0, [(fresh, fresh)] * n_layers, 4, 8)
        kv.lengths[0] = 8
        k0, _, _ = kv.gather([0], 0)
        np.testing.assert_allclose(np.asarray(k0[0, :, 4:]),
                                   expect(fresh).transpose(1, 0, 2),
                                   rtol=1e-6, atol=1e-6)

        # recover() reconciles an injected refcount leak and must keep
        # both live sequences' dequantized content intact
        page = kv.pool.free.pop()
        kv.pool.refs[page] = 1
        assert kv.recover() >= 1
        self._assert_aligned(kv)
        k1, _, _ = kv.gather([1], 0)
        np.testing.assert_allclose(np.asarray(k1[0, :, 7]), expect(div),
                                   rtol=1e-6, atol=1e-6)
        kv.free_seq(0)
        kv.free_seq(1)
        assert kv.pool.num_free == kv.pool.num_pages

    @pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
    def test_randomized_churn_conserves_and_tracks_oracle(self, kv_dtype):
        """Randomized submit/cancel/recover churn over a quantized
        engine: page conservation and scale alignment hold at every
        step; finished greedy outputs agree with the fp32 dense-cache
        oracle at or above the tier's token-agreement floor."""
        floors = {"int8": 0.75, "fp8_e4m3": 0.35}
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=24,
                            max_batch=3, chunk_size=4, token_budget=8,
                            kv_dtype=kv_dtype)
        rng = random.Random(9 if kv_dtype == "int8" else 10)
        prompts, ids, cancelled = {}, [], set()
        finished = []
        for step in range(300):
            if len(ids) < 10 and rng.random() < 0.4:
                n = rng.randint(1, 14)
                base = rng.choice([0, 40])       # some shared prefixes
                p = [(base + j) % 97 for j in range(n)]
                rid = eng.submit(p, max_new_tokens=rng.randint(2, 5))
                prompts[rid] = p
                ids.append(rid)
            if ids and rng.random() < 0.08:
                victim = rng.choice(ids)
                if eng.cancel(victim):
                    cancelled.add(victim)
            if rng.random() < 0.05:
                eng.kv.recover()                 # repair pass mid-churn
            finished.extend(eng.step())
            st = eng.kv.pool.stats
            held = len(eng.kv.pool.refs)
            assert st.allocated_pages == st.freed_pages + held
            assert held + eng.kv.pool.num_free == eng.kv.pool.num_pages
            self._assert_aligned(eng.kv)
            if len(ids) >= 10 and not eng.waiting and not eng.running:
                break
        finished.extend(eng.run())
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages
        assert eng.metrics["kv_dtype"] == kv_dtype
        done = [r for r in finished if r.req_id not in cancelled]
        assert len(done) >= 6
        agree = total = 0
        for r in done:
            oracle = dense_rollout(cfg, params, prompts[r.req_id],
                                   len(r.out_tokens))
            agree += sum(a == b for a, b in zip(r.out_tokens, oracle))
            total += len(oracle)
        assert total > 0
        assert agree / total >= floors[kv_dtype], \
            f"{kv_dtype} agreement {agree}/{total} below floor"


class TestCancellation:
    def make(self, **kw):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        kw.setdefault("page_size", 4)
        kw.setdefault("num_pages", 64)
        kw.setdefault("max_batch", 4)
        return cfg, params, ServingEngine(cfg, params, **kw)

    def test_cancel_queued_request(self):
        _, _, eng = self.make()
        rid = eng.submit([1, 2, 3], max_new_tokens=4)
        assert eng.cancel(rid)
        assert eng.run() == []
        r = eng.result(rid)
        assert r.state is RequestState.CANCELLED
        assert r.out_tokens == []
        assert eng.metrics["cancellations"] == 1

    def test_cancel_unknown_or_terminal_returns_false(self):
        _, _, eng = self.make()
        rid = eng.submit([1, 2, 3], max_new_tokens=2)
        assert not eng.cancel(rid + 99)
        eng.run()
        assert not eng.cancel(rid)           # already FINISHED
        assert eng.metrics["cancellations"] == 0

    def test_cancel_mid_decode_frees_pages_keeps_sibling_exact(self):
        cfg, params, eng = self.make(max_batch=2)
        prompts = [[(5 + 13 * i + j) % 97 for j in range(8)]
                   for i in range(2)]
        rids = [eng.submit(p, max_new_tokens=8) for p in prompts]
        for _ in range(4):
            eng.step()
        victim = eng.running[rids[0]]
        assert victim.state is RequestState.DECODE
        held_before = len(eng.kv.pool.refs)
        assert eng.cancel(rids[0])
        assert len(eng.kv.pool.refs) < held_before   # pages released NOW
        done = {r.req_id: r for r in eng.run()}
        assert set(done) == {rids[1]}
        assert done[rids[1]].out_tokens == dense_rollout(
            cfg, params, prompts[1], 8)
        partial = eng.result(rids[0])
        assert partial.state is RequestState.CANCELLED
        assert 0 < len(partial.out_tokens) < 8       # partials preserved
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_cancel_during_prefill_chunk(self):
        """Cancel a long request while it is mid-chunked-prefill: its
        pages release immediately and the other requests still match
        the dense oracle."""
        cfg, params, eng = self.make(chunk_size=8, token_budget=16,
                                     num_pages=96)
        long_prompt = [(3 + 7 * i) % 97 for i in range(40)]
        shorts = [[50 + i, 2, 3, 4, 5] for i in range(2)]
        rid_long = eng.submit(long_prompt, max_new_tokens=4)
        rids = [eng.submit(p, max_new_tokens=4) for p in shorts]
        eng.step()
        req = eng.running[rid_long]
        assert req.state is RequestState.PREFILL
        assert 0 < req.computed < len(long_prompt)   # mid-chunk
        held_before = len(eng.kv.pool.refs)
        assert eng.cancel(rid_long)
        assert len(eng.kv.pool.refs) < held_before
        done = {r.req_id: r for r in eng.run()}
        assert set(done) == set(rids)
        for rid, p in zip(rids, shorts):
            assert done[rid].out_tokens == dense_rollout(cfg, params, p, 4)
        assert eng.result(rid_long).state is RequestState.CANCELLED
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_cancel_prefix_sharer_drops_one_ref_only(self):
        """Cancelling one of several prefix-sharing requests releases
        exactly its reference on the shared pages; siblings keep theirs
        and still produce oracle-exact tokens."""
        cfg, params, eng = self.make()
        shared = [5, 6, 7, 8, 9, 10, 11, 12]     # 2 full pages at ps=4
        prompts = [shared + [30 + i] for i in range(3)]
        rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
        for _ in range(2):
            eng.step()
        assert eng.kv.pool.stats.prefix_hits > 0
        shared_page = eng.kv.tables[rids[1]][0]
        assert eng.kv.pool.refs[shared_page] == 3
        assert eng.cancel(rids[0])
        assert eng.kv.pool.refs[shared_page] == 2    # sharers keep theirs
        done = {r.req_id: r for r in eng.run()}
        assert set(done) == {rids[1], rids[2]}
        for rid, p in zip(rids[1:], prompts[1:]):
            assert done[rid].out_tokens == dense_rollout(cfg, params, p, 5)
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_cancel_cow_sharer_conserves_pages(self):
        """KV-level: free one sharer after a copy-on-write split — the
        sibling keeps its pages and the pool conserves."""
        kv = PagedKVCache(n_layers=2, n_kv_heads=2, head_dim=8,
                          page_size=4, num_pages=16, dtype=jnp.float32)
        assert kv.create(0, list(range(8)))
        kv.advance(0, 8)
        assert kv.create(1, list(range(8)))          # shares both pages
        # divergent write through seq 1's shared page forces COW
        kv.lengths[1] = 7
        k_t = jnp.ones((2, 8))
        kv.append(1, [(k_t, k_t), (k_t, k_t)])
        assert kv.pool.stats.cow_copies == 1
        kv.free_seq(1)                               # "cancel" the sharer
        st = kv.pool.stats
        assert st.allocated_pages == st.freed_pages + len(kv.pool.refs)
        assert all(p in kv.pool.refs for p in kv.tables[0])
        kv.free_seq(0)
        assert kv.pool.num_free == kv.pool.num_pages


class TestDeadlines:
    def make(self, **kw):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        clk = FakeClock()
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            clock=clk, **kw)
        return clk, eng

    def test_timeout_ms_expires_mid_flight(self):
        clk, eng = self.make(max_batch=2)
        rid = eng.submit([1, 2, 3, 4, 5], max_new_tokens=50,
                         timeout_ms=100)
        eng.step()
        eng.step()
        clk.advance(0.2)                 # past the 100 ms budget
        eng.step()                       # plan() expires it
        with pytest.raises(DeadlineExceeded):
            eng.result(rid)
        req = eng.scheduler.done[rid]
        assert req.state is RequestState.TIMED_OUT
        assert len(req.out_tokens) >= 1              # partials preserved
        assert eng.metrics["timeouts"] == 1
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_ttft_deadline_while_queued(self):
        clk, eng = self.make(max_batch=1)
        rid_hog = eng.submit([1, 2, 3, 4], max_new_tokens=30)
        eng.step()                       # hog takes the only slot...
        rid = eng.submit([9, 8, 7], max_new_tokens=4,
                         ttft_deadline_ms=50)
        # ...so EDF admission can't help the late arrival
        eng.step()
        eng.step()                       # hog holds the only slot
        clk.advance(0.1)
        eng.step()
        with pytest.raises(DeadlineExceeded):
            eng.result(rid)
        assert eng.scheduler.done[rid].state is RequestState.TIMED_OUT
        assert rid_hog in eng.running    # hog unaffected
        done = eng.run()
        assert [r.req_id for r in done] == [rid_hog]

    def test_generous_deadlines_are_inert(self):
        clk, eng = self.make(max_batch=2)
        rid = eng.submit([1, 2, 3, 4, 5], max_new_tokens=3,
                         ttft_deadline_ms=1e6, timeout_ms=1e6)
        done = eng.run()
        assert [r.req_id for r in done] == [rid]
        assert eng.metrics["timeouts"] == 0


class TestTypedAdmissionErrors:
    def make(self, **kw):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        kw.setdefault("page_size", 4)
        kw.setdefault("num_pages", 64)
        kw.setdefault("max_batch", 2)
        return ServingEngine(cfg, params, **kw)

    def test_over_cap_prompt_raises_typed(self):
        eng = self.make(max_pages_per_seq=4)
        with pytest.raises(AdmissionRejected) as ei:
            eng.submit(list(range(1, 30)), max_new_tokens=4)
        assert isinstance(ei.value, ValueError)      # back-compat
        assert eng.metrics["rejected_submits"] == 1

    def test_queue_depth_bound(self):
        eng = self.make(max_queue_depth=2)
        eng.submit([1, 2, 3], max_new_tokens=2)
        eng.submit([4, 5, 6], max_new_tokens=2)
        with pytest.raises(AdmissionRejected):
            eng.submit([7, 8, 9], max_new_tokens=2)
        assert len(eng.run()) == 2       # accepted ones still serve

    def test_page_watermark_backpressure(self):
        eng = self.make(num_pages=8, admit_hwm_frac=0.5)
        assert eng.kv.create(999, list(range(16)))   # 4/8 pages live
        with pytest.raises(PoolExhausted) as ei:
            eng.submit([1, 2, 3], max_new_tokens=2)
        assert isinstance(ei.value, AdmissionRejected)
        eng.kv.free_seq(999)
        rid = eng.submit([1, 2, 3], max_new_tokens=2)
        assert [r.req_id for r in eng.run()] == [rid]

    def test_pow2_bucket_overflow_typed(self):
        with pytest.raises(BucketOverflow) as ei:
            pow2_bucket(33, 8, 32)
        assert isinstance(ei.value, ValueError)


class TestStepCapExhaustion:
    def test_step_cap_times_out_remaining_and_recovers(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=2)
        rids = [eng.submit([1 + i, 2, 3, 4, 5, 6, 7, 8],
                           max_new_tokens=32) for i in range(2)]
        done = eng.run(max_steps=3)
        assert done == []
        assert eng.metrics["steps_exhausted"] == 1
        assert eng.metrics["timeouts"] == 2
        for rid in rids:
            with pytest.raises(DeadlineExceeded):
                eng.result(rid)
            assert len(eng.scheduler.done[rid].out_tokens) > 0
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages
        # the engine keeps serving after the drain
        rid2 = eng.submit([5, 6, 7], max_new_tokens=2)
        assert [r.req_id for r in eng.run()] == [rid2]


class TestWatchdogQuarantine:
    def make(self, **kw):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        kw.setdefault("watchdog_interval", 1)
        kw.setdefault("max_batch", 2)
        return ServingEngine(cfg, params, page_size=4, num_pages=64,
                             **kw)

    def test_stalled_sequence_quarantined(self):
        eng = self.make(stall_steps=8)
        rid = eng.submit([1, 2, 3, 4, 5], max_new_tokens=4)
        eng.step()
        req = eng.running[rid]
        assert req.in_decode
        req.last_advance_step = -1000    # simulate a wedged sequence
        eng._run_watchdog()
        assert rid not in eng.running
        with pytest.raises(RequestFailed):
            eng.result(rid)
        assert eng.metrics["watchdog_trips"] >= 1
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_refcount_leak_repaired_without_victim(self):
        """An unattributable pool inconsistency is repaired by
        reconciliation; the in-flight request is NOT failed."""
        eng = self.make()
        rid = eng.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=6)
        eng.step()
        page = eng.kv.pool.free.pop()    # leak: held by nobody
        eng.kv.pool.refs[page] = 1
        eng.step()                       # interval=1: repaired here
        assert eng.metrics["watchdog_trips"] >= 1
        done = eng.run()
        assert [r.req_id for r in done] == [rid]
        st = eng.kv.pool.stats
        assert st.allocated_pages == st.freed_pages
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_dead_table_page_quarantined(self):
        """A block-table row referencing a dead page fails that one
        sequence; the other request keeps serving."""
        eng = self.make(max_batch=2)
        rids = [eng.submit([10 + i, 2, 3, 4, 5], max_new_tokens=6)
                for i in range(2)]
        eng.step()
        eng.kv.tables[rids[0]][-1] = eng.kv.pool.num_pages + 3
        eng.kv._bump(rids[0])
        done = eng.run()
        assert [r.req_id for r in done] == [rids[1]]
        with pytest.raises(RequestFailed):
            eng.result(rids[0])
        assert eng.metrics["watchdog_trips"] >= 1
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages


class TestAgingAdmission:
    def test_blocked_request_is_bypassed_then_ages_in(self):
        """Best-effort FIFO: small late arrivals bypass a page-blocked
        big request, but the big one still lands (starvation-free) and
        counts in ``aged_admissions``."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=8,
                            max_batch=2, aging_steps=3)
        rid_r = eng.submit([(j % 90) + 1 for j in range(8)],
                           max_new_tokens=8)
        # 7 pages needed > the ≤6 ever free while rid_r runs: blocked
        rid_a = eng.submit([(60 + j) % 97 for j in range(24)],
                           max_new_tokens=2)
        rid_b = eng.submit([50, 51, 52, 53], max_new_tokens=2)
        done = eng.run()
        ids = [r.req_id for r in done]
        assert set(ids) == {rid_r, rid_a, rid_b}
        assert ids.index(rid_b) < ids.index(rid_a)   # bypass happened
        assert eng.metrics["aged_admissions"] >= 1
        assert eng.metrics["rejected_admissions"] > 0


class TestMixedAttentionKernel:
    def test_matches_reference(self):
        from repro.kernels import ops as kops
        from repro.models.attention import mixed_attention
        s, hkv, l, d, hq, t = 3, 2, 32, 16, 4, 7
        kc = jax.random.normal(jax.random.key(0), (s, hkv, l, d))
        vc = jax.random.normal(jax.random.key(1), (s, hkv, l, d))
        q = jax.random.normal(jax.random.key(2), (t, hq, d))
        seg = jnp.asarray([0, 0, 1, 2, 2, 2, -1], jnp.int32)
        pos = jnp.asarray([3, 4, 0, 10, 11, 12, 0], jnp.int32)
        for window in (None, 4):
            ref = mixed_attention(q, kc, vc, seg, pos, backend="ref",
                                  window=window)
            ker = kops.mixed_attention(q, kc, vc, seg, pos,
                                       window=window)
            np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                       atol=2e-5, rtol=2e-5)


class TestPagedAttentionOverCacheState:
    def test_kernel_matches_ref_on_real_cache_state(self):
        """paged_attention kernel vs ref over a REAL PagedKVCache with
        shared-prefix (dedup'd) pages and ragged page counts."""
        from repro.kernels import ops as kops
        from repro.models.attention import paged_attention
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=16,
                          page_size=4, num_pages=32, dtype=jnp.float32)
        shared = list(range(8))
        assert kv.create(0, shared + [30])       # 3 pages
        assert kv.create(1, shared + [40, 41, 42, 43, 44])  # shares 2
        assert kv.create(2, [70, 71, 72])        # 1 page, ragged
        assert kv.pool.stats.prefix_hits == 2
        key = jax.random.key(3)
        for sid, n in ((0, 9), (1, 13), (2, 3)):
            kv.lengths[sid] = 0
            for t in range(n):
                key, k1, k2 = jax.random.split(key, 3)
                kv.append(sid, [(jax.random.normal(k1, (2, 16)),
                                 jax.random.normal(k2, (2, 16)))])
        tables = kv.device_tables([0, 1, 2, -1], 4)
        q = jax.random.normal(jax.random.key(9), (5, 4, 16))
        seg = jnp.asarray([0, 1, 1, 2, -1], jnp.int32)
        pos = jnp.asarray([8, 11, 12, 2, 0], jnp.int32)
        ref = paged_attention(q, kv.k[0], kv.v[0], tables, seg, pos,
                              backend="ref")
        ker = kops.paged_attention(q, kv.k[0], kv.v[0], tables, seg, pos)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                   atol=1e-5, rtol=1e-5)


class TestDeltaTableUploads:
    def test_steady_decode_uploads_zero_rows(self):
        """Within a page, decode steps change no block table — the
        device mirror must flush ZERO rows on those steps."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=2)
        eng.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=10)
        uploads = []
        for _ in range(50):              # bounded: ~11 steps expected
            if not (eng.scheduler.waiting or eng.scheduler.running):
                break
            rebuilds_before = eng.kv.upload_full_rebuilds
            eng.step()
            uploads.append((eng.kv.last_upload_rows,
                            eng.kv.upload_full_rebuilds - rebuilds_before))
        assert not eng.scheduler.running and not eng.scheduler.waiting
        # first step pays the one-time full mirror build (max_batch
        # rows); afterwards a single sequence dirties at most its own
        # row, except the O(log) steps where the pow2 page bucket
        # outgrows the mirror width (a counted full rebuild)
        assert uploads[0] == (2, 1)
        assert all(u <= 1 for u, rebuilt in uploads[1:] if not rebuilt)
        assert sum(r for _, r in uploads) <= 2
        # 10 decode steps cross a 4-token page boundary ~3 times: most
        # steps are pure decode and upload nothing
        zeros = [u for u, _ in uploads[1:]].count(0)
        assert zeros >= (len(uploads) - 1) // 2

    def test_mixed_workload_uploads_bounded_by_dirty_rows(self):
        """Across a 32-request mixed workload, host→device table rows
        stay O(rows actually dirtied) — NOT O(steps × slots), which is
        what whole-table re-uploads would cost."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        max_batch = 8
        eng = ServingEngine(cfg, params, page_size=4, num_pages=256,
                            max_batch=max_batch, chunk_size=8,
                            token_budget=16)
        for i in range(8):
            eng.submit([(7 + 13 * i + j) % 97 for j in range(24)],
                       max_new_tokens=4)
            for s in range(3):
                eng.submit([(91 + 5 * (3 * i + s) + j) % 97
                            for j in range(6)], max_new_tokens=4)
        done = eng.run()
        assert len(done) == 32
        kv, m = eng.kv, eng.metrics
        # every upload is accounted for by a table-version bump, a slot
        # retirement (row -> empty), or a one-time full rebuild; the
        # pow2 scatter padding costs at most 2x the dirty rows
        dirty_budget = (2 * (kv._version_counter + 32)
                        + kv.upload_full_rebuilds * max_batch)
        assert m["table_upload_rows"] <= dirty_budget
        # and decisively below the whole-table re-upload regime
        assert m["table_upload_rows"] < m["steps"] * max_batch / 2
        assert m["table_full_rebuilds"] <= 4    # pow2 width growth only

    def test_freed_and_readmitted_seq_id_never_serves_stale_row(self):
        """Version monotonicity: free seq, re-create the same id with a
        different table — the mirror row must be re-uploaded."""
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=8,
                          page_size=4, num_pages=8, dtype=jnp.float32)
        assert kv.create(0, list(range(8)))
        t1 = np.asarray(kv.device_tables([0], 2)).copy()
        old_pages = list(kv.tables[0])
        kv.free_seq(0)
        assert kv.create(7, [50, 51, 52, 53])    # takes a freed page
        assert kv.create(0, list(range(60, 68)))  # same id, new pages
        t2 = np.asarray(kv.device_tables([0], 2))
        assert kv.tables[0] != old_pages
        np.testing.assert_array_equal(t2[0], np.asarray(kv.tables[0]))
        assert not np.array_equal(t1, t2)


class TestDonationInvariant:
    def test_taken_kv_cannot_be_aliased(self):
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=8,
                          page_size=4, num_pages=4, dtype=jnp.float32)
        ks, vs = kv.take_kv()
        with pytest.raises(AssertionError):
            kv.take_kv()
        kv.put_kv(ks, vs)
        ks2, _ = kv.take_kv()
        assert ks2 is not None


class TestPagePoolProperties:
    def test_alloc_free_invariants_random_trace(self):
        """Property: under random alloc/retain/release traces the pool
        never double-frees, never leaks, and free+live == total."""
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=50, deadline=None)
        @given(ops=st.lists(st.integers(0, 2), min_size=1, max_size=200),
               n=st.integers(1, 16))
        def run(ops, n):
            pool = PagePool(n)
            live = []
            for op in ops:
                if op == 0:
                    p = pool.alloc()
                    if p is not None:
                        live.append(p)
                elif op == 1 and live:
                    pool.retain(live[len(live) // 2])
                    live.append(live[len(live) // 2])
                elif op == 2 and live:
                    pool.release(live.pop())
                held = {p for p in live}
                assert held.isdisjoint(set(pool.free))
                assert len(set(pool.free)) == len(pool.free)
                assert len(pool.free) + len(pool.refs) <= n
            for p in list(live):
                pool.release(p)
            assert len(pool.free) == n

        run()


class TestSamplingContract:
    """The ``greedy=False`` / per-request SamplingParams surface —
    sampling actually happens, is seed-reproducible, and never pays a
    per-step host logits round-trip."""

    def _run(self, eng, prompts, n=8):
        ids = [eng.submit(p, n) for p in prompts]
        eng.run()
        return [eng.result(i).out_tokens for i in ids]

    def test_seeded_temperature_run_reproducible_and_not_argmax(self):
        from repro.serving.sampling import SamplingParams
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        prompts = [[1, 2, 3, 4, 5], [9, 8, 7], [11, 12, 13, 14]]
        greedy_out = self._run(
            ServingEngine(cfg, params, page_size=4, num_pages=64,
                          max_batch=4), prompts)
        sp = SamplingParams(temperature=0.9, top_k=25, top_p=0.95,
                            seed=123)
        mk = lambda: ServingEngine(cfg, params, page_size=4,  # noqa: E731
                                   num_pages=64, max_batch=4,
                                   sampling=sp)
        out_a = self._run(mk(), prompts)
        # a REBUILT engine (fresh KV pool, fresh executor) replays the
        # same seed token-for-token
        out_b = self._run(mk(), prompts)
        assert out_a == out_b
        assert out_a != greedy_out          # greedy=False does something
        # and greedy itself is still deterministic argmax
        assert greedy_out == self._run(
            ServingEngine(cfg, params, page_size=4, num_pages=64,
                          max_batch=4, greedy=True), prompts)

    def test_greedy_false_defaults_to_temperature_sampling(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, num_pages=64, greedy=False)
        assert eng.sampling.temperature == 1.0 and not eng.greedy
        assert ServingEngine(cfg, params, num_pages=64).greedy

    def test_per_request_sampling_override(self):
        from repro.serving.sampling import SamplingParams
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4)     # engine-wide greedy
        pr = [3, 1, 4, 1, 5]
        ga = eng.submit(pr, 8)
        sa = eng.submit(pr, 8, sampling=SamplingParams(temperature=1.2,
                                                       seed=7))
        eng.run()
        g, s = eng.result(ga).out_tokens, eng.result(sa).out_tokens
        assert g == dense_rollout(cfg, params, pr, 8)
        assert s != g                        # the override sampled

    def test_no_host_logits_round_trip(self, monkeypatch):
        """The only arrays the executor materializes on host per step
        are the (S, K+1) token ids and the (S,) fault flags — nothing
        vocab-sized ever crosses the device boundary."""
        import repro.serving.executor as ex
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4, greedy=False, spec_k=2)
        for i in range(3):
            eng.submit([1 + i, 2, 3, 4, 5], 6)
        crossed = []
        real = np.asarray

        def spy(a, *args, **kw):
            out = real(a, *args, **kw)
            if isinstance(a, jax.Array):     # device -> host only
                crossed.append(out.shape)
            return out
        monkeypatch.setattr(ex.np, "asarray", spy)
        eng.run()
        assert crossed, "spy never saw a device->host conversion"
        v = cfg.vocab_size
        assert all(np.prod(s) < v for s in crossed), \
            f"vocab-sized array crossed to host: {crossed}"


class TestSpeculativeDecoding:
    def test_greedy_spec_bitwise_equals_nonspec(self):
        """THE exactness anchor: spec_k>0 with the n-gram proposer
        yields token-for-token the dense-rollout greedy output."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4, spec_k=4)
        prompts = [[5, 6, 7, 5, 6, 7, 5, 6], [1, 2, 1, 2, 1],
                   [40, 41, 42, 43]]
        ids = [eng.submit(p, 10) for p in prompts]
        eng.run()
        for rid, pr in zip(ids, prompts):
            assert eng.result(rid).out_tokens == \
                dense_rollout(cfg, params, pr, 10)
        m = eng.metrics
        assert m["proposed_tokens"] > 0
        assert 0 < m["accepted_tokens"] <= m["proposed_tokens"]
        assert m["spec_acceptance_rate"] > 0
        assert m["bucket_compiles"] <= eng.bucket_count

    def test_all_rejected_drafts_still_exact_and_conserve_pages(self):
        from repro.serving.spec import FixedProposer
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        # vocab-edge drafts the model will (almost surely) never emit
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4, spec_k=3,
                            proposer=FixedProposer([96, 95, 94]))
        prompts = [[5, 6, 7, 8], [1, 2, 3]]
        ids = [eng.submit(p, 8) for p in prompts]
        eng.run()
        for rid, pr in zip(ids, prompts):
            assert eng.result(rid).out_tokens == \
                dense_rollout(cfg, params, pr, 8)
        m = eng.metrics
        assert m["proposed_tokens"] > 0
        # a fixed junk draft can still coincide with a real sample now
        # and then — what matters is that rejections DOMINATE and the
        # rewind path ran constantly without corrupting anything
        assert m["spec_acceptance_rate"] < 0.2
        st = eng.kv.pool.stats
        assert st.allocated_pages == st.freed_pages      # pool drained
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_spec_temperature_equals_nonspec_temperature(self):
        """Position-keyed PRNG makes speculation exact at ANY
        temperature, not just greedy."""
        from repro.serving.sampling import SamplingParams
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        sp = SamplingParams(temperature=0.8, top_k=30, seed=5)
        prompts = [[5, 6, 5, 6, 5], [7, 8, 9]]
        outs = []
        for spec_k in (0, 4):
            eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                                max_batch=4, sampling=sp, spec_k=spec_k)
            ids = [eng.submit(p, 10) for p in prompts]
            eng.run()
            outs.append([eng.result(i).out_tokens for i in ids])
        assert outs[0] == outs[1]

    def test_rejection_rewind_reuploads_table_rows(self):
        """A rewound block-table row must hit the device mirror again:
        forced all-reject speculation uploads strictly more rows than
        the same workload without speculation (whose steady decode
        steps inside a page upload zero)."""
        from repro.serving.spec import FixedProposer
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))

        def uploads(spec_k, proposer):
            # page_size 4 + spec_k 3: nearly every speculative tail
            # crosses into a fresh page, so every rejection releases
            # it again (grow-bump + truncate-bump -> row re-upload)
            eng = ServingEngine(cfg, params, page_size=4, num_pages=32,
                                max_batch=1, spec_k=spec_k,
                                proposer=proposer)
            eng.submit([1, 2, 3], 10)
            eng.run()
            return eng.metrics["table_upload_rows"]

        base = uploads(0, None)
        spec = uploads(3, FixedProposer([96, 95, 94]))
        assert spec > base

    def test_randomized_spec_workload_conserves_pages(self):
        """Satellite: the refcount conservation property under
        propose/accept/REJECT interleavings (an adversarial proposer
        corrupts every other draft) with cancels mixed in — allocated
        == freed + held at every step, lengths never overstate the
        committed cursor (no stale ``filled``), pool drains."""
        from repro.serving.spec import NgramProposer

        class Adversarial:
            """Half right (n-gram continuations), half garbage —
            guarantees both accepted and rejected drafts."""

            def __init__(self):
                self.inner = NgramProposer()
                self.flip = False

            def propose(self, history, k):
                self.flip = not self.flip
                if self.flip:
                    return [96] * min(k, 2)
                return self.inner.propose(history, k)

        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=24,
                            max_batch=3, chunk_size=4, token_budget=8,
                            spec_k=3, proposer=Adversarial())
        rng = random.Random(2718)
        ids = []
        for step in range(300):
            if len(ids) < 12 and rng.random() < 0.4:
                n = rng.randint(1, 12)
                base = rng.choice([0, 40])
                ids.append(eng.submit(
                    [(base + j) % 97 for j in range(n)],
                    max_new_tokens=rng.randint(1, 6)))
            if ids and rng.random() < 0.1:
                eng.cancel(rng.choice(ids))
            eng.step()
            st = eng.kv.pool.stats
            held = len(eng.kv.pool.refs)
            assert st.allocated_pages == st.freed_pages + held
            assert held + eng.kv.pool.num_free == eng.kv.pool.num_pages
            for rid, req in eng.scheduler.running.items():
                # rewind left no stale filled counts: valid KV never
                # exceeds the committed cursor, and the table never
                # holds pages beyond the next pending token
                assert eng.kv.lengths[rid] <= req.computed
                # admission allocates the whole prompt; past that the
                # table may only run ahead by the speculative tail
                assert len(eng.kv.tables[rid]) <= eng.kv.pages_needed(
                    max(len(req.history),
                        req.computed + 1 + eng.spec_k))
            if len(ids) >= 12 and not eng.waiting and not eng.running:
                break
        eng.run()
        assert len(eng.scheduler.done) == 12
        m = eng.metrics
        assert m["proposed_tokens"] > 0
        assert 0 < m["accepted_tokens"] < m["proposed_tokens"]
        st = eng.kv.pool.stats
        assert st.allocated_pages == st.freed_pages
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages
        # every FINISHED request still matches the greedy oracle
        for req in eng.scheduler.done.values():
            if req.state is RequestState.FINISHED:
                assert req.out_tokens == dense_rollout(
                    cfg, params, req.prompt, req.max_new_tokens)


# ---------------------------------------------------------------------------
# sharded serving: replicated slot space + device-mesh parity
# ---------------------------------------------------------------------------

class TestReplicatedSlotSpace:
    """``n_replicas > 1`` without a mesh: the exact vmapped plan/step
    layout the device mesh runs, on one device — the tier-1 parity seam
    for the sharded serving data plane."""

    def _run(self, n_replicas, n_requests=10, seed=0):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=48,
                            max_batch=4, n_replicas=n_replicas,
                            chunk_size=8, token_budget=16)
        rng = np.random.RandomState(seed)
        ids = [eng.submit(list(rng.randint(1, 97, rng.randint(3, 12))),
                          max_new_tokens=8) for _ in range(n_requests)]
        fin = eng.run()
        outs = {r.req_id: r.out_tokens for r in fin}
        return [outs[i] for i in ids], eng

    def test_replicated_outputs_match_single(self):
        """S slots -> R*S slots changes WHICH step serves a request,
        never WHAT it emits: greedy outputs are identical."""
        o1, _ = self._run(1)
        o2, e2 = self._run(2)
        assert o1 == o2
        assert e2.metrics["n_replicas"] == 2
        # replication adds concurrency, not compiled variants
        assert e2.metrics["bucket_compiles"] <= e2.bucket_count

    def test_replicated_matches_dense_oracle(self):
        outs, eng = self._run(2, n_requests=6, seed=7)
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        for req in eng.scheduler.done.values():
            if req.state is RequestState.FINISHED:
                assert req.out_tokens == dense_rollout(
                    cfg, params, req.prompt, req.max_new_tokens)

    def test_slot_space_scales_with_replicas(self):
        """R=2 x max_batch=4 runs 8 requests CONCURRENTLY (the whole
        point: aggregate throughput from replicated slot lanes)."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4, n_replicas=2, chunk_size=8,
                            token_budget=16)
        assert eng.scheduler.total_slots == 8
        for i in range(8):
            eng.submit([(i * 7 + j) % 97 for j in range(4)],
                       max_new_tokens=8)
        eng.step()
        assert len(eng.running) == 8
        lanes = {r.slot for r in eng.running.values()}
        assert lanes == set(range(8))
        eng.run()

    def test_replica_page_isolation(self):
        """A sequence's pages all come from its replica's contiguous
        range — replicas never alias each other's KV."""
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=4,
                          page_size=4, num_pages=16, n_replicas=2)
        kv.create(0, list(range(1, 10)), replica=0)
        kv.create(1, list(range(1, 10)), replica=1)
        assert all(p < 8 for p in kv.tables[0])
        assert all(8 <= p < 16 for p in kv.tables[1])
        # same-prompt prefix hit must NOT cross the replica boundary
        assert kv.seq_replica == {0: 0, 1: 1}
        assert set(kv.tables[0]).isdisjoint(kv.tables[1])
        # growth allocs stay replica-pinned too
        assert kv.ensure_capacity(1, 16)
        assert all(8 <= p < 16 for p in kv.tables[1])
        kv.free_seq(0)
        kv.free_seq(1)
        assert kv.pool.num_free == 16

    def test_replica_oom_is_local(self):
        """Replica 0 running dry rejects ITS admissions while replica 1
        still admits — per-replica free accounting."""
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=4,
                          page_size=4, num_pages=8, n_replicas=2)
        kv.create(0, list(range(1, 16)), replica=0)   # 4 pages: full
        assert not kv.can_admit(4, replica=0)
        assert kv.can_admit(4, replica=1)
        assert kv.pool.free_in(0) == 0 and kv.pool.free_in(1) == 4

    def test_refcount_conservation_replicated_with_cancels(self):
        """The randomized conservation property holds with a replicated
        slot space: allocated == freed + held at every step, per-replica
        ranges never alias, and the pool drains."""
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=24,
                            max_batch=3, n_replicas=2, chunk_size=4,
                            token_budget=8)
        ppr = eng.kv.pages_per_replica
        rng = random.Random(97531)
        ids = []
        for step in range(300):
            if len(ids) < 14 and rng.random() < 0.4:
                n = rng.randint(1, 14)
                base = rng.choice([0, 40])       # some shared prefixes
                ids.append(eng.submit([(base + j) % 97 for j in range(n)],
                                      max_new_tokens=rng.randint(1, 5)))
            if ids and rng.random() < 0.15:
                eng.cancel(rng.choice(ids))      # may be terminal: False
            eng.step()
            st = eng.kv.pool.stats
            held = len(eng.kv.pool.refs)
            assert st.allocated_pages == st.freed_pages + held
            assert held + eng.kv.pool.num_free == eng.kv.pool.num_pages
            for sid, table in eng.kv.tables.items():
                rep = eng.kv.seq_replica[sid]
                assert all(rep * ppr <= p < (rep + 1) * ppr
                           for p in table)
            if len(ids) >= 14 and not eng.waiting and not eng.running:
                break
        eng.run()
        assert len(eng.scheduler.done) == 14     # all terminal
        st = eng.kv.pool.stats
        assert st.allocated_pages == st.freed_pages
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_kv_bytes_and_per_replica_hwm_metrics(self):
        _, eng = self._run(2, n_requests=6)
        m = eng.metrics
        kv = eng.kv
        # page_size * n_kv * hd * (k+v) * itemsize(f32) * layers
        page_bytes = (kv.page_size * kv.n_kv_heads * kv.head_dim
                      * 2 * 4 * kv.n_layers)
        assert m["kv_bytes"] == kv.pool.num_pages * page_bytes
        assert len(m["page_hwm_per_replica"]) == 2
        assert all(h > 0 for h in m["page_hwm_per_replica"])
        assert max(m["page_hwm_per_replica"]) <= eng.kv.pages_per_replica
        assert m["page_hwm"] <= sum(m["page_hwm_per_replica"])

    def test_scheduler_kv_replica_mismatch_raises(self):
        from repro.serving.errors import MeshConfigError
        from repro.serving.scheduler import Scheduler
        kv = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=4,
                          page_size=4, num_pages=8, n_replicas=1)
        with pytest.raises(MeshConfigError):
            Scheduler(kv, max_batch=2, n_replicas=2)

    def test_pool_replica_divisibility_raises(self):
        from repro.serving.errors import MeshConfigError
        with pytest.raises(MeshConfigError):
            PagePool(10, n_replicas=4)

    def test_mesh_for_serving_validation(self):
        from repro.launch.mesh import mesh_for_serving
        from repro.serving.errors import MeshConfigError
        n = len(jax.devices())
        mesh = mesh_for_serving(n, tp=1)
        assert dict(mesh.shape) == {"data": n, "model": 1}
        with pytest.raises(MeshConfigError):
            mesh_for_serving(n + 1)              # more than exist
        with pytest.raises(MeshConfigError):
            mesh_for_serving(n, tp=n + 1)        # tp doesn't divide
        with pytest.raises(MeshConfigError):
            mesh_for_serving(0)

    def test_select_paged_backend(self):
        from repro.models.attention import select_paged_backend
        assert select_paged_backend("pallas", sharded=False) == "pallas"
        assert select_paged_backend("auto", sharded=False) == "auto"
        assert select_paged_backend("pallas", sharded=True) == "ref"
        assert select_paged_backend("ref", sharded=True) == "ref"


class TestShardedParity:
    """Device-mesh parity: the SAME seeded workload on (1,1)/(2,1)/
    (1,2)/(2,2) meshes yields identical finished outputs.  Multi-device
    shapes need forced host devices, so these run in subprocesses
    (pattern from tests/test_checkpoint_distributed.py)."""

    @staticmethod
    def _run_subprocess(code, n_devices=4):
        import os as _os
        import subprocess as _sp
        import sys as _sys
        import textwrap as _tw
        env = dict(_os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{n_devices}")
        env["PYTHONPATH"] = _os.path.join(
            _os.path.dirname(__file__), "..", "src")
        out = _sp.run([_sys.executable, "-c", _tw.dedent(code)],
                      capture_output=True, text=True, env=env,
                      timeout=540)
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    @pytest.mark.slow
    def test_mesh_shapes_identical_outputs(self):
        out = self._run_subprocess("""
            import numpy as np, jax
            import jax.numpy as jnp
            from repro.models.lm import LMConfig, init_params
            from repro.serving.engine import ServingEngine
            from repro.serving.sampling import SamplingParams

            cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, d_ff=128, vocab_size=97,
                           param_dtype=jnp.float32, remat="none",
                           attn_backend="ref")
            params = init_params(cfg, jax.random.key(0))

            def run(shape):
                mesh = (jax.make_mesh(shape, ("data", "model"))
                        if shape else None)
                eng = ServingEngine(
                    cfg, params, page_size=4, num_pages=64, max_batch=4,
                    mesh=mesh, chunk_size=8, token_budget=16,
                    sampling=SamplingParams(temperature=0.8, top_k=20,
                                            seed=42))
                rng = np.random.RandomState(0)
                ids = [eng.submit(
                           list(rng.randint(1, 97, rng.randint(3, 12))),
                           max_new_tokens=8) for _ in range(10)]
                fin = eng.run()
                outs = {r.req_id: r.out_tokens for r in fin}
                assert len(outs) == 10
                m = eng.metrics
                assert m["bucket_compiles"] <= eng.bucket_count
                return [outs[i] for i in ids]

            base = run(None)
            for shape in [(1, 1), (2, 1), (1, 2), (2, 2)]:
                assert run(shape) == base, f"mesh {shape} diverged"
            print("PARITY-OK")
        """)
        assert "PARITY-OK" in out

    @pytest.mark.slow
    def test_paged_attention_heads_sharded_matches_ref(self):
        """kernel-vs-ref with KV heads sharded over ``model``: the
        GSPMD-partitioned gather+softmax equals the single-device
        oracle."""
        out = self._run_subprocess("""
            import numpy as np, jax
            import jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models.attention import paged_attention

            T, H, KVH, HD = 16, 4, 2, 16
            NP_, PS, S, W = 32, 4, 4, 8
            k = jax.random.key(1)
            ks = jax.random.split(k, 5)
            q = jax.random.normal(ks[0], (T, H, HD), jnp.float32)
            kp = jax.random.normal(ks[1], (NP_, PS, KVH, HD), jnp.float32)
            vp = jax.random.normal(ks[2], (NP_, PS, KVH, HD), jnp.float32)
            tables = jax.random.randint(ks[3], (S, W), 0, NP_, jnp.int32)
            seg = jnp.asarray(np.arange(T) % S, jnp.int32)
            pos = jnp.asarray(np.arange(T) // S * PS + 1, jnp.int32)

            ref = paged_attention(q, kp, vp, tables, seg, pos,
                                  backend="ref")

            mesh = jax.make_mesh((1, 2), ("data", "model"))
            kv_sh = NamedSharding(mesh, P(None, None, "model", None))
            f = jax.jit(lambda *a: paged_attention(*a, backend="ref"))
            got = f(q, jax.device_put(kp, kv_sh),
                    jax.device_put(vp, kv_sh), tables, seg, pos)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5, rtol=1e-5)
            print("KERNEL-REF-OK")
        """)
        assert "KERNEL-REF-OK" in out
