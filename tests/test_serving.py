"""Paged KV cache + continuous-batching engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import (LMConfig, decode_step, forward, init_cache,
                             init_params)
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedKVCache, PagePool


def tiny_cfg():
    return LMConfig(name="serve-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab_size=97,
                    param_dtype=jnp.float32, remat="none",
                    attn_backend="ref")


class TestPagePool:
    def test_refcount_release(self):
        pool = PagePool(4)
        p = pool.alloc()
        pool.retain(p)
        pool.release(p)
        assert p not in pool.free
        pool.release(p)
        assert p in pool.free

    def test_oom_returns_none(self):
        pool = PagePool(1)
        assert pool.alloc() is not None
        assert pool.alloc() is None
        assert pool.stats.oom_rejections == 1


class TestPagedKVCache:
    def make(self, num_pages=16, page_size=4):
        return PagedKVCache(n_layers=2, n_kv_heads=2, head_dim=8,
                            page_size=page_size, num_pages=num_pages,
                            dtype=jnp.float32)

    def test_create_and_free_releases_pages(self):
        kv = self.make()
        assert kv.create(0, list(range(10)))
        used = kv.pool.num_pages - kv.pool.num_free
        assert used == 3  # ceil(10/4)
        kv.free_seq(0)
        assert kv.pool.num_free == kv.pool.num_pages

    def test_prefix_sharing_and_cow(self):
        kv = self.make()
        prompt = list(range(8))          # 2 full pages
        kv.create(0, prompt)
        kv.create(1, prompt)             # shares both pages
        assert kv.pool.stats.prefix_hits == 2
        used = kv.pool.num_pages - kv.pool.num_free
        assert used == 2                 # shared!
        # writing through seq 1 triggers copy-on-write
        k_t = jnp.ones((2, 8))
        kv.lengths[1] = 7                # overwrite last slot of page 2
        kv.append(1, [(k_t, k_t), (k_t, k_t)])
        assert kv.pool.stats.cow_copies == 1
        # seq 0's data unchanged
        page0 = kv.tables[0][1]
        page1 = kv.tables[1][1]
        assert page0 != page1

    def test_admission_control(self):
        kv = self.make(num_pages=2)
        assert kv.can_admit(8)
        assert not kv.can_admit(9)
        assert kv.create(0, list(range(8)))
        assert not kv.create(1, list(range(90, 94)))  # no pages left

    def test_gather_roundtrip(self):
        kv = self.make()
        kv.create(0, [1, 2, 3, 4, 5])
        kv.lengths[0] = 0
        writes = []
        for t in range(5):
            k_t = jnp.full((2, 8), float(t + 1))
            writes.append(k_t)
            kv.append(0, [(k_t, k_t * 2), (k_t, k_t * 2)])
        k, v, lens = kv.gather([0], layer=0)
        assert int(lens[0]) == 5
        for t in range(5):
            np.testing.assert_allclose(np.asarray(k[0, :, t]),
                                       np.asarray(writes[t]))
            np.testing.assert_allclose(np.asarray(v[0, :, t]),
                                       np.asarray(writes[t]) * 2)


class TestEngine:
    def test_batched_greedy_matches_dense_rollout(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=4)
        prompts = [[5, 6, 7, 8, 9, 10, 11, 12, 20 + i] for i in range(3)]
        for pr in prompts:
            eng.submit(pr, max_new_tokens=4)
        done = {r.req_id: r for r in eng.run()}
        assert len(done) == 3

        for rid, pr in enumerate(prompts):
            cache = init_cache(cfg, 1, 32, jnp.float32)
            lg = None
            for t, tok in enumerate(pr):
                lg, cache = decode_step(cfg, params, cache,
                                        jnp.asarray([[tok]]), jnp.int32(t))
            seq = []
            cur = int(jnp.argmax(lg[0, -1]))
            pos = len(pr)
            for _ in range(4):
                seq.append(cur)
                lg, cache = decode_step(cfg, params, cache,
                                        jnp.asarray([[cur]]),
                                        jnp.int32(pos))
                cur = int(jnp.argmax(lg[0, -1]))
                pos += 1
            assert done[rid].out_tokens == seq, (rid, done[rid].out_tokens,
                                                 seq)

    def test_prefix_sharing_across_requests(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=64,
                            max_batch=8)
        shared = [5, 6, 7, 8, 9, 10, 11, 12]
        for i in range(5):
            eng.submit(shared + [30 + i], max_new_tokens=2)
        eng.run()
        assert eng.stats()["prefix_hit_rate"] > 0.3

    def test_pages_released_after_completion(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params, page_size=4, num_pages=32,
                            max_batch=2)
        for i in range(4):
            eng.submit([1 + i, 2, 3, 4, 5], max_new_tokens=3)
        eng.run()
        assert eng.kv.pool.num_free == eng.kv.pool.num_pages

    def test_admission_backpressure(self):
        cfg = tiny_cfg()
        params = init_params(cfg, jax.random.key(0))
        # only enough pages for ~1 sequence at a time
        eng = ServingEngine(cfg, params, page_size=4, num_pages=4,
                            max_batch=4)
        for i in range(3):
            eng.submit([1, 2, 3, 4, 5, 6 + i], max_new_tokens=2)
        done = eng.run()
        assert len(done) == 3            # all eventually served
        assert eng.metrics["rejected_admissions"] > 0

    def test_hybrid_arch_rejected(self):
        from repro.models.lm import BlockSpec
        cfg = LMConfig(name="x", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab_size=31,
                       pattern=(BlockSpec("mamba", "dense"),),
                       param_dtype=jnp.float32, remat="none")
        with pytest.raises(ValueError, match="paged engine"):
            ServingEngine(cfg, {}, num_pages=4)


class TestPagePoolProperties:
    def test_alloc_free_invariants_random_trace(self):
        """Property: under random alloc/retain/release traces the pool
        never double-frees, never leaks, and free+live == total."""
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=50, deadline=None)
        @given(ops=st.lists(st.integers(0, 2), min_size=1, max_size=200),
               n=st.integers(1, 16))
        def run(ops, n):
            pool = PagePool(n)
            live = []
            for op in ops:
                if op == 0:
                    p = pool.alloc()
                    if p is not None:
                        live.append(p)
                elif op == 1 and live:
                    pool.retain(live[len(live) // 2])
                    live.append(live[len(live) // 2])
                elif op == 2 and live:
                    pool.release(live.pop())
                held = {p for p in live}
                assert held.isdisjoint(set(pool.free))
                assert len(set(pool.free)) == len(pool.free)
                assert len(pool.free) + len(pool.refs) <= n
            for p in list(live):
                pool.release(p)
            assert len(pool.free) == n

        run()
